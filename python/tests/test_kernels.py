"""L1 kernel correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes/densities/seeds; every case asserts allclose
against ref.py.  These tests are the build-time contract the Rust
runtime relies on.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ell_spmv import (csr_to_ell, ell_spmm, ell_spmv_batch,
                                      ell_spmv_pallas)
from compile.kernels.matmul import matmul_tiled

from tests.helpers import random_csr, random_ell


# ----------------------------------------------------------------------
# ELL SpMV
# ----------------------------------------------------------------------

class TestEllSpmv:
    def test_identity(self, rng):
        n = 32
        idx = np.arange(n, dtype=np.int32)[:, None]
        val = np.ones((n, 1), dtype=np.float32)
        x = rng.normal(size=n).astype(np.float32)
        y = ell_spmv_pallas(idx, val, x, row_tile=8)
        np.testing.assert_allclose(np.asarray(y), x, rtol=1e-6)

    def test_zero_matrix(self, rng):
        n, k = 16, 4
        idx = np.zeros((n, k), dtype=np.int32)
        val = np.zeros((n, k), dtype=np.float32)
        x = rng.normal(size=n).astype(np.float32)
        y = ell_spmv_pallas(idx, val, x, row_tile=8)
        np.testing.assert_array_equal(np.asarray(y), np.zeros(n))

    def test_vs_dense(self, rng):
        n, k = 64, 8
        idx, val = random_ell(rng, n, k, density=0.7)
        x = rng.normal(size=n).astype(np.float32)
        dense = ref.ell_to_dense(idx, val)
        y = ell_spmv_pallas(idx, val, x, row_tile=16)
        np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=2e-5,
                                   atol=1e-5)

    def test_non_multiple_of_tile(self, rng):
        """N not divisible by row_tile exercises the pad-and-slice path."""
        n, k = 37, 3
        idx, val = random_ell(rng, n, k)
        x = rng.normal(size=n).astype(np.float32)
        y = ell_spmv_pallas(idx, val, x, row_tile=16)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.ell_spmv_ref(idx, val, x)),
            rtol=2e-5, atol=1e-5)

    def test_duplicate_columns_accumulate(self, rng):
        """Repeated idx within a row must sum, not overwrite."""
        n = 8
        idx = np.full((n, 3), 2, dtype=np.int32)
        val = np.ones((n, 3), dtype=np.float32)
        x = np.arange(n, dtype=np.float32)
        y = ell_spmv_pallas(idx, val, x, row_tile=8)
        np.testing.assert_allclose(np.asarray(y), np.full(n, 3.0 * x[2]))

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=80),
        k=st.integers(min_value=1, max_value=9),
        density=st.floats(min_value=0.0, max_value=1.0),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, k, density, seed):
        rng = np.random.default_rng(seed)
        idx, val = random_ell(rng, n, k, density=density)
        x = rng.normal(size=n).astype(np.float32)
        y = ell_spmv_pallas(idx, val, x, row_tile=8)
        expect = np.asarray(ref.ell_spmv_ref(idx, val, x))
        np.testing.assert_allclose(np.asarray(y), expect, rtol=3e-5,
                                   atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=8, max_value=64),
        k=st.integers(min_value=1, max_value=6),
        r=st.integers(min_value=1, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_batch_matches_loop(self, n, k, r, seed):
        rng = np.random.default_rng(seed)
        idx, val = random_ell(rng, n, k)
        x = rng.normal(size=(n, r)).astype(np.float32)
        y = np.asarray(ell_spmv_batch(idx, val, x, row_tile=8))
        for j in range(r):
            col = np.asarray(ell_spmv_pallas(idx, val, x[:, j], row_tile=8))
            np.testing.assert_allclose(y[:, j], col, rtol=3e-5, atol=1e-5)


# ----------------------------------------------------------------------
# Multi-RHS ELL SpMM (native padding/spill semantics)
# ----------------------------------------------------------------------

class TestEllSpmm:
    def test_matches_dense_matmul(self, rng):
        n, k, r = 48, 5, 7
        idx, val = random_ell(rng, n, k, density=0.8)
        x = rng.normal(size=(n, r)).astype(np.float32)
        dense = ref.ell_to_dense(idx, val)
        y = ell_spmm(idx, val, x, row_tile=16)
        np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=3e-5,
                                   atol=1e-4)

    def test_padded_rows_and_odd_n(self, rng):
        """Low density (many padded slots), explicit empty rows, and N
        not divisible by the row tile (pad-and-slice path)."""
        n, k, r = 37, 3, 4
        idx, val = random_ell(rng, n, k, density=0.4)
        idx[5] = 0
        val[5] = 0.0
        x = rng.normal(size=(n, r)).astype(np.float32)
        y = np.asarray(ell_spmm(idx, val, x, row_tile=16))
        expect = np.asarray(ref.ell_spmm_ref(idx, val, x))
        np.testing.assert_allclose(y, expect, rtol=3e-5, atol=1e-5)
        np.testing.assert_array_equal(y[5], np.zeros(r))

    @pytest.mark.parametrize("width", [2, 4])
    def test_spill_rows_match_dense(self, rng, width):
        """Rows wider than the ELL width overflow into the CSR spill
        remainder; ELL body + spill must reproduce the dense product."""
        n, r = 24, 5
        widths = rng.integers(0, width + 1, size=n)
        widths[3] = width + 7   # spill rows
        widths[17] = width + 2
        indptr, indices, data = random_csr(rng, widths, n)
        idx, val, spill = csr_to_ell(indptr, indices, data, width)
        assert spill is not None
        sp_indptr, sp_indices, _ = spill
        assert sp_indptr[-1] == (widths[3] - width) + (widths[17] - width)
        assert len(sp_indices) == sp_indptr[-1]
        x = rng.normal(size=(n, r)).astype(np.float32)
        dense = ref.csr_to_dense(indptr, indices, data, n, n)
        y = np.asarray(ell_spmm(idx, val, x, spill=spill, row_tile=8))
        np.testing.assert_allclose(y, dense @ x.astype(np.float64),
                                   rtol=3e-5, atol=1e-4)

    def test_no_spill_when_width_covers(self, rng):
        n, r = 19, 3
        widths = rng.integers(0, 4, size=n)
        indptr, indices, data = random_csr(rng, widths, n)
        idx, val, spill = csr_to_ell(indptr, indices, data, 4)
        assert spill is None
        x = rng.normal(size=(n, r)).astype(np.float32)
        dense = ref.csr_to_dense(indptr, indices, data, n, n)
        y = np.asarray(ell_spmm(idx, val, x, row_tile=8))
        np.testing.assert_allclose(y, dense @ x.astype(np.float64),
                                   rtol=3e-5, atol=1e-4)

    def test_spmm_columns_match_spmv(self, rng):
        """Each column of the blocked product equals the single-RHS
        kernel on that column (the Rust block contract, mirrored)."""
        n, k, r = 32, 4, 6
        idx, val = random_ell(rng, n, k)
        x = rng.normal(size=(n, r)).astype(np.float32)
        y = np.asarray(ell_spmm(idx, val, x, row_tile=8))
        for j in range(r):
            col = np.asarray(ell_spmv_pallas(idx, val, x[:, j], row_tile=8))
            np.testing.assert_allclose(y[:, j], col, rtol=3e-5, atol=1e-5)


# ----------------------------------------------------------------------
# Blocked matmul
# ----------------------------------------------------------------------

class TestMatmulTiled:
    def test_small_exact(self, rng):
        a = rng.normal(size=(4, 4)).astype(np.float32)
        b = rng.normal(size=(4, 4)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(matmul_tiled(a, b, block=4)),
                                   a @ b, rtol=1e-5, atol=1e-5)

    def test_multi_block_accumulation(self, rng):
        """K-axis grid > 1 exercises the accumulate-into-o_ref path."""
        a = rng.normal(size=(8, 32)).astype(np.float32)
        b = rng.normal(size=(32, 8)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(matmul_tiled(a, b, block=8)),
                                   a @ b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=40),
        k=st.integers(min_value=1, max_value=40),
        n=st.integers(min_value=1, max_value=40),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(m, k)).astype(np.float32)
        b = rng.normal(size=(k, n)).astype(np.float32)
        out = np.asarray(matmul_tiled(a, b, block=16))
        np.testing.assert_allclose(out, a @ b, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# Oracles are self-consistent
# ----------------------------------------------------------------------

class TestRefInternal:
    def test_expm_identity(self):
        np.testing.assert_allclose(ref.expm_taylor_ref(np.zeros((5, 5))),
                                   np.eye(5), atol=1e-12)

    def test_expm_vs_eig(self, rng):
        a = rng.normal(size=(6, 6))
        a = (a + a.T) / 2
        lam, q = np.linalg.eigh(a)
        expect = q @ np.diag(np.exp(lam)) @ q.T
        np.testing.assert_allclose(ref.expm_taylor_ref(a), expect,
                                   rtol=1e-8, atol=1e-8)

    def test_diffusion_kernel_psd(self, rng):
        w = rng.random((10, 10))
        w = np.triu(w, 1)
        w = w + w.T
        k = ref.diffusion_kernel_ref(w, beta=0.7)
        lam = np.linalg.eigvalsh(k)
        assert lam.min() > -1e-10
