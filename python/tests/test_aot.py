"""AOT pipeline integrity: lower the --quick bucket and validate the
manifest contract the Rust runtime (rust/src/runtime/manifest.rs)
depends on: file presence, input ordering, shape/dtype fields."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_quick")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=PYDIR,
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_structure(quick_artifacts):
    with open(quick_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text/return-tuple"
    assert manifest["cg_iters"] > 0
    arts = manifest["artifacts"]
    kinds = {a["kind"] for a in arts}
    assert {"gram_matvec", "cg_solve", "posterior_sample",
            "posterior_mean", "dense_diffusion"} <= kinds
    for a in arts:
        # Every artifact file exists and is non-trivial HLO text.
        path = quick_artifacts / a["file"]
        assert path.exists(), a["file"]
        text = path.read_text()
        assert "HloModule" in text
        assert a["bytes"] == len(text)
        # Shape bucket fields are coherent.
        assert a["n"] > 0
        if a["kind"] != "dense_diffusion":
            assert a["k"] > 0 and a["kt"] >= a["k"]


def test_input_ordering_matches_runtime_contract(quick_artifacts):
    """The Rust runtime packs literals positionally; the manifest input
    order must be exactly what runtime/mod.rs sends."""
    with open(quick_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    expect = {
        "gram_matvec": ["phi_idx", "phi_val", "phit_idx", "phit_val", "x",
                        "sigma2"],
        "cg_solve": ["phi_idx", "phi_val", "phit_idx", "phit_val", "mask",
                     "b", "sigma2"],
        "posterior_sample": ["phi_idx", "phi_val", "phit_idx", "phit_val",
                             "mask", "y", "w", "eps", "sigma2"],
        "posterior_mean": ["phi_idx", "phi_val", "phit_idx", "phit_val",
                           "mask", "y", "sigma2"],
        "dense_diffusion": ["w_adj", "beta", "sigma_f2"],
    }
    for a in manifest["artifacts"]:
        names = [i["name"] for i in a["inputs"]]
        assert names == expect[a["kind"]], a["name"]


def test_ell_dtypes(quick_artifacts):
    with open(quick_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    for a in manifest["artifacts"]:
        for inp in a["inputs"]:
            if inp["name"].endswith("_idx"):
                assert inp["dtype"] == "int32"
            else:
                assert inp["dtype"] == "float32"
