import os
import sys

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_ell(rng, n, k, n_cols=None, density=1.0):
    """Random ELL pair: some rows fully populated, some padded."""
    n_cols = n_cols or n
    idx = rng.integers(0, n_cols, size=(n, k)).astype(np.int32)
    val = rng.normal(size=(n, k)).astype(np.float32)
    keep = rng.random(size=(n, k)) < density
    val = np.where(keep, val, 0.0).astype(np.float32)
    idx = np.where(keep, idx, 0).astype(np.int32)
    return idx, val
