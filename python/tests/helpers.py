"""Shared test fixtures/utilities."""
import numpy as np


def random_ell(rng, n, k, n_cols=None, density=1.0):
    """Random ELL pair: some rows fully populated, some padded."""
    n_cols = n_cols or n
    idx = rng.integers(0, n_cols, size=(n, k)).astype(np.int32)
    val = rng.normal(size=(n, k)).astype(np.float32)
    keep = rng.random(size=(n, k)) < density
    val = np.where(keep, val, 0.0).astype(np.float32)
    idx = np.where(keep, idx, 0).astype(np.int32)
    return idx, val


def random_csr(rng, widths, n_cols):
    """Random CSR triple with the given per-row nonzero counts.

    Columns are unique and sorted within each row (canonical CSR, like
    the Rust `CooBuilder` output); `widths[i] == 0` gives an empty row.
    """
    indptr = np.zeros(len(widths) + 1, dtype=np.int64)
    indices = []
    data = []
    for i, w in enumerate(widths):
        cols = np.sort(rng.choice(n_cols, size=min(w, n_cols), replace=False))
        indices.extend(cols)
        data.extend(rng.normal(size=len(cols)))
        indptr[i + 1] = len(indices)
    return (
        indptr,
        np.asarray(indices, dtype=np.int32),
        np.asarray(data, dtype=np.float32),
    )
