"""L2 model-graph correctness: jitted GP graphs vs dense oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

from tests.helpers import random_ell


def ell_transpose(idx, val, kt):
    """Dense-roundtrip transpose for test fixtures (rust does this natively)."""
    dense = ref.ell_to_dense(idx, val).T
    n = dense.shape[0]
    t_idx = np.zeros((n, kt), dtype=np.int32)
    t_val = np.zeros((n, kt), dtype=np.float32)
    for i in range(n):
        nz = np.nonzero(dense[i])[0]
        assert len(nz) <= kt, "test fixture too dense for kt"
        t_idx[i, :len(nz)] = nz
        t_val[i, :len(nz)] = dense[i, nz]
    return t_idx, t_val


def make_problem(seed, n=32, k=3, kt=None, train_frac=0.5):
    rng = np.random.default_rng(seed)
    idx, val = random_ell(rng, n, k, density=0.8)
    val = (val * 0.3).astype(np.float32)      # keep K well-conditioned
    kt = kt or 4 * k
    t_idx, t_val = ell_transpose(idx, val, kt)
    dense = ref.ell_to_dense(idx, val)
    mask = (rng.random(n) < train_frac).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    y = (mask * rng.normal(size=n)).astype(np.float32)
    return idx, val, t_idx, t_val, dense, mask, y, rng


class TestGramMatvec:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_vs_dense(self, seed):
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(seed)
        x = rng.normal(size=dense.shape[0]).astype(np.float32)
        got = np.asarray(model.gram_matvec(idx, val, t_idx, t_val, x,
                                           np.float32(0.3)))
        expect = np.asarray(ref.gram_matvec_ref(dense, x, 0.3))
        np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-4)

    def test_masked_operator_spd(self):
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(7)
        n = dense.shape[0]
        # Assemble the operator matrix column by column; check SPD.
        a = np.zeros((n, n))
        for j in range(n):
            e = np.zeros(n, dtype=np.float32)
            e[j] = 1.0
            a[:, j] = np.asarray(model.masked_gram_matvec(
                idx, val, t_idx, t_val, mask, e, np.float32(0.5)))
        np.testing.assert_allclose(a, a.T, atol=1e-5)
        lam = np.linalg.eigvalsh((a + a.T) / 2)
        assert lam.min() > 0.4   # >= sigma2 - tolerance


class TestCgSolve:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_vs_direct(self, seed):
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(seed)
        n = dense.shape[0]
        b = (mask[:, None] * rng.normal(size=(n, 2))).astype(np.float32)
        x, rs = model.cg_solve(idx, val, t_idx, t_val, mask, b,
                               np.float32(0.5), iters=n)
        expect = ref.cg_solve_ref(dense, mask, b, 0.5)
        np.testing.assert_allclose(np.asarray(x), expect, rtol=5e-3,
                                   atol=5e-3)
        assert np.all(np.asarray(rs) < 1e-4)

    def test_off_train_stays_zero(self):
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(3)
        n = dense.shape[0]
        b = (mask * rng.normal(size=n)).astype(np.float32)[:, None]
        x, _ = model.cg_solve(idx, val, t_idx, t_val, mask, b,
                              np.float32(0.5), iters=n)
        x = np.asarray(x)[:, 0]
        np.testing.assert_allclose(x[mask == 0], 0.0, atol=1e-6)


class TestPosterior:
    def test_sample_matches_dense_pathwise(self):
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(11)
        n = dense.shape[0]
        w = rng.normal(size=n).astype(np.float32)
        eps = (0.1 * rng.normal(size=n)).astype(np.float32)
        got, rs = model.posterior_sample(idx, val, t_idx, t_val, mask,
                                         y, w, eps, np.float32(0.25),
                                         iters=n)
        expect = ref.posterior_sample_ref(dense, mask, y, w, eps, 0.25)
        np.testing.assert_allclose(np.asarray(got), expect, rtol=5e-3,
                                   atol=5e-3)

    def test_mean_interpolates_when_noise_small(self):
        """With tiny noise, posterior mean ~ y at training nodes."""
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(5)
        # Make the kernel strongly diagonal so the system is well posed.
        n = dense.shape[0]
        idx2 = np.arange(n, dtype=np.int32)[:, None]
        val2 = np.ones((n, 1), dtype=np.float32)
        mean, _ = model.posterior_mean(idx2, val2, idx2, val2, mask, y,
                                       np.float32(1e-4), iters=n)
        mean = np.asarray(mean)
        np.testing.assert_allclose(mean[mask == 1], y[mask == 1],
                                   rtol=1e-2, atol=1e-2)

    def test_sample_moments(self):
        """Empirical mean/cov of pathwise samples match GP posterior."""
        idx, val, t_idx, t_val, dense, mask, y, rng = make_problem(2, n=16,
                                                                   k=2)
        n = dense.shape[0]
        sigma2 = 0.25
        draws = []
        for s in range(400):
            w = rng.normal(size=n).astype(np.float32)
            eps = (np.sqrt(sigma2) * rng.normal(size=n)).astype(np.float32)
            g, _ = model.posterior_sample(idx, val, t_idx, t_val, mask, y,
                                          w, eps, np.float32(sigma2),
                                          iters=n)
            draws.append(np.asarray(g))
        draws = np.stack(draws)
        # Dense posterior mean: K m (m K m + s I)^{-1} y
        k = dense.astype(np.float64) @ dense.astype(np.float64).T
        alpha = ref.cg_solve_ref(dense, mask, (mask * y), sigma2)
        mean = k @ (mask * alpha)
        err = np.abs(draws.mean(axis=0) - mean)
        assert err.max() < 0.25, f"max |emp - exact| = {err.max()}"


class TestDenseDiffusion:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           beta=st.floats(min_value=0.05, max_value=2.0))
    def test_vs_ref(self, seed, beta):
        rng = np.random.default_rng(seed)
        n = 16
        w = rng.random((n, n)).astype(np.float32)
        w = np.triu(w, 1)
        w = (w + w.T).astype(np.float32)
        got = np.asarray(model.dense_diffusion(w, np.float32(beta),
                                               np.float32(1.3)))
        expect = ref.diffusion_kernel_ref(w, beta, 1.3)
        np.testing.assert_allclose(got, expect, rtol=1e-3, atol=1e-3)
