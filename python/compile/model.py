"""Layer-2: the GRF-GP compute graphs, in JAX, calling the L1 kernels.

Everything here is build-time only — `aot.py` lowers these functions to
HLO text once, and the Rust runtime (rust/src/runtime/) loads and
executes the artifacts on the PJRT CPU client.  Python never runs on
the request path.

Conventions shared with the Rust side (see rust/src/runtime/mod.rs):

  * The GRF feature matrix Phi (N x N, sparse) is passed as a pair of
    ELL arrays: row-major (phi_idx, phi_val) of shape [N, K] for
    products Phi @ x, and the ELL of Phi^T, (phit_idx, phit_val) of
    shape [N, Kt], for products Phi^T @ x.
  * Training-set restriction is a mask m in {0,1}^N: the masked CG
    operator A(v) = m*(Phi Phi^T (m*v)) + sigma2*v solves the training
    system embedded in R^N (off-train coordinates decouple and stay 0
    whenever the RHS is masked), so a single shape bucket serves any
    train/test split.
  * All dtypes f32 / i32; sigma2 and kernel scales are scalar inputs.
"""

import jax
import jax.numpy as jnp

from .kernels.ell_spmv import ell_spmv, ell_spmv_batch
from .kernels.matmul import matmul_tiled

# Fixed CG iteration budget compiled into the artifacts.  The paper's
# near-linear training/inference scaling (Table 1) explicitly reflects
# "the fixed iteration budget of sparse linear solves"; the Rust native
# engine uses a tolerance-based stop instead, and the two are compared
# in rust/tests/pjrt_parity.rs.
DEFAULT_CG_ITERS = 32


# ----------------------------------------------------------------------
# Core operators
# ----------------------------------------------------------------------

def gram_matvec(phi_idx, phi_val, phit_idx, phit_val, x, sigma2):
    """(Phi Phi^T + sigma2 I) @ x via two sparse matvecs (never forms K)."""
    z = ell_spmv(phit_idx, phit_val, x)
    y = ell_spmv(phi_idx, phi_val, z)
    return y + sigma2 * x


def masked_gram_matvec(phi_idx, phi_val, phit_idx, phit_val, mask, x, sigma2):
    """A(x) = m*(Phi Phi^T (m*x)) + sigma2*x — SPD for sigma2 > 0."""
    mx = mask * x
    z = ell_spmv(phit_idx, phit_val, mx)
    y = ell_spmv(phi_idx, phi_val, z)
    return mask * y + sigma2 * x


def _masked_gram_matmat(phi_idx, phi_val, phit_idx, phit_val, mask, x, sigma2):
    """Batched masked operator on X: f32[N, R]."""
    mx = mask[:, None] * x
    z = ell_spmv_batch(phit_idx, phit_val, mx)
    y = ell_spmv_batch(phi_idx, phi_val, z)
    return mask[:, None] * y + sigma2 * x


def cg_solve(phi_idx, phi_val, phit_idx, phit_val, mask, b, sigma2,
             iters=DEFAULT_CG_ITERS):
    """Solve (m K m + sigma2 I) X = B for B f32[N, R] with batched CG.

    Fixed `iters` iterations (lax.scan — fully unrolled into a compiled
    loop), per-column scalars.  Returns (X, residual_sq[R]).
    """

    def matvec(v):
        return _masked_gram_matmat(
            phi_idx, phi_val, phit_idx, phit_val, mask, v, sigma2)

    x0 = jnp.zeros_like(b)
    r0 = b - matvec(x0)
    p0 = r0
    rs0 = jnp.sum(r0 * r0, axis=0)          # [R]

    def step(carry, _):
        x, r, p, rs = carry
        ap = matvec(p)
        # Guard against exactly-converged columns (rs == 0).
        denom = jnp.sum(p * ap, axis=0)
        alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        rs_new = jnp.sum(r * r, axis=0)
        beta = jnp.where(rs > 0, rs_new / jnp.maximum(rs, 1e-30), 0.0)
        p = r + beta[None, :] * p
        return (x, r, p, rs_new), None

    (x, r, _, rs), _ = jax.lax.scan(step, (x0, r0, p0, rs0), None,
                                    length=iters)
    return x, rs


# ----------------------------------------------------------------------
# GP workflow graphs (the artifacts)
# ----------------------------------------------------------------------

def posterior_sample(phi_idx, phi_val, phit_idx, phit_val, mask,
                     y, w, eps, sigma2, iters=DEFAULT_CG_ITERS):
    """One pathwise-conditioning posterior draw (paper Eq. 12), fused.

      g      = Phi w,  w ~ N(0, I)      (prior sample: Cov = Phi Phi^T)
      rhs    = m * (y - g - eps)        (eps ~ N(0, sigma2 I))
      alpha  = (m K m + sigma2 I)^{-1} rhs       (masked batched CG)
      sample = g + Phi (Phi^T (m * alpha))       (correction term)

    This is the entire inner loop of graph Thompson sampling — one
    artifact execution per BO step.
    """
    g = ell_spmv(phi_idx, phi_val, w)
    rhs = mask * (y - g - eps)
    alpha, rs = cg_solve(phi_idx, phi_val, phit_idx, phit_val, mask,
                         rhs[:, None], sigma2, iters=iters)
    alpha = alpha[:, 0]
    corr = ell_spmv(phi_idx, phi_val,
                    ell_spmv(phit_idx, phit_val, mask * alpha))
    return g + corr, rs[0]


def posterior_mean(phi_idx, phi_val, phit_idx, phit_val, mask, y, sigma2,
                   iters=DEFAULT_CG_ITERS):
    """MAP prediction at every node: K_{.,x} (K_xx + sigma2 I)^{-1} y."""
    rhs = (mask * y)[:, None]
    alpha, rs = cg_solve(phi_idx, phi_val, phit_idx, phit_val, mask,
                         rhs, sigma2, iters=iters)
    alpha = alpha[:, 0]
    mean = ell_spmv(phi_idx, phi_val,
                    ell_spmv(phit_idx, phit_val, mask * alpha))
    return mean, rs[0]


def lml_solves(phi_idx, phi_val, phit_idx, phit_val, mask, b, sigma2,
               iters=DEFAULT_CG_ITERS):
    """The batch of solves for one LML-gradient step (paper Eq. 9-11).

    B packs [y, z_1, ..., z_S] (observation vector + Hutchinson probes);
    the Rust side assembles the gradient from the returned solves.
    """
    return cg_solve(phi_idx, phi_val, phit_idx, phit_val, mask, b, sigma2,
                    iters=iters)


# ----------------------------------------------------------------------
# Dense baseline graph
# ----------------------------------------------------------------------

DENSE_EXPM_SQUARINGS = 8
DENSE_EXPM_ORDER = 12


def dense_diffusion(w_adj, beta, sigma_f2):
    """Exact diffusion kernel K = sigma_f^2 exp(-beta L) (dense baseline).

    expm via scaling-and-squaring with a fixed squaring count (shape- and
    trace-stable): exp(A) = (exp(A / 2^s))^(2^s), Taylor order 12.  Valid
    for ||beta*L||_inf <~ 2^s; the manifest records the bound and the
    Rust runtime checks it before dispatching to this artifact.
    All matmuls go through the L1 blocked Pallas kernel (MXU path).
    """
    n = w_adj.shape[0]
    deg = jnp.sum(w_adj, axis=1)
    lap = jnp.diag(deg) - w_adj
    a = (-beta / (2.0 ** DENSE_EXPM_SQUARINGS)) * lap

    eye = jnp.eye(n, dtype=w_adj.dtype)
    term = eye
    out = eye
    for r in range(1, DENSE_EXPM_ORDER + 1):
        term = matmul_tiled(term, a) / r
        out = out + term
    for _ in range(DENSE_EXPM_SQUARINGS):
        out = matmul_tiled(out, out)
    return sigma_f2 * out
