"""Pure-jnp/numpy oracles for every Layer-1 kernel and Layer-2 graph.

These are the CORE correctness signal: pytest (and hypothesis sweeps)
assert_allclose each Pallas kernel / jitted model graph against the
implementations here.  Everything below is deliberately naive.
"""

import jax.numpy as jnp
import numpy as np


def ell_spmv_ref(idx, val, x):
    """y[i] = sum_k val[i,k] * x[idx[i,k]] — naive gather."""
    return jnp.sum(val * x[idx], axis=1)


def ell_spmm_ref(idx, val, x):
    """Y[i, :] = sum_k val[i,k] * X[idx[i,k], :] — naive batched gather."""
    return jnp.sum(val[..., None] * x[idx], axis=1)


def csr_to_dense(indptr, indices, data, n_rows, n_cols):
    """Expand a CSR triple into a dense [n_rows, n_cols] matrix."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    data = np.asarray(data)
    dense = np.zeros((n_rows, n_cols), dtype=np.float64)
    for i in range(n_rows):
        for k in range(indptr[i], indptr[i + 1]):
            dense[i, indices[k]] += data[k]
    return dense


def ell_to_dense(idx, val, n_cols=None):
    """Expand an ELL (idx, val) pair into a dense [N, n_cols] matrix."""
    idx = np.asarray(idx)
    val = np.asarray(val)
    n, k = idx.shape
    n_cols = n_cols or n
    dense = np.zeros((n, n_cols), dtype=val.dtype)
    for i in range(n):
        for j in range(k):
            dense[i, idx[i, j]] += val[i, j]
    return dense


def gram_matvec_ref(phi_dense, x, sigma2):
    """(Phi Phi^T + sigma2 I) x with dense Phi."""
    return phi_dense @ (phi_dense.T @ x) + sigma2 * x


def masked_gram_matvec_ref(phi_dense, mask, x, sigma2):
    """A(x) = m * (Phi Phi^T (m*x)) + sigma2 x — the masked CG operator."""
    return mask * (phi_dense @ (phi_dense.T @ (mask * x))) + sigma2 * x


def cg_solve_ref(phi_dense, mask, b, sigma2):
    """Direct dense solve of the masked system (oracle for cg_solve)."""
    n = phi_dense.shape[0]
    m = np.diag(np.asarray(mask, dtype=np.float64))
    k = np.asarray(phi_dense, dtype=np.float64)
    a = m @ k @ k.T @ m + sigma2 * np.eye(n)
    return np.linalg.solve(a, np.asarray(b, dtype=np.float64))


def posterior_sample_ref(phi_dense, mask, y, w, eps, sigma2):
    """Pathwise conditioning (paper Eq. 12) with dense algebra.

    g      = Phi w                      (prior sample at all nodes)
    rhs    = m * (y - g - eps)
    alpha  = (m K m + sigma2 I)^{-1} rhs   (masked solve; alpha=0 off-train)
    sample = g + K @ (m * alpha)
    """
    phi64 = np.asarray(phi_dense, dtype=np.float64)
    g = phi64 @ np.asarray(w, dtype=np.float64)
    rhs = np.asarray(mask, np.float64) * (np.asarray(y, np.float64) - g
                                          - np.asarray(eps, np.float64))
    alpha = cg_solve_ref(phi_dense, mask, rhs, sigma2)
    k = phi64 @ phi64.T
    return g + k @ (np.asarray(mask, np.float64) * alpha)


def expm_taylor_ref(a, order=32):
    """Matrix exponential via scaling-and-squaring + Taylor (float64)."""
    a = np.asarray(a, dtype=np.float64)
    n = a.shape[0]
    nrm = np.linalg.norm(a, ord=np.inf)
    squarings = max(0, int(np.ceil(np.log2(max(nrm, 1e-30)))) + 1)
    a_s = a / (2.0 ** squarings)
    out = np.eye(n)
    term = np.eye(n)
    for r in range(1, order + 1):
        term = term @ a_s / r
        out = out + term
    for _ in range(squarings):
        out = out @ out
    return out


def diffusion_kernel_ref(w_adj, beta, sigma_f2=1.0):
    """K = sigma_f^2 exp(-beta L), L = D - W  (dense, float64)."""
    w_adj = np.asarray(w_adj, dtype=np.float64)
    lap = np.diag(w_adj.sum(axis=1)) - w_adj
    return sigma_f2 * expm_taylor_ref(-beta * lap)
