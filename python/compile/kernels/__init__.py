"""Layer-1 Pallas kernels for GRF-GP.

Every kernel here is lowered with ``interpret=True`` — the CPU PJRT
plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness path and real-TPU performance is estimated analytically
(see DESIGN.md §Hardware-Adaptation).
"""

from .ell_spmv import ell_spmv, ell_spmv_pallas, DEFAULT_ROW_TILE
from .matmul import matmul_tiled
from . import ref

__all__ = [
    "ell_spmv",
    "ell_spmv_pallas",
    "matmul_tiled",
    "ref",
    "DEFAULT_ROW_TILE",
]
