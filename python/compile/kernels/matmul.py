"""Blocked dense matmul Pallas kernel.

Used by the *dense baseline* artifacts (exact diffusion kernel via
scaling-and-squaring): chains of N x N matmuls.  On a real TPU this is
the MXU path — [BM, BK] x [BK, BN] systolic tiles accumulated over the
K grid axis; under interpret=True it is a correctness mirror of the
same schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Grid (M/BM, N/BN, K/BK); accumulate partial products into o_ref."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block",))
def matmul_tiled(a, b, block=DEFAULT_BLOCK):
    """C = A @ B with an MXU-style blocked schedule (interpret mode)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = min(block, m)
    bn = min(block, n)
    bk = min(block, k)
    if m % bm or n % bn or k % bk:
        # Tests with odd sizes: pad up, compute, slice back.
        mp, np_, kp = -m % bm, -n % bn, -k % bk
        a = jnp.pad(a, ((0, mp), (0, kp)))
        b = jnp.pad(b, ((0, kp), (0, np_)))
        return matmul_tiled(a, b, block=block)[:m, :n]
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
