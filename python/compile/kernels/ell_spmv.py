"""ELL-format sparse matrix-vector product as a Pallas kernel.

The GRF feature matrix Phi is *naturally* fixed-width sparse: Theorem 1
of the paper bounds the number of nonzeros per feature by a constant
w.h.p., so padding rows to a common width K wastes a bounded, known
factor.  ELL layout stores the matrix as two dense [N, K] arrays:

    idx[i, k] — column of the k-th nonzero of row i (0 for padding)
    val[i, k] — its value                          (0.0 for padding)

and the matvec is  y[i] = sum_k val[i, k] * x[idx[i, k]].

Hardware adaptation (paper ran CSR SpMV on an RTX 2080 Ti): the GPU
warp-per-row gather becomes a ROW_TILE-rows-per-grid-step Pallas block.
Each grid step holds a [ROW_TILE, K] tile of idx/val in VMEM plus the
full dense vector x (f32[N] fits comfortably in the ~16 MiB VMEM budget
for every bucket we compile; see DESIGN.md §8 for footprints), performs
a vectorised gather and a VPU reduce over K.  The op is memory-bound —
roofline is HBM bytes, not MXU flops.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Rows per grid step.  8 sublanes x 128 lanes is the natural f32 tile on
# TPU; 128 rows keeps the [ROW_TILE, K] tile well inside VMEM for every
# K bucket we compile (K <= 128 -> 64 KiB val + 64 KiB idx per step).
DEFAULT_ROW_TILE = 128


def _ell_spmv_kernel(idx_ref, val_ref, x_ref, o_ref):
    """One grid step: y_tile = sum_k val_tile[:, k] * x[idx_tile[:, k]]."""
    idx = idx_ref[...]          # [ROW_TILE, K] int32
    val = val_ref[...]          # [ROW_TILE, K] f32
    x = x_ref[...]              # [N] f32  (whole vector resident in VMEM)
    gathered = x[idx]           # [ROW_TILE, K] gather
    o_ref[...] = jnp.sum(val * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def ell_spmv_pallas(idx, val, x, row_tile=DEFAULT_ROW_TILE):
    """y = A @ x for A in ELL format, as a Pallas kernel (interpret mode).

    Args:
      idx: int32[N, K] column indices (padding entries may be any valid
        column as long as the matching ``val`` is 0).
      val: f32[N, K] values.
      x:   f32[N] dense vector.
    Returns:
      f32[N] product.
    """
    n, k = idx.shape
    if n % row_tile != 0:
        # Shape buckets are always multiples of the tile; this path only
        # triggers in tests with odd sizes.
        pad = row_tile - n % row_tile
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0)))
        out = ell_spmv_pallas(idx, val, x, row_tile=row_tile)
        return out[:n]
    grid = (n // row_tile,)
    return pl.pallas_call(
        _ell_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),   # full vector each step
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), val.dtype),
        interpret=True,
    )(idx, val, x)


def ell_spmv(idx, val, x):
    """Public entry point used by the L2 model graph."""
    return ell_spmv_pallas(idx, val, x)


def _ell_spmv_batch_kernel(idx_ref, val_ref, x_ref, o_ref):
    """Batched variant: X is [N, R]; one grid step computes [ROW_TILE, R]."""
    idx = idx_ref[...]                   # [ROW_TILE, K]
    val = val_ref[...]                   # [ROW_TILE, K]
    x = x_ref[...]                       # [N, R]
    gathered = x[idx]                    # [ROW_TILE, K, R]
    o_ref[...] = jnp.sum(val[..., None] * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def ell_spmv_batch(idx, val, x, row_tile=DEFAULT_ROW_TILE):
    """Y = A @ X for A in ELL format and X f32[N, R] (batched RHS).

    Used by the batched-CG artifact: solving for [y, z_1..z_S] probes
    simultaneously amortises the idx/val tile traffic across R columns
    (R-fold better arithmetic intensity than R separate matvecs).
    """
    n, k = idx.shape
    _, r = x.shape
    if n % row_tile != 0:
        pad = row_tile - n % row_tile
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0)))
        return ell_spmv_batch(idx, val, x, row_tile=row_tile)[:n]
    grid = (n // row_tile,)
    return pl.pallas_call(
        _ell_spmv_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), val.dtype),
        interpret=True,
    )(idx, val, x)


# ----------------------------------------------------------------------
# Multi-RHS SpMM with the native (Rust) padding/spill semantics
# ----------------------------------------------------------------------
#
# The Rust engine's `Csr::to_ell` packs the first `width` entries of
# each row into the dense [N, width] arrays (padding with idx 0 /
# val 0) and keeps the overflow of wider rows in a small CSR *spill*
# remainder, so any matrix converts losslessly without padding every
# row to the maximum width.  `csr_to_ell` mirrors that split
# host-side, and `ell_spmm` applies both parts: the regular ELL body
# through the Pallas batch kernel, the (tiny) spill through a
# segment-sum gather.


def csr_to_ell(indptr, indices, data, width):
    """Split a CSR matrix into an ELL body + CSR spill remainder.

    Mirrors the Rust ``Csr::to_ell`` layout exactly: row ``i``'s first
    ``width`` entries land in ``idx/val[i, :]`` (padded with index 0 /
    value 0), the rest stay — in order — in the returned spill CSR
    ``(sp_indptr, sp_indices, sp_data)``.

    Returns ``(idx, val, spill)`` with ``spill = None`` when no row is
    wider than ``width``.
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    data = np.asarray(data)
    n = len(indptr) - 1
    idx = np.zeros((n, width), dtype=np.int32)
    val = np.zeros((n, width), dtype=np.float32)
    sp_indptr = np.zeros(n + 1, dtype=np.int64)
    sp_indices = []
    sp_data = []
    for i in range(n):
        row = slice(indptr[i], indptr[i + 1])
        cols_i = indices[row]
        vals_i = data[row]
        head = min(len(cols_i), width)
        idx[i, :head] = cols_i[:head]
        val[i, :head] = vals_i[:head]
        sp_indices.extend(cols_i[head:])
        sp_data.extend(vals_i[head:])
        sp_indptr[i + 1] = len(sp_indices)
    if not sp_indices:
        return idx, val, None
    spill = (
        sp_indptr,
        np.asarray(sp_indices, dtype=np.int32),
        np.asarray(sp_data, dtype=np.float32),
    )
    return idx, val, spill


def _spill_spmm(spill, x, n_rows):
    """Y contribution of the CSR spill remainder: a segment-sum gather.

    The spill holds only the overflow of the few rows wider than the
    ELL width, so this is a tiny irregular tail — jnp ops are plenty;
    the bandwidth-critical regular body runs in the Pallas kernel.
    """
    sp_indptr, sp_indices, sp_data = spill
    nnz = int(sp_indices.shape[0])
    counts = jnp.diff(jnp.asarray(sp_indptr))
    row_ids = jnp.repeat(
        jnp.arange(n_rows, dtype=jnp.int32), counts, total_repeat_length=nnz
    )
    contrib = jnp.asarray(sp_data)[:, None] * x[jnp.asarray(sp_indices)]
    return jnp.zeros((n_rows, x.shape[1]), x.dtype).at[row_ids].add(contrib)


def ell_spmm(idx, val, x, spill=None, row_tile=DEFAULT_ROW_TILE):
    """Y = A @ X for A split as ELL body + optional CSR spill.

    Args:
      idx: int32[N, K] ELL column indices (padding: 0 with val 0).
      val: f32[N, K] ELL values.
      x:   f32[M, R] dense multi-RHS block.
      spill: optional ``(indptr, indices, data)`` CSR remainder from
        :func:`csr_to_ell` holding the entries of rows wider than K.
    Returns:
      f32[N, R] product, matching the dense oracle ``A_dense @ x``.
    """
    y = ell_spmv_batch(idx, val, x, row_tile=row_tile)
    if spill is not None:
        y = y + _spill_spmm(spill, jnp.asarray(x), idx.shape[0])
    return y
