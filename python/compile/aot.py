"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Each entry point is lowered for a ladder of shape buckets
``(N, K, Kt, R)`` and recorded in ``artifacts/manifest.json``; the Rust
runtime pads its inputs up to the nearest bucket.  Usage:

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--buckets 1024:32:64,4096:32:64] [--rhs 8] [--iters 32] \
        [--dense-n 256] [--quick]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ell_args(n, k, kt):
    """The four ELL arrays every sparse entry point takes, in order."""
    return [
        ("phi_idx", _spec((n, k), I32)),
        ("phi_val", _spec((n, k))),
        ("phit_idx", _spec((n, kt), I32)),
        ("phit_val", _spec((n, kt))),
    ]


def entry_points(n, k, kt, r, iters):
    """(name, fn, [(arg_name, ShapeDtypeStruct)]) for one bucket."""
    ell = _ell_args(n, k, kt)
    s = ("sigma2", _spec(()))
    mask = ("mask", _spec((n,)))
    eps = []  # populated below for readability

    def wrap_iters(fn):
        def inner(*args):
            return fn(*args, iters=iters)
        return inner

    return [
        (
            f"gram_matvec_n{n}_k{k}_kt{kt}",
            model.gram_matvec,
            ell + [("x", _spec((n,))), s],
        ),
        (
            f"cg_solve_n{n}_k{k}_kt{kt}_r{r}_i{iters}",
            wrap_iters(model.cg_solve),
            ell + [mask, ("b", _spec((n, r))), s],
        ),
        (
            f"posterior_sample_n{n}_k{k}_kt{kt}_i{iters}",
            wrap_iters(model.posterior_sample),
            ell + [mask, ("y", _spec((n,))), ("w", _spec((n,))),
                   ("eps", _spec((n,))), s],
        ),
        (
            f"posterior_mean_n{n}_k{k}_kt{kt}_i{iters}",
            wrap_iters(model.posterior_mean),
            ell + [mask, ("y", _spec((n,))), s],
        ),
    ]


def dense_entry_points(n):
    return [
        (
            f"dense_diffusion_n{n}",
            model.dense_diffusion,
            [("w_adj", _spec((n, n))), ("beta", _spec(())),
             ("sigma_f2", _spec(()))],
        ),
    ]


def lower_one(name, fn, args, out_dir):
    specs = [spec for _, spec in args]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"name": arg_name,
             "shape": list(spec.shape),
             "dtype": str(spec.dtype)}
            for arg_name, spec in args
        ],
        "bytes": len(text),
    }


def parse_buckets(text):
    out = []
    for part in text.split(","):
        n, k, kt = (int(v) for v in part.split(":"))
        out.append((n, k, kt))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default="1024:32:64,4096:32:64",
                    help="comma-separated N:K:Kt shape buckets")
    ap.add_argument("--rhs", type=int, default=8,
                    help="RHS batch width for cg_solve artifacts")
    ap.add_argument("--iters", type=int, default=model.DEFAULT_CG_ITERS,
                    help="fixed CG iteration budget")
    ap.add_argument("--dense-n", default="256",
                    help="comma-separated N for dense baseline artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="single tiny bucket (CI smoke)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    if args.quick:
        buckets = [(256, 16, 32)]
        dense_ns = [128]
    else:
        buckets = parse_buckets(args.buckets)
        dense_ns = [int(v) for v in args.dense_n.split(",") if v]

    manifest = {
        "format": "hlo-text/return-tuple",
        "cg_iters": args.iters,
        "rhs": args.rhs,
        "dense_expm": {
            "squarings": model.DENSE_EXPM_SQUARINGS,
            "taylor_order": model.DENSE_EXPM_ORDER,
            "max_beta_lap_inf_norm": float(2 ** model.DENSE_EXPM_SQUARINGS),
        },
        "artifacts": [],
    }

    for (n, k, kt) in buckets:
        for name, fn, eps in entry_points(n, k, kt, args.rhs, args.iters):
            print(f"lowering {name} ...", flush=True)
            entry = lower_one(name, fn, eps, args.out_dir)
            entry.update({"n": n, "k": k, "kt": kt, "iters": args.iters,
                          "kind": name.split("_n")[0]})
            manifest["artifacts"].append(entry)
    for n in dense_ns:
        for name, fn, eps in dense_entry_points(n):
            print(f"lowering {name} ...", flush=True)
            entry = lower_one(name, fn, eps, args.out_dir)
            entry.update({"n": n, "kind": "dense_diffusion"})
            manifest["artifacts"].append(entry)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
