//! Bench for Figure 4: BO regret on the three benchmark families at
//! reduced sizes (full: `grfgp exp bo-synthetic / bo-social / bo-wind`).

use grfgp::exp::bo;
use grfgp::util::cli::Args;

fn main() {
    println!("== fig4_bo bench (reduced; full: grfgp exp bo-*) ==");
    let args = Args::parse(
        [
            "exp",
            "--side",
            "30",
            "--ring-n",
            "5000",
            "--seeds",
            "2",
            "--n-steps",
            "60",
            "--n-init",
            "15",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    bo::run_synthetic(&args);
    let social_args = Args::parse(
        ["exp", "--scale", "0.01", "--seeds", "2", "--n-steps", "80"]
            .iter()
            .map(|s| s.to_string()),
    );
    bo::run_social(&social_args);
    let wind_args = Args::parse(
        ["exp", "--res-deg", "10", "--seeds", "2", "--n-steps", "60"]
            .iter()
            .map(|s| s.to_string()),
    );
    bo::run_wind(&wind_args);
}
