//! Bench for Table 1 / Figure 2: sparse-vs-dense end-to-end pipeline
//! timings at doubling sizes (a fast, fixed-seed excerpt of
//! `grfgp exp scaling`; the full sweep with exponent fits lives there).

use grfgp::exp::scaling;
use grfgp::util::cli::Args;

fn main() {
    println!("== table1_scaling bench (excerpt; full sweep: grfgp exp scaling) ==");
    let args = Args::parse(
        [
            "exp",
            "--sparse-pows",
            "8,9,10,11,12",
            "--dense-pows",
            "8,9,10",
            "--seeds",
            "2",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    scaling::run(&args);
}
