//! Micro-benchmarks of the L3 hot paths, used by the performance pass
//! (EXPERIMENTS.md §Perf): sparse matvec, gram matvec, CG solve, walk
//! engine, and modulation recombination.

use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::sparse::ops::GramOperator;
use grfgp::util::bench::bench;
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, WalkConfig};

fn main() {
    let mut rng = Rng::new(0);
    println!("== hotpath microbenches ==");

    for &n in &[16_384usize, 131_072] {
        let g = generators::ring(n);
        let cfg = WalkConfig { n_walks: 100, p_halt: 0.1, max_len: 3, ..Default::default() };
        let comps = sample_components(&g, &cfg, 1);

        bench(&format!("walk_engine/n={n}"), 1, 5, || {
            sample_components(&g, &cfg, 2)
        });

        let mut prepared = comps.prepare();
        let f = vec![1.0, 0.5, 0.25, 0.12];
        bench(&format!("combine/n={n}"), 1, 10, || {
            prepared.combine_into(&f).nnz()
        });

        let phi = prepared.combine_into(&f).clone();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        bench(&format!("spmv/n={n}"), 2, 20, || phi.matvec(&x));
        bench(&format!("spmv_par/n={n}"), 2, 20, || phi.matvec_par(&x, 0));

        let mut op = GramOperator::new(phi.clone(), 0.1);
        bench(&format!("gram_matvec/n={n}"), 2, 20, || op.apply(&x));

        // Full CG solve through the model (the paper's O(N^{3/2}) op).
        let train: Vec<usize> = (0..n).step_by(2).collect();
        let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.01).sin()).collect();
        let model = GpModel::new(
            comps.clone(),
            Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1),
            &train,
            &y,
        );
        let rhs: Vec<f64> = model
            .mask
            .iter()
            .zip(&model.y)
            .map(|(m, v)| m * v)
            .collect();
        bench(&format!("cg_solve/n={n}"), 1, 10, || {
            model.solve_system(&rhs).1.iterations
        });
        bench(&format!("posterior_sample/n={n}"), 1, 10, || {
            model.posterior_sample(&mut rng)
        });
    }
}
