//! Micro-benchmarks of the L3 hot paths, used by the performance pass
//! (EXPERIMENTS.md §Perf): sparse matvec/SpMM (CSR vs native ELL, f64
//! vs f32 values), gram matvec, single and block CG, the walk engine,
//! modulation recombination, and the end-to-end multi-RHS paths
//! (`lml_grad`, `predict`) under each operand layout.
//!
//! Besides the human-readable table, the run writes
//! `BENCH_hotpath.json` — the machine-readable `BenchRow` schema
//! `[{"name", "n", "b", "ns_per_op"}, ...]` (pinned by a tier-1 test
//! in `util::bench`) — so the perf trajectory of the blocked/ELL
//! solver paths is tracked across PRs. The headline comparison is
//! `csr_spmm` (f64 CSR) vs `ell_spmm` (f64 ELL) vs `ell_spmm_f32`
//! (f32 values, f64 accumulators — half the value traffic), and the
//! same contrast on the blocked `predict`/`lml_grad` solves via
//! `*_csr` vs `*_ell_f32`.
//!
//! Row-name continuity vs the PR 1 schema: `spmm`/`spmm_par` are now
//! `csr_spmm`/`csr_spmm_par`, and `lml_grad`/`predict` (which ran the
//! then-only CSR operator) continue as `lml_grad_csr`/`predict_csr`;
//! splice those series when reading the trajectory across PRs.
//!
//! PR 3 additions (streaming + end-task rows):
//! * `stream_full_rebuild` vs `stream_delta` — full walk resample +
//!   feature build against one single-edge incremental update
//!   (`StreamingFeatures::apply_delta`); `stream_delta_model` is the
//!   model-level path (`GpModel::apply_graph_delta`: feature patch +
//!   operator refresh + warm re-solve).
//! * `stream_delta_solve_{warm,cold}_iters` — post-delta block-CG
//!   iteration counts; these rows carry the **count in the `b`
//!   column** (ns_per_op 0).
//! * `metric_*` rows — dimensionless end-task values in `ns_per_op`
//!   (EllF32 LML-gradient deviation, final BO regret per layout), the
//!   data behind the ROADMAP "f32-by-default" decision.
//!
//! PR 4 additions: `stream_delta_batch` vs `stream_delta_sequential` —
//! 64 hub-incident edge deltas on a power-law (Barabási–Albert) graph
//! through `StreamingFeatures::apply_delta_batch` (one union
//! invalidation + parallel resample) vs 64 single-delta applies. Set
//! `HOTPATH_PROFILE=quick` for the small-size CI profile (same schema).
//!
//! PR 5 additions: `model_delta_batch_overlay` vs
//! `model_delta_batch_memcpy` — the same K-delta model-level batch
//! with patches staged in the Φ/Φᵀ/feature row-store overlays
//! (sub-linear, the default) vs compacted back to base CSRs after
//! every batch (the old per-batch O(total nnz) memcpy profile). The
//! `BENCH_hotpath.json` trajectory is now **enforced**: CI gates each
//! run against the committed `BENCH_baseline.json` via
//! `src/bin/bench_gate.rs` (median-normalised, >1.5× slowdown of any
//! matched row fails the workflow).
//!
//! PR 6 additions: `wire_decode` vs `wire_decode_garbage` — the
//! serving edge's bounded streaming frame decoder (`server::wire`) on
//! a batch of well-formed predict frames vs a hostile mix of binary
//! junk and frame-cap bombs; `n` carries the frame count and
//! `ns_per_op` is the whole-batch decode time.
//!
//! PR 7 additions: `server_predict_throughput` (per-request wall time,
//! 4 concurrent predict clients against a live TCP server) and
//! `server_mixed_p99` (p99 predict latency under a concurrent
//! edge-toggling writer) — the end-to-end rows for the snapshot-based
//! wait-free read path; both run in the quick CI profile.
//!
//! PR 8 additions (telemetry): `telemetry_overhead` /
//! `telemetry_overhead_disabled` — per-record cost of one registry
//! histogram record (enabled: two relaxed atomic adds; disabled: one
//! relaxed load), the price every instrumented hot path pays;
//! `metrics_scrape` — one full `{"op":"metrics"}` export (JSON +
//! Prometheus text) over the whole catalogue; and
//! `metric_grf_variance_iid` — the mean per-entry kernel-estimate
//! variance across independent walk seeds
//! (`walks::kernel_variance_iid`, also published as the registry gauge
//! of the same name). All run in the quick CI profile.
//!
//! PR 9 additions (sharding): `stream_delta_batch_sharded` — the PR 4
//! K-delta roundtrip through the partitioned engine
//! (`shard::ShardedFeatures`, S=4 workers resampling their owned walks
//! in parallel), contrasted against the mono `stream_delta_batch`; and
//! `server_predict_throughput_sharded` — the PR 7 four-client predict
//! hammer against a `--shards 2` server (wait-free reads either way,
//! so this row tracks its mono twin). Both run in the quick CI
//! profile and flow through the bench gate like any other row.
//!
//! PR 10 additions (termination schemes): the single
//! `metric_grf_variance_iid` row became a four-row family —
//! `metric_grf_variance_{iid,antithetic,qmc}` at an identical walk
//! budget and seed set (the correlated schemes should land strictly
//! below iid), plus `metric_grf_variance_qmc_half_walks` (QMC at half
//! the walks, expected to land near the iid row — fewer walks for the
//! same error). All metric rows run in the quick CI profile and are
//! never gated.

use grfgp::bo::{run_policy, BoConfig, ThompsonPolicy};
use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::server::wire::{WireConfig, WireDecoder};
use grfgp::shard::ShardedFeatures;
use grfgp::sparse::ops::GramOperator;
use grfgp::sparse::FeatureLayout;
use grfgp::stream::{GraphDelta, StreamingFeatures};
use grfgp::util::bench::{bench, write_rows_json, BenchRow};
use grfgp::util::parallel::num_threads;
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, Termination, WalkConfig};

/// Serial multi-RHS reference: what `lml_grad`'s solve phase cost
/// before the blocked path — one independent CG run per RHS.
fn serial_solves(model: &GpModel, rhs: &[Vec<f64>]) -> usize {
    let mut iters = 0;
    for b in rhs {
        iters += model.solve_system(b).1.iterations;
    }
    iters
}

fn main() {
    let mut rng = Rng::new(0);
    let threads = num_threads();
    let mut rows: Vec<BenchRow> = Vec::new();
    // HOTPATH_PROFILE=quick: small sizes for the CI perf-trajectory
    // profile (same row schema, minutes not tens of minutes).
    let quick = std::env::var("HOTPATH_PROFILE")
        .map(|v| v == "quick")
        .unwrap_or(false);
    let sizes: &[usize] = if quick { &[4096] } else { &[16_384, 131_072] };
    println!("== hotpath microbenches (threads={threads}, quick={quick}) ==");

    for &n in sizes {
        let g = generators::ring(n);
        let cfg = WalkConfig { n_walks: 100, p_halt: 0.1, max_len: 3, ..Default::default() };
        let comps = sample_components(&g, &cfg, 1);

        let r = bench(&format!("walk_engine/n={n}"), 1, 5, || {
            sample_components(&g, &cfg, 2)
        });
        rows.push(BenchRow::new("walk_engine", n, 1, r.mean_s));

        let mut prepared = comps.prepare();
        let f = vec![1.0, 0.5, 0.25, 0.12];
        let r = bench(&format!("combine/n={n}"), 1, 10, || {
            prepared.combine_into(&f).nnz()
        });
        rows.push(BenchRow::new("combine", n, 1, r.mean_s));

        let phi = prepared.combine_into(&f).clone();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let r = bench(&format!("spmv/n={n}"), 2, 20, || phi.matvec(&x));
        rows.push(BenchRow::new("spmv", n, 1, r.mean_s));
        let r = bench(&format!("spmv_par/n={n}"), 2, 20, || {
            phi.matvec_par(&x, threads)
        });
        rows.push(BenchRow::new("spmv_par", n, 1, r.mean_s));

        let r = bench(&format!("transpose/n={n}"), 1, 10, || phi.transpose());
        rows.push(BenchRow::new("transpose", n, 1, r.mean_s));
        let r = bench(&format!("transpose_par/n={n}"), 1, 10, || {
            phi.transpose_par(threads)
        });
        rows.push(BenchRow::new("transpose_par", n, 1, r.mean_s));

        // The feature-build row-width stats that drive the ELL layout
        // decision, plus the ELL operands themselves: f64 (bit-identical
        // to CSR) and f32 values (half the value traffic).
        let st = phi.row_width_stats();
        let width = phi.ell_auto_width();
        let mut ell = phi.to_ell(width, false);
        println!(
            "Φ row widths: mean {:.2}, max {}, nnz {} -> ELL width {} \
             (pad ratio {:.2}, spill {} nnz)",
            st.mean,
            st.max,
            st.nnz,
            width,
            st.pad_ratio(width),
            ell.spill_nnz()
        );

        // SpMM: one pass over Φ feeding B right-hand sides, vs B SpMVs,
        // across layouts. All three kernels produce the same per-column
        // accumulation order, so this is a pure memory-layout contrast.
        for &b in &[8usize, 16] {
            let xb: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let mut yb = vec![0.0; n * b];
            let r = bench(&format!("csr_spmm/n={n}/B={b}"), 2, 10, || {
                phi.matmat_into(&xb, b, &mut yb);
                yb[0]
            });
            rows.push(BenchRow::new("csr_spmm", n, b, r.mean_s));
            let r = bench(&format!("csr_spmm_par/n={n}/B={b}"), 2, 10, || {
                phi.matmat_par_into(&xb, b, &mut yb, threads);
                yb[0]
            });
            rows.push(BenchRow::new("csr_spmm_par", n, b, r.mean_s));

            ell.set_use_f32(false);
            let r = bench(&format!("ell_spmm/n={n}/B={b}"), 2, 10, || {
                ell.matmat_into(&xb, b, &mut yb);
                yb[0]
            });
            rows.push(BenchRow::new("ell_spmm", n, b, r.mean_s));
            let r = bench(&format!("ell_spmm_par/n={n}/B={b}"), 2, 10, || {
                ell.matmat_par_into(&xb, b, &mut yb, threads);
                yb[0]
            });
            rows.push(BenchRow::new("ell_spmm_par", n, b, r.mean_s));

            ell.set_use_f32(true);
            let r = bench(&format!("ell_spmm_f32/n={n}/B={b}"), 2, 10, || {
                ell.matmat_into(&xb, b, &mut yb);
                yb[0]
            });
            rows.push(BenchRow::new("ell_spmm_f32", n, b, r.mean_s));
            let r = bench(&format!("ell_spmm_f32_par/n={n}/B={b}"), 2, 10, || {
                ell.matmat_par_into(&xb, b, &mut yb, threads);
                yb[0]
            });
            rows.push(BenchRow::new("ell_spmm_f32_par", n, b, r.mean_s));

            // Columns pre-extracted outside the timed closure so the
            // baseline measures B passes of matrix traffic, not the
            // gather; each SpMV still allocates its result, as the
            // legacy per-RHS path did.
            let x_cols: Vec<Vec<f64>> = (0..b)
                .map(|j| (0..n).map(|i| xb[i * b + j]).collect())
                .collect();
            let r = bench(&format!("spmv_xB/n={n}/B={b}"), 2, 10, || {
                let mut acc = 0.0;
                for xj in &x_cols {
                    acc += phi.matvec(xj)[0];
                }
                acc
            });
            rows.push(BenchRow::new("spmv_xB", n, b, r.mean_s));
        }

        let mut op = GramOperator::new(phi.clone(), 0.1);
        println!("gram operator layout: {}", op.layout_desc());
        let r = bench(&format!("gram_matvec/n={n}"), 2, 20, || op.apply(&x));
        rows.push(BenchRow::new("gram_matvec", n, 1, r.mean_s));

        // Full CG solve through the model (the paper's O(N^{3/2}) op).
        let train: Vec<usize> = (0..n).step_by(2).collect();
        let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.01).sin()).collect();
        let mut model = GpModel::new(
            comps.clone(),
            Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1),
            &train,
            &y,
        );
        let rhs: Vec<f64> = model
            .mask
            .iter()
            .zip(&model.y)
            .map(|(m, v)| m * v)
            .collect();
        let r = bench(&format!("cg_solve/n={n}"), 1, 10, || {
            model.solve_system(&rhs).1.iterations
        });
        rows.push(BenchRow::new("cg_solve", n, 1, r.mean_s));

        // Multi-RHS solve: S+1 = 9 systems (training-step shape),
        // blocked vs the legacy serial loop.
        let n_rhs = 9;
        let mut probe_rng = Rng::new(5);
        let rhs_vecs: Vec<Vec<f64>> = (0..n_rhs)
            .map(|j| {
                if j == 0 {
                    rhs.clone()
                } else {
                    model
                        .mask
                        .iter()
                        .map(|&m| if m == 1.0 { probe_rng.normal() } else { 0.0 })
                        .collect()
                }
            })
            .collect();
        let mut rhs_block = vec![0.0; n * n_rhs];
        for (j, b) in rhs_vecs.iter().enumerate() {
            for i in 0..n {
                rhs_block[i * n_rhs + j] = b[i];
            }
        }
        let r = bench(&format!("block_cg/n={n}/B={n_rhs}"), 1, 5, || {
            let (_, stats) = model.solve_system_block(&rhs_block, n_rhs);
            stats.iter().map(|s| s.iterations).sum::<usize>()
        });
        rows.push(BenchRow::new("block_cg", n, n_rhs, r.mean_s));
        let r = bench(&format!("cg_serial_loop/n={n}/B={n_rhs}"), 1, 5, || {
            serial_solves(&model, &rhs_vecs)
        });
        rows.push(BenchRow::new("cg_serial_loop", n, n_rhs, r.mean_s));

        // End-to-end multi-RHS paths under each operand layout: the
        // blocked solves dominate both, so `*_ell_f32` vs `*_csr` is
        // the headline bandwidth win of the f32 ELL path.
        let n_samples = 16;
        for (tag, layout) in [
            ("csr", FeatureLayout::Csr),
            ("ell", FeatureLayout::Ell),
            ("ell_f32", FeatureLayout::EllF32),
        ] {
            model.solve.layout = layout;
            let r = bench(&format!("lml_grad_{tag}/n={n}/S=8"), 1, 5, || {
                let mut step_rng = Rng::new(3);
                model.lml_grad(&mut step_rng).1.cg_iters
            });
            rows.push(BenchRow::new(&format!("lml_grad_{tag}"), n, 9, r.mean_s));
            let r = bench(&format!("predict_{tag}/n={n}/B={n_samples}"), 1, 3, || {
                let mut p_rng = Rng::new(7);
                model.predict(n_samples, &mut p_rng).1[0]
            });
            rows.push(BenchRow::new(&format!("predict_{tag}"), n, n_samples, r.mean_s));
        }

        // Legacy serial-draw prediction baseline (per-sample solves).
        model.solve.layout = FeatureLayout::Auto;
        let r = bench(&format!("predict_serial/n={n}/B={n_samples}"), 1, 3, || {
            let mut p_rng = Rng::new(7);
            let (_, st) = model.posterior_mean();
            let mut acc = st.iterations as f64;
            for _ in 0..n_samples {
                acc += model.posterior_sample(&mut p_rng)[0];
            }
            acc
        });
        rows.push(BenchRow::new("predict_serial", n, n_samples, r.mean_s));

        // --- Streaming graph deltas: incremental vs full rebuild ------
        // apply_delta is bit-identical to the full rebuild (property-
        // tested in `stream`); here we measure the wall-clock gap of a
        // single-edge update, which the visit index turns from
        // O(N·n_walks) walk work into O(visits at the endpoints).
        let fmod = vec![1.0, 0.5, 0.25, 0.12];
        let mut stream = StreamingFeatures::new(g.clone(), cfg.clone(), fmod.clone(), 11);
        let r = bench(&format!("stream_full_rebuild/n={n}"), 1, 3, || {
            StreamingFeatures::new(g.clone(), cfg.clone(), fmod.clone(), 11).n()
        });
        rows.push(BenchRow::new("stream_full_rebuild", n, 1, r.mean_s));
        let mut flip = 0usize;
        let r = bench(&format!("stream_delta/n={n}"), 2, 20, || {
            // Alternate add/remove of one chord: every rep is a
            // single-edge delta against the current graph.
            let (u, v) = (17usize, n / 2 + 17);
            let d = if flip % 2 == 0 {
                GraphDelta::AddEdge { u, v, w: 0.5 }
            } else {
                GraphDelta::RemoveEdge { u, v }
            };
            flip += 1;
            stream.apply_delta(&d).unwrap().resampled.len()
        });
        rows.push(BenchRow::new("stream_delta", n, 1, r.mean_s));

        // Model-level delta: feature-row patch + operator refresh +
        // warm-started post-delta solve, against a cold re-solve of the
        // same refreshed system.
        let mut model_s = GpModel::new(
            stream.components(),
            Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1),
            &train,
            &y,
        );
        let rhs_s: Vec<f64> = model_s
            .mask
            .iter()
            .zip(&model_s.y)
            .map(|(m, v)| m * v)
            .collect();
        let (alpha0, _) = model_s.solve_system_block(&rhs_s, 1);
        let t0 = std::time::Instant::now();
        let out = model_s
            .apply_graph_delta(
                &mut stream,
                &GraphDelta::AddEdge { u: 3, v: n / 3, w: 0.5 },
                Some(&alpha0),
            )
            .unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "stream_delta_model/n={n}: {:.3} ms ({} walks resampled, {} rows \
             patched), post-delta solve warm {} iters",
            1e3 * dt,
            out.resampled_walks,
            out.patched_rows,
            out.solve_stats.iterations
        );
        rows.push(BenchRow::new("stream_delta_model", n, 1, dt));
        let (_, st_cold) = model_s.solve_system_block(&rhs_s, 1);
        println!(
            "post-delta block-CG iterations: warm {} vs cold {}",
            out.solve_stats.iterations, st_cold[0].iterations
        );
        rows.push(BenchRow::new(
            "stream_delta_solve_warm_iters",
            n,
            out.solve_stats.iterations,
            0.0,
        ));
        rows.push(BenchRow::new(
            "stream_delta_solve_cold_iters",
            n,
            st_cold[0].iterations,
            0.0,
        ));

        // --- Batched deltas on a power-law graph -----------------------
        // 64 edge deltas incident to a handful of hubs: sequential
        // application resamples each hub's (large) visitor set once per
        // delta; the batch path resamples the union once, in parallel,
        // and rebuilds each affected row once. This is the acceptance
        // contrast for the batched delta engine (`apply_delta_batch` is
        // property-tested bit-identical to both paths).
        {
            let mut brng = Rng::new(42);
            let npl = (n / 4).max(2048);
            let gpl = generators::barabasi_albert(npl, 3, &mut brng);
            let cfgpl = WalkConfig {
                n_walks: 32,
                p_halt: 0.1,
                max_len: 3,
                ..Default::default()
            };
            let fpl = vec![1.0, 0.5, 0.25, 0.12];
            let mut by_deg: Vec<usize> = (0..npl).collect();
            by_deg.sort_by_key(|&i| std::cmp::Reverse(gpl.degree(i)));
            let k_deltas = 64usize;
            // Chords from 8 hubs to fresh non-neighbors: skipping
            // existing edges keeps the add/undo cycle a true roundtrip
            // (reinforcing an existing edge and then removing it would
            // permanently delete it from the measured graph).
            let mut vtx = npl / 2;
            let adds: Vec<GraphDelta> = (0..k_deltas)
                .map(|k| {
                    let u = by_deg[k % 8];
                    let mut v = vtx;
                    while gpl.has_edge(u, v) || v == u {
                        v += 1;
                    }
                    vtx = v + 1;
                    GraphDelta::AddEdge { u, v, w: 0.5 }
                })
                .collect();
            let undo: Vec<GraphDelta> = adds
                .iter()
                .rev()
                .map(|d| match *d {
                    GraphDelta::AddEdge { u, v, .. } => {
                        GraphDelta::RemoveEdge { u, v }
                    }
                    _ => unreachable!(),
                })
                .collect();
            let mut s_seq =
                StreamingFeatures::new(gpl.clone(), cfgpl.clone(), fpl.clone(), 33);
            let r = bench(
                &format!("stream_delta_sequential/n={npl}/K={k_deltas}"),
                1,
                3,
                || {
                    for d in adds.iter().chain(&undo) {
                        s_seq.apply_delta(d).unwrap();
                    }
                    s_seq.overlay_rows()
                },
            );
            let seq_s = r.mean_s;
            rows.push(BenchRow::new(
                "stream_delta_sequential",
                npl,
                k_deltas,
                seq_s,
            ));
            let mut s_bat =
                StreamingFeatures::new(gpl.clone(), cfgpl.clone(), fpl.clone(), 33);
            let r = bench(
                &format!("stream_delta_batch/n={npl}/K={k_deltas}"),
                1,
                3,
                || {
                    s_bat.apply_delta_batch(&adds).unwrap();
                    s_bat.apply_delta_batch(&undo).unwrap();
                    s_bat.overlay_rows()
                },
            );
            let bat_s = r.mean_s;
            rows.push(BenchRow::new("stream_delta_batch", npl, k_deltas, bat_s));
            println!(
                "stream delta batch speedup (n={npl}, {k_deltas} deltas): {:.1}x",
                seq_s / bat_s.max(1e-12)
            );

            // The same roundtrip through the partitioned engine (S=4
            // shard workers, each resampling only its owned walks and
            // patching only its own rows — bit-identical features,
            // property-tested in `shard`). The contrast vs
            // `stream_delta_batch` is the fan-out overhead / win of the
            // per-shard parallel resample.
            let n_shards = 4usize;
            let mut s_shard = ShardedFeatures::new(
                gpl.clone(),
                cfgpl.clone(),
                fpl,
                33,
                n_shards,
            );
            let r = bench(
                &format!(
                    "stream_delta_batch_sharded/n={npl}/K={k_deltas}/S={n_shards}"
                ),
                1,
                3,
                || {
                    s_shard.apply_delta_batch(&adds).unwrap();
                    s_shard.apply_delta_batch(&undo).unwrap();
                    s_shard.overlay_rows()
                },
            );
            rows.push(BenchRow::new(
                "stream_delta_batch_sharded",
                npl,
                k_deltas,
                r.mean_s,
            ));
            println!(
                "stream delta batch sharded (n={npl}, S={n_shards}): {:.2}x vs mono",
                bat_s / r.mean_s.max(1e-12)
            );
        }

        // --- Model-side delta patching: overlay vs per-batch memcpy ---
        // The same K-delta roundtrip batch through the full model path
        // (stream resample + feature patch + Φ/Φᵀ maintenance + a
        // short, iteration-capped re-solve), in two modes:
        // * `model_delta_batch_overlay` — the default sub-linear path:
        //   patches stay in the Φ/Φᵀ/feature row-store overlays, so
        //   the patch stage costs O(touched nnz);
        // * `model_delta_batch_memcpy` — `compact_model_overlays()`
        //   after every batch, restoring the pre-overlay cost profile
        //   (one O(total nnz) splice per operand per batch — a lower
        //   bound on the old clone+splice+build_maps path).
        // The deltas touch a fixed set of rows, so as n grows the
        // overlay row should stay ~flat (it tracks touched nnz plus
        // the O(n) solve vectors) while the memcpy row grows with
        // total feature nnz.
        {
            let k_deltas = 16usize;
            let adds: Vec<GraphDelta> = (0..k_deltas)
                .map(|k| GraphDelta::AddEdge {
                    u: (11 * k + 5) % 64,
                    v: ((11 * k + 5) % 64 + n / 2) % n,
                    w: 0.5,
                })
                .collect();
            let undo: Vec<GraphDelta> = adds
                .iter()
                .rev()
                .map(|d| match *d {
                    GraphDelta::AddEdge { u, v, .. } => {
                        GraphDelta::RemoveEdge { u, v }
                    }
                    _ => unreachable!(),
                })
                .collect();
            let fdm = vec![1.0, 0.5, 0.25, 0.12];
            let hy = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
            let mut run_mode = |tag: &str, compact_every_batch: bool| {
                let mut s =
                    StreamingFeatures::new(g.clone(), cfg.clone(), fdm.clone(), 19);
                s.set_compact_threshold(usize::MAX);
                let mut m = GpModel::new(s.components(), hy.clone(), &train, &y);
                m.solve.max_iters = 8; // bound the (identical) solve cost
                let r = bench(
                    &format!("model_delta_batch_{tag}/n={n}/K={k_deltas}"),
                    1,
                    5,
                    || {
                        let o1 =
                            m.apply_graph_delta_batch(&mut s, &adds, None).unwrap();
                        if compact_every_batch {
                            m.compact_model_overlays();
                        }
                        let o2 =
                            m.apply_graph_delta_batch(&mut s, &undo, None).unwrap();
                        if compact_every_batch {
                            m.compact_model_overlays();
                        }
                        o1.patched_rows + o2.patched_rows
                    },
                );
                rows.push(BenchRow::new(
                    &format!("model_delta_batch_{tag}"),
                    n,
                    k_deltas,
                    r.mean_s,
                ));
                r.mean_s
            };
            let overlay_s = run_mode("overlay", false);
            let memcpy_s = run_mode("memcpy", true);
            println!(
                "model delta patch overlay vs memcpy (n={n}, {k_deltas} deltas): \
                 {:.1}x",
                memcpy_s / overlay_s.max(1e-12)
            );
        }

        // --- End-task f32 metrics (ROADMAP: flip EllF32 by default?) --
        // Gated on the profile's first size so the quick CI profile
        // still emits the metric_* rows the trajectory tracks.
        if n == sizes[0] {
            // Relative L2 deviation of the stochastic LML gradient
            // under the f32-valued operator (same probe stream).
            model.solve.layout = FeatureLayout::Auto;
            let mut gr = Rng::new(3);
            let (g64, _) = model.lml_grad(&mut gr);
            model.solve.layout = FeatureLayout::EllF32;
            let mut gr = Rng::new(3);
            let (g32, _) = model.lml_grad(&mut gr);
            model.solve.layout = FeatureLayout::Auto;
            let num = g64
                .iter()
                .zip(&g32)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            let den = g64.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
            let dev = num / den;
            println!("metric_lml_grad_reldev_f32: {dev:.3e}");
            rows.push(BenchRow {
                name: "metric_lml_grad_reldev_f32".into(),
                n,
                b: 9,
                ns_per_op: dev,
            });

            // Short-horizon BO regret per layout: does the f32 operand
            // move the end-task result at all?
            let nb = 2048usize;
            let gb = generators::ring(nb);
            let h = move |i: usize| {
                let c = 0.37 * nb as f64;
                let mut d = (i as f64 - c).abs();
                d = d.min(nb as f64 - d);
                let w = 0.05 * nb as f64;
                (-d * d / (2.0 * w * w)).exp()
            };
            let bo_cfg = BoConfig {
                n_init: 10,
                n_steps: 25,
                noise: 0.01,
                walk: WalkConfig {
                    n_walks: 64,
                    max_len: 4,
                    threads: 1,
                    ..Default::default()
                },
                ..Default::default()
            };
            let optimum = (0..nb).map(h).fold(f64::MIN, f64::max);
            for (tag, layout) in [
                ("f64", FeatureLayout::Auto),
                ("ell_f32", FeatureLayout::EllF32),
            ] {
                let mut regret = 0.0;
                let seeds = 2u64;
                for seed in 0..seeds {
                    let mut brng = Rng::new(seed);
                    let mut p = ThompsonPolicy::new(&gb, &bo_cfg, &mut brng);
                    p.model_mut().solve.layout = layout;
                    let run = run_policy(&mut p, &h, optimum, nb, &bo_cfg, &mut brng);
                    regret += run.regret.last().unwrap() / seeds as f64;
                }
                println!("metric_bo_regret_{tag}: {regret:.4}");
                rows.push(BenchRow {
                    name: format!("metric_bo_regret_{tag}"),
                    n: nb,
                    b: 1,
                    ns_per_op: regret,
                });
            }
        }
    }

    // --- Wire decoder throughput (hardened serving edge) -------------
    // Per-frame cost of the serving edge's decode path: pre-rendered
    // predict frames streamed through the bounded decoder in 64 KiB
    // chunks (newline split + depth-capped parse). The garbage row
    // measures the rejection path — alternating binary junk and
    // frame-cap bombs — i.e. the cost of surviving a hostile client.
    {
        let n_frames = if quick { 4096 } else { 16_384 };
        let mut blob = Vec::new();
        for i in 0..n_frames {
            blob.extend_from_slice(
                format!(
                    "{{\"op\":\"predict\",\"nodes\":[{},{}],\"samples\":8}}\n",
                    i % 1024,
                    (i * 7) % 1024
                )
                .as_bytes(),
            );
        }
        let r = bench(&format!("wire_decode/F={n_frames}"), 1, 5, || {
            let mut dec = WireDecoder::new(WireConfig::default());
            let mut out = Vec::new();
            for chunk in blob.chunks(64 * 1024) {
                dec.feed(chunk, &mut out);
            }
            assert!(out.len() == n_frames && out.iter().all(|f| f.is_ok()));
            out.len()
        });
        rows.push(BenchRow::new("wire_decode", n_frames, 1, r.mean_s));

        let cap = 4096usize;
        let n_junk = if quick { 512 } else { 2048 };
        let mut junk = Vec::new();
        for i in 0..n_junk {
            if i % 2 == 0 {
                junk.extend_from_slice(b"\xff\xfe{[garbage\x00\n");
            } else {
                junk.resize(junk.len() + 2 * cap, b'[');
                junk.push(b'\n');
            }
        }
        let r = bench(&format!("wire_decode_garbage/F={n_junk}"), 1, 5, || {
            let mut dec = WireDecoder::new(WireConfig {
                max_frame_bytes: cap,
                ..Default::default()
            });
            let mut out = Vec::new();
            for chunk in junk.chunks(64 * 1024) {
                dec.feed(chunk, &mut out);
            }
            assert!(out.len() == n_junk && out.iter().all(|f| f.is_err()));
            out.len()
        });
        rows.push(BenchRow::new("wire_decode_garbage", n_junk, 1, r.mean_s));
    }

    // --- Serving path: wait-free predict reads ------------------------
    // End-to-end rows over a real TCP server (accept loop, wire
    // decoder, batcher, snapshot reads):
    // * `server_predict_throughput` — per-request wall time with 4
    //   concurrent predict clients hammering the published snapshot
    //   (`b` = client count, whole-run time / total requests);
    // * `server_mixed_p99` — p99 predict latency while a writer
    //   connection toggles an edge in a loop, i.e. reads racing the
    //   write path's publish cycle. Before the snapshot split, every
    //   one of these predicts queued behind the model mutex.
    {
        fn srv_call(
            s: &mut std::net::TcpStream,
            r: &mut std::io::BufReader<std::net::TcpStream>,
            body: &str,
        ) -> String {
            use std::io::{BufRead, Write};
            s.write_all(body.as_bytes()).unwrap();
            s.write_all(b"\n").unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(line.contains("\"ok\":true"), "server error: {line}");
            line
        }
        fn srv_connect(
            addr: std::net::SocketAddr,
        ) -> (std::net::TcpStream, std::io::BufReader<std::net::TcpStream>) {
            let s = std::net::TcpStream::connect(addr).unwrap();
            let r = std::io::BufReader::new(s.try_clone().unwrap());
            (s, r)
        }
        let ns = if quick { 2048 } else { 8192 };
        let g = generators::ring(ns);
        let wcfg = WalkConfig {
            n_walks: 32,
            p_halt: 0.1,
            max_len: 3,
            threads: 1,
            ..Default::default()
        };
        let hy = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
        let stream =
            StreamingFeatures::new(g, wcfg, hy.modulation.coeffs(), 0);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = std::thread::spawn(move || {
            grfgp::server::ServeOptions::new()
                .seed(7)
                .serve_on(stream, hy, listener)
                .unwrap();
        });
        let (mut s0, mut r0) = srv_connect(addr);
        for i in 0..16 {
            srv_call(
                &mut s0,
                &mut r0,
                &format!(
                    "{{\"op\":\"observe\",\"node\":{},\"y\":{}}}",
                    i * 37 % ns,
                    (i as f64 * 0.3).sin()
                ),
            );
        }

        let clients = 4usize;
        let per_client = if quick { 64 } else { 256 };
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|k| {
                std::thread::spawn(move || {
                    let (mut s, mut r) = srv_connect(addr);
                    for j in 0..per_client {
                        let a = (k * 31 + j * 7) % 2048;
                        srv_call(
                            &mut s,
                            &mut r,
                            &format!(
                                "{{\"op\":\"predict\",\"nodes\":[{},{}],\
                                 \"samples\":4}}",
                                a,
                                (a + 97) % 2048
                            ),
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = (clients * per_client) as f64;
        let per_req = t0.elapsed().as_secs_f64() / total;
        println!(
            "server_predict_throughput/n={ns}/C={clients}: {:.3} ms/req \
             ({:.0} req/s)",
            1e3 * per_req,
            1.0 / per_req
        );
        rows.push(BenchRow::new("server_predict_throughput", ns, clients, per_req));

        let stop = std::sync::Arc::new(
            std::sync::atomic::AtomicBool::new(false),
        );
        let stop_w = stop.clone();
        let writer = std::thread::spawn(move || {
            let (mut s, mut r) = srv_connect(addr);
            let mut flip = 0usize;
            while !stop_w.load(std::sync::atomic::Ordering::SeqCst) {
                let body = if flip % 2 == 0 {
                    "{\"op\":\"add_edge\",\"u\":13,\"v\":1037,\"w\":0.5}"
                } else {
                    "{\"op\":\"remove_edge\",\"u\":13,\"v\":1037}"
                };
                srv_call(&mut s, &mut r, body);
                flip += 1;
            }
            flip
        });
        let m = if quick { 200 } else { 500 };
        let mut lats = Vec::with_capacity(m);
        let (mut s1, mut r1) = srv_connect(addr);
        for j in 0..m {
            let a = (j * 13) % 2048;
            let t = std::time::Instant::now();
            srv_call(
                &mut s1,
                &mut r1,
                &format!(
                    "{{\"op\":\"predict\",\"nodes\":[{a}],\"samples\":4}}"
                ),
            );
            lats.push(t.elapsed().as_secs_f64());
        }
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let deltas = writer.join().unwrap();
        lats.sort_by(f64::total_cmp);
        let p99 = lats[(m * 99 / 100).min(m - 1)];
        println!(
            "server_mixed_p99/n={ns}: {:.3} ms (median {:.3} ms, {} deltas \
             applied concurrently)",
            1e3 * p99,
            1e3 * lats[m / 2],
            deltas
        );
        rows.push(BenchRow::new("server_mixed_p99", ns, 1, p99));
        srv_call(&mut s0, &mut r0, "{\"op\":\"shutdown\"}");
        srv.join().unwrap();

        // The same 4-client predict hammer against a server running the
        // partitioned engine (`--shards 2`). Reads are wait-free in
        // both modes (snapshot loads, zero model locks), so this row
        // should track `server_predict_throughput`; a regression here
        // is sharded-operand kernel overhead on the read path.
        let g2 = generators::ring(ns);
        let wcfg2 = WalkConfig {
            n_walks: 32,
            p_halt: 0.1,
            max_len: 3,
            threads: 1,
            ..Default::default()
        };
        let hy2 = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
        let stream2 =
            StreamingFeatures::new(g2, wcfg2, hy2.modulation.coeffs(), 0);
        let listener2 = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr2 = listener2.local_addr().unwrap();
        let srv2 = std::thread::spawn(move || {
            grfgp::server::ServeOptions::new()
                .shards(2)
                .seed(7)
                .serve_on(stream2, hy2, listener2)
                .unwrap();
        });
        let (mut s2, mut r2) = srv_connect(addr2);
        for i in 0..16 {
            srv_call(
                &mut s2,
                &mut r2,
                &format!(
                    "{{\"op\":\"observe\",\"node\":{},\"y\":{}}}",
                    i * 37 % ns,
                    (i as f64 * 0.3).sin()
                ),
            );
        }
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|k| {
                std::thread::spawn(move || {
                    let (mut s, mut r) = srv_connect(addr2);
                    for j in 0..per_client {
                        let a = (k * 31 + j * 7) % 2048;
                        srv_call(
                            &mut s,
                            &mut r,
                            &format!(
                                "{{\"op\":\"predict\",\"nodes\":[{},{}],\
                                 \"samples\":4}}",
                                a,
                                (a + 97) % 2048
                            ),
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let per_req_sharded = t0.elapsed().as_secs_f64() / total;
        println!(
            "server_predict_throughput_sharded/n={ns}/C={clients}/S=2: \
             {:.3} ms/req ({:.0} req/s)",
            1e3 * per_req_sharded,
            1.0 / per_req_sharded
        );
        rows.push(BenchRow::new(
            "server_predict_throughput_sharded",
            ns,
            clients,
            per_req_sharded,
        ));
        srv_call(&mut s2, &mut r2, "{\"op\":\"shutdown\"}");
        srv2.join().unwrap();
    }

    // --- Telemetry: record-path cost + scrape cost --------------------
    // The record path is two relaxed fetch_adds on static atomics (no
    // locks, no allocation — the zero-allocation claim is asserted by a
    // counting global allocator in tests/obs.rs); the disabled path is
    // a single relaxed load. Row value = per-record nanoseconds.
    {
        use grfgp::obs;
        let iters = 1_000_000usize;
        obs::set_enabled(true);
        let r = bench(&format!("telemetry_record_on/I={iters}"), 1, 5, || {
            for i in 0..iters {
                obs::registry::STOPWATCH_NS.record((i & 0xFFFF) as u64);
            }
            obs::registry::STOPWATCH_NS.count()
        });
        rows.push(BenchRow::new(
            "telemetry_overhead",
            iters,
            1,
            r.mean_s / iters as f64,
        ));
        obs::set_enabled(false);
        let r = bench(&format!("telemetry_record_off/I={iters}"), 1, 5, || {
            for i in 0..iters {
                obs::registry::STOPWATCH_NS.record((i & 0xFFFF) as u64);
            }
            obs::registry::STOPWATCH_NS.count()
        });
        obs::set_enabled(true);
        rows.push(BenchRow::new(
            "telemetry_overhead_disabled",
            iters,
            1,
            r.mean_s / iters as f64,
        ));

        // One full wire scrape: JSON export + Prometheus rendering of
        // the entire catalogue (what a `{"op":"metrics"}` request costs
        // the server, minus socket IO).
        let r = bench("metrics_scrape", 1, 10, || {
            obs::registry::to_json().to_string().len()
                + obs::prom::render().len()
        });
        rows.push(BenchRow::new("metrics_scrape", 1, 1, r.mean_s));
    }

    // --- GRF estimator quality: variance across walk seeds ------------
    // Mean per-entry variance of K̂ = Φ Φᵀ across independent walk
    // seeds, one row per walk-termination scheme (each also published
    // as its `grf_variance_*` registry gauge). `metric_*` convention:
    // dimensionless value in ns_per_op, never gated. The config keeps
    // the walk-length distribution termination-sensitive (p_halt 0.2,
    // max_len 5: survival to the cap ≈ 0.33, not ≈ 1) so the
    // correlated schemes have tail mass to cancel — antithetic and qmc
    // should land strictly below iid at the identical walk budget, and
    // `..._qmc_half_walks` (n_walks 16 vs 32) should land near the iid
    // row: the "half the walks for the same error" headline.
    {
        let nv = 1024usize;
        let gv = generators::ring(nv);
        let coeffs = vec![1.0, 0.5, 0.25, 0.12, 0.06, 0.03];
        let seeds = [101u64, 102, 103];
        let vcfg = |termination, n_walks| WalkConfig {
            n_walks,
            p_halt: 0.2,
            max_len: 5,
            termination,
            ..Default::default()
        };
        let schemes = [
            ("metric_grf_variance_iid", Termination::Iid, 32usize),
            ("metric_grf_variance_antithetic", Termination::Antithetic, 32),
            ("metric_grf_variance_qmc", Termination::Qmc, 32),
            ("metric_grf_variance_qmc_half_walks", Termination::Qmc, 16),
        ];
        for (name, termination, n_walks) in schemes {
            let var = grfgp::walks::kernel_variance(
                &gv,
                &vcfg(termination, n_walks),
                &coeffs,
                &seeds,
                64,
                9,
            );
            println!(
                "{name}: {var:.3e} (n={nv}, walks={n_walks}, {} seeds)",
                seeds.len()
            );
            rows.push(BenchRow {
                name: name.into(),
                n: nv,
                b: n_walks,
                ns_per_op: var,
            });
        }
    }

    // Machine-readable record for cross-PR perf tracking.
    match write_rows_json("BENCH_hotpath.json", &rows) {
        Ok(()) => println!("wrote BENCH_hotpath.json ({} entries)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}
