//! Bench for Table 5 / Figure 5: the importance-sampling ablation at a
//! reduced walk budget (full version: `grfgp exp ablation`).

use grfgp::exp::ablation;
use grfgp::util::cli::Args;

fn main() {
    println!("== table5_ablation bench (reduced; full: grfgp exp ablation) ==");
    let args = Args::parse(
        [
            "exp",
            "--side",
            "20",
            "--walks",
            "500",
            "--train-iters",
            "60",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    ablation::run(&args);
}
