//! Bench: PJRT artifact path (L1 Pallas interpret + L2 JAX, compiled by
//! XLA) vs the native Rust engine on the same operations. Skipped when
//! `artifacts/` is missing.

use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::runtime::Runtime;
use grfgp::util::bench::bench;
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, WalkConfig};
use std::path::Path;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(rt) = Runtime::load(&dir) else {
        println!("SKIP pjrt_vs_native: no artifacts (run `make artifacts`)");
        return;
    };
    println!("== pjrt_vs_native bench (platform: {}) ==", rt.platform());

    let g = generators::grid2d(10, 10);
    let cfg = WalkConfig { n_walks: 24, max_len: 3, threads: 1, ..Default::default() };
    let comps = sample_components(&g, &cfg, 1);
    let mut rng = Rng::new(0);
    let train: Vec<usize> = rng.sample_without_replacement(100, 40);
    let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.17).sin()).collect();
    let model = GpModel::new(
        comps,
        Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.25),
        &train,
        &y,
    );
    let phi = model.features.current();
    let ell = phi.to_ell_artifact(phi.max_row_nnz()).unwrap();
    let phi_t = phi.transpose();
    let ell_t = phi_t.to_ell_artifact(phi_t.max_row_nnz()).unwrap();
    let n = model.n();
    let x64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let mask32: Vec<f32> = model.mask.iter().map(|&m| m as f32).collect();
    let y32: Vec<f32> = model.y.iter().map(|&v| v as f32).collect();

    bench("native/gram_matvec n=100", 3, 50, || {
        model.apply_kernel(&x64)
    });
    bench("pjrt/gram_matvec n=100 (bucket 256)", 3, 50, || {
        rt.gram_matvec(&ell, &ell_t, &x32, 0.25).unwrap()
    });
    let rhs64: Vec<f64> = model
        .mask
        .iter()
        .zip(&model.y)
        .map(|(m, v)| m * v)
        .collect();
    bench("native/cg_solve n=100", 2, 20, || {
        model.solve_system(&rhs64).1.iterations
    });
    let rhs32: Vec<f32> = rhs64.iter().map(|&v| v as f32).collect();
    bench("pjrt/cg_solve n=100 (32 iters, 8 rhs)", 2, 20, || {
        rt.cg_solve(&ell, &ell_t, &mask32, &[rhs32.clone()], 0.25).unwrap()
    });
    bench("pjrt/posterior_mean n=100", 2, 20, || {
        rt.posterior_mean(&ell, &ell_t, &mask32, &y32, 0.25).unwrap()
    });
}
