//! Bench for Table 7: Cora classification at reduced scale
//! (full: `grfgp exp classify --scale 1.0`).

use grfgp::exp::classify;
use grfgp::util::cli::Args;

fn main() {
    println!("== table7_classification bench (reduced; full: grfgp exp classify) ==");
    let args = Args::parse(
        [
            "exp",
            "--scale",
            "0.25",
            "--seeds",
            "2",
            "--train-iters",
            "80",
            "--walks",
            "256",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    classify::run(&args);
}
