//! Bench for Figure 3: traffic + wind regression sweeps at reduced
//! budgets (full versions: `grfgp exp traffic` / `grfgp exp wind`).

use grfgp::exp::regression;
use grfgp::util::cli::Args;

fn main() {
    println!("== fig3_regression bench (reduced; full: grfgp exp traffic/wind) ==");
    let args = Args::parse(
        [
            "exp",
            "--walk-counts",
            "16,128",
            "--seeds",
            "1",
            "--train-iters",
            "30",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    regression::run_traffic(&args);
    let wind_args = Args::parse(
        [
            "exp",
            "--walk-counts",
            "16,64",
            "--seeds",
            "1",
            "--res-deg",
            "10",
            "--train-iters",
            "20",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    regression::run_wind(&wind_args);
}
