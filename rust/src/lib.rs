//! # grfgp — Graph Random Features for Scalable Gaussian Processes
//!
//! Production-quality reproduction of *"Graph Random Features for
//! Scalable Gaussian Processes"* (Zhang et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: graph substrate, the
//!   GRF random-walk engine, sparse/dense linear algebra, the iterative
//!   GP workflow (LML training, pathwise-conditioning inference),
//!   Thompson-sampling Bayesian optimisation, variational
//!   classification, a batching inference server, and the experiment
//!   drivers regenerating every table/figure in the paper.
//! * **Layer 2** — `python/compile/model.py`: the GP compute graphs in
//!   JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels (ELL SpMV,
//!   blocked matmul) called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts and executes them via
//! PJRT; Python never runs on the request path.

pub mod bo;
pub mod datasets;
pub mod exp;
pub mod gp;
pub mod graph;
pub mod linalg;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod shard;
pub mod sparse;
pub mod stream;
pub mod util;
pub mod vgp;
pub mod walks;
