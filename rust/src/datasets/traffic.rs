//! San Jose traffic substitute (paper App. C.4).
//!
//! Paper: PeMS San Jose freeway sensor network + OpenStreetMap — 1,016
//! nodes, 1,173 edges, 325 sensors (250 train / 75 test), speeds
//! normalised to zero mean / unit variance.
//!
//! Substitute: a planar road network (jittered grid + freeway spines,
//! see `graph::generators::road_network`) with speeds sampled from a
//! diffusion-kernel GP on the *graph* plus road-class offsets (freeways
//! fast, side streets slow). This preserves the property that motivates
//! graph kernels in the first place: spatially adjacent but unconnected
//! lanes can carry very different speeds.

use super::RegressionData;
use crate::graph::generators::road_network;
use crate::linalg::chol::Cholesky;
use crate::linalg::expm::diffusion_kernel;
use crate::linalg::Mat;
use crate::util::rng::Rng;

pub const PAPER_NODES: usize = 1016;
pub const PAPER_EDGES: usize = 1173;
pub const PAPER_SENSORS: usize = 325;
pub const PAPER_TRAIN: usize = 250;
pub const PAPER_TEST: usize = 75;

/// Generate the traffic dataset: graph + GP-smooth speed field +
/// sensor subset split 250/75 as in the paper.
pub fn generate(rng: &mut Rng) -> RegressionData {
    let (graph, _pos, class) = road_network(PAPER_NODES, PAPER_EDGES, rng);
    let n = graph.num_nodes();

    // Ground-truth speeds: diffusion-GP sample on the graph (beta=8
    // gives multi-hop correlation lengths) + road-class offset that is
    // smoothed over the graph (ramps transition gradually) + noise.
    let l = Mat::from_rows(&graph.dense_laplacian());
    let mut k = diffusion_kernel(&l, 8.0, 1.0);
    k.add_diag(1e-6);
    let ch = Cholesky::new(&k).expect("diffusion kernel PSD");
    let u = rng.normal_vec(n);
    let gp = ch.sample(&u);
    // Class base field, diffused by 4 rounds of neighbour averaging.
    let mut base: Vec<f64> =
        class.iter().map(|&c| if c == 1 { 65.0 } else { 35.0 }).collect();
    for _ in 0..4 {
        let mut next = base.clone();
        for (i, nb) in next.iter_mut().enumerate() {
            let d = graph.degree(i);
            if d > 0 {
                let s: f64 = graph
                    .neighbors(i)
                    .iter()
                    .map(|&j| base[j as usize])
                    .sum();
                *nb = 0.5 * base[i] + 0.5 * s / d as f64;
            }
        }
        base = next;
    }
    // GP scale normalised by its empirical sd so the smooth component
    // dominates edge-level variation.
    let gp_sd = (gp.iter().map(|v| v * v).sum::<f64>() / n as f64)
        .sqrt()
        .max(1e-12);
    let signal: Vec<f64> =
        (0..n).map(|i| base[i] + 8.0 * gp[i] / gp_sd).collect();

    // Sensors: uniform subset of nodes; 250 train / 75 test.
    let sensors = rng.sample_without_replacement(n, PAPER_SENSORS.min(n));
    let train_nodes: Vec<usize> = sensors[..PAPER_TRAIN].to_vec();
    let test_nodes: Vec<usize> = sensors[PAPER_TRAIN..].to_vec();
    let obs_noise = 1.5; // mph
    let train_y: Vec<f64> = train_nodes
        .iter()
        .map(|&i| signal[i] + obs_noise * rng.normal())
        .collect();
    let test_y: Vec<f64> = test_nodes.iter().map(|&i| signal[i]).collect();

    let mut d = RegressionData {
        graph,
        signal,
        train_nodes,
        train_y,
        test_nodes,
        test_y,
    };
    d.standardise();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let mut rng = Rng::new(1);
        let d = generate(&mut rng);
        assert!(d.graph.num_nodes() >= 700);
        assert!(d.graph.avg_degree() < 3.5);
        assert_eq!(d.train_nodes.len(), PAPER_TRAIN);
        assert_eq!(d.test_nodes.len(), PAPER_TEST);
        // Standardised.
        let mu: f64 =
            d.train_y.iter().sum::<f64>() / d.train_y.len() as f64;
        assert!(mu.abs() < 1e-9);
    }

    #[test]
    fn signal_is_graph_smooth() {
        // Variation along edges must be far below variation between
        // random node pairs — the property the GP exploits.
        let mut rng = Rng::new(2);
        let d = generate(&mut rng);
        let g = &d.graph;
        let mut edge_var = 0.0;
        let mut edge_cnt = 0usize;
        for i in 0..g.num_nodes() {
            for &j in g.neighbors(i) {
                edge_var += (d.signal[i] - d.signal[j as usize]).powi(2);
                edge_cnt += 1;
            }
        }
        edge_var /= edge_cnt as f64;
        let mut rand_var = 0.0;
        for _ in 0..edge_cnt {
            let a = rng.below(g.num_nodes());
            let b = rng.below(g.num_nodes());
            rand_var += (d.signal[a] - d.signal[b]).powi(2);
        }
        rand_var /= edge_cnt as f64;
        assert!(
            edge_var < 0.7 * rand_var,
            "edge variance {edge_var} vs random-pair {rand_var}"
        );
    }
}
