//! Synthetic substitutes for the paper's datasets.
//!
//! Every dataset the paper evaluates on (PeMS traffic, ERA5 wind, SNAP
//! social networks, Cora) is behind a download we cannot perform in
//! this offline environment. Each substitute preserves the structural
//! properties the GRF-GP algorithm is sensitive to — degree
//! distribution, locality, and graph-smoothness of the signal — as
//! documented per-dataset in DESIGN.md §5.

pub mod cora;
pub mod social;
pub mod traffic;
pub mod wind;

use crate::graph::Graph;

/// A regression dataset on a graph.
pub struct RegressionData {
    pub graph: Graph,
    /// Ground-truth signal at every node.
    pub signal: Vec<f64>,
    /// Training node ids and noisy observations.
    pub train_nodes: Vec<usize>,
    pub train_y: Vec<f64>,
    /// Held-out node ids and true values.
    pub test_nodes: Vec<usize>,
    pub test_y: Vec<f64>,
}

impl RegressionData {
    /// Standardise observations to zero mean / unit variance (paper
    /// App. C.4 normalises speeds), returning the transform (mu, sd).
    pub fn standardise(&mut self) -> (f64, f64) {
        let n = self.train_y.len() as f64;
        let mu = self.train_y.iter().sum::<f64>() / n;
        let sd = (self.train_y.iter().map(|v| (v - mu).powi(2)).sum::<f64>()
            / n)
            .sqrt()
            .max(1e-12);
        for v in self
            .train_y
            .iter_mut()
            .chain(self.test_y.iter_mut())
            .chain(self.signal.iter_mut())
        {
            *v = (*v - mu) / sd;
        }
        (mu, sd)
    }
}

/// A node-classification dataset on a graph.
pub struct ClassificationData {
    pub graph: Graph,
    pub labels: Vec<usize>,
    pub n_classes: usize,
    pub train_nodes: Vec<usize>,
    pub test_nodes: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn standardise_normalises_train() {
        let g = crate::graph::generators::ring(8);
        let mut d = RegressionData {
            graph: g,
            signal: vec![0.0; 8],
            train_nodes: vec![0, 1, 2, 3],
            train_y: vec![10.0, 12.0, 14.0, 16.0],
            test_nodes: vec![4],
            test_y: vec![13.0],
        };
        d.standardise();
        let mu: f64 = d.train_y.iter().sum::<f64>() / 4.0;
        assert!(mu.abs() < 1e-12);
        let var: f64 = d.train_y.iter().map(|v| v * v).sum::<f64>() / 4.0;
        assert!((var - 1.0).abs() < 1e-9);
    }

    #[test]
    fn all_datasets_produce_valid_structures() {
        let mut rng = Rng::new(0);
        let t = traffic::generate(&mut rng);
        t.graph.validate().unwrap();
        assert_eq!(t.train_nodes.len(), 250);
        assert_eq!(t.test_nodes.len(), 75);

        let w = wind::generate(wind::Altitude::Low, 10.0, &mut rng);
        w.graph.validate().unwrap();
        assert!(!w.train_nodes.is_empty());

        let c = cora::generate(&mut rng);
        c.graph.validate().unwrap();
        assert_eq!(c.n_classes, 7);
        assert!(c.labels.iter().all(|&l| l < 7));

        let s = social::generate(social::Network::Facebook, 0.05, &mut rng);
        s.validate().unwrap();
    }
}
