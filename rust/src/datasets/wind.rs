//! ERA5 wind-speed substitute (paper App. C.5).
//!
//! Paper: monthly-average ERA5 wind at 0.1/2/5 km, globe discretised at
//! 2.5° (≈10K-node kNN graph on S²), trained on 1,441 nodes along the
//! Aeolus satellite ground track.
//!
//! Substitute: a band-limited random spherical-harmonic field (altitude
//! controls spectral decay — low altitude → rough, high → smooth/zonal)
//! on the same 2.5° kNN sphere graph, with training nodes chosen as the
//! nodes nearest a simulated sun-synchronous polar orbit ground track.

use super::RegressionData;
use crate::graph::generators::{knn_graph, sphere_grid};
use crate::util::rng::Rng;

/// Altitude regimes from the paper (0.1 km, 2 km, 5 km).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Altitude {
    Low,  // 0.1 km: rough, small-scale structure
    Mid,  // 2 km
    High, // 5 km: smooth, zonal jets
}

impl Altitude {
    /// Max spherical-harmonic degree and spectral decay.
    fn spectrum(self) -> (usize, f64) {
        match self {
            Altitude::Low => (12, 1.2),
            Altitude::Mid => (8, 1.8),
            Altitude::High => (5, 2.5),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Altitude::Low => "0.1km",
            Altitude::Mid => "2km",
            Altitude::High => "5km",
        }
    }
}

/// Band-limited random field on the sphere as a sum of directional
/// plane waves: f(p) = Σ_k a_k cos(ω_k ⟨d_k, p⟩ + φ_k), with frequency
/// ω_k up to `l_max` (the harmonic-degree analogue) and amplitude decay
/// a_k ∝ ω_k^{-decay}. Roughness genuinely scales with the bandwidth.
struct Wave {
    dir: [f64; 3],
    omega: f64,
    phase: f64,
    amp: f64,
}

fn eval_field(p: [f64; 3], waves: &[Wave]) -> f64 {
    waves
        .iter()
        .map(|w| {
            let x = w.dir[0] * p[0] + w.dir[1] * p[1] + w.dir[2] * p[2];
            w.amp * (w.omega * x + w.phase).cos()
        })
        .sum()
}

fn draw_field(l_max: usize, decay: f64, rng: &mut Rng) -> Vec<Wave> {
    let mut waves = Vec::new();
    for l in 1..=l_max {
        // A few random directions per frequency shell.
        for _ in 0..4 {
            let mut d = [rng.normal(), rng.normal(), rng.normal()];
            let norm = (d.iter().map(|x| x * x).sum::<f64>()).sqrt().max(1e-9);
            d.iter_mut().for_each(|x| *x /= norm);
            waves.push(Wave {
                dir: d,
                omega: l as f64,
                phase: std::f64::consts::TAU * rng.uniform(),
                amp: (l as f64).powf(-decay) * rng.normal(),
            });
        }
    }
    waves
}

/// Simulated sun-synchronous polar orbit ground track: `n_orbits`
/// passes with the longitude of the ascending node precessing.
fn satellite_track(n_points: usize, n_orbits: usize) -> Vec<[f64; 3]> {
    let mut pts = Vec::with_capacity(n_points);
    let per_orbit = n_points.div_ceil(n_orbits);
    for orbit in 0..n_orbits {
        let lon0 = orbit as f64 / n_orbits as f64 * std::f64::consts::TAU;
        for s in 0..per_orbit {
            if pts.len() == n_points {
                break;
            }
            let phase = s as f64 / per_orbit as f64 * std::f64::consts::TAU;
            // Near-polar inclination (97°).
            let incl = 97f64.to_radians();
            let lat = (phase.sin() * incl.sin()).asin();
            let lon = lon0 + phase.cos().atan2(phase.sin() * incl.cos());
            pts.push([
                lat.cos() * lon.cos(),
                lat.cos() * lon.sin(),
                lat.sin(),
            ]);
        }
    }
    pts
}

/// Build the wind dataset at `res_deg` resolution (2.5 in the paper;
/// coarser for quick tests). Training set ≈ 1441·(2.5/res)² nodes near
/// the track, capped to 14% of the graph.
pub fn generate(alt: Altitude, res_deg: f64, rng: &mut Rng) -> RegressionData {
    let pts = sphere_grid(res_deg);
    let graph = knn_graph(&pts, 6);
    let n = pts.len();
    let (l_max, decay) = alt.spectrum();
    let waves = draw_field(l_max, decay, rng);
    // Wind speed = |band-limited field| (normalised to unit sd) + a
    // zonal jet component (smooth in latitude).
    let raw: Vec<f64> = pts.iter().map(|&p| eval_field(p, &waves)).collect();
    let sd = (raw.iter().map(|v| v * v).sum::<f64>() / n as f64)
        .sqrt()
        .max(1e-12);
    let signal: Vec<f64> = pts
        .iter()
        .zip(&raw)
        .map(|(&p, &f)| {
            // Low-frequency jet: cos²(1.5 z) varies on planetary scale
            // only, so it stays smooth at any grid resolution.
            let zonal = match alt {
                Altitude::High => 1.0 * (1.5 * p[2]).cos().powi(2),
                Altitude::Mid => 0.5 * (1.5 * p[2]).cos().powi(2),
                Altitude::Low => 0.3,
            };
            (f / sd).abs() + zonal
        })
        .collect();

    // Training nodes: nearest grid node to each track point.
    let n_track = ((1441.0 * (2.5 / res_deg).powi(2)) as usize)
        .clamp(32, n * 14 / 100);
    let track = satellite_track(n_track, 16);
    let mut is_train = vec![false; n];
    for t in &track {
        let mut best = 0;
        let mut best_d = f64::MAX;
        for (i, p) in pts.iter().enumerate() {
            let d: f64 = (0..3).map(|a| (p[a] - t[a]).powi(2)).sum();
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        is_train[best] = true;
    }
    let train_nodes: Vec<usize> = (0..n).filter(|&i| is_train[i]).collect();
    let test_nodes: Vec<usize> = (0..n).filter(|&i| !is_train[i]).collect();
    let noise = 0.05;
    let train_y: Vec<f64> = train_nodes
        .iter()
        .map(|&i| signal[i] + noise * rng.normal())
        .collect();
    let test_y: Vec<f64> = test_nodes.iter().map(|&i| signal[i]).collect();
    let mut d = RegressionData {
        graph,
        signal,
        train_nodes,
        train_y,
        test_nodes,
        test_y,
    };
    d.standardise();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_resolution_graph_size() {
        // 2.5 degrees -> 72 x 144 = 10368 nodes (paper: "roughly 10K").
        let pts = sphere_grid(2.5);
        assert_eq!(pts.len(), 10368);
    }

    #[test]
    fn track_is_localised() {
        let mut rng = Rng::new(0);
        let d = generate(Altitude::Mid, 10.0, &mut rng);
        let frac = d.train_nodes.len() as f64 / d.graph.num_nodes() as f64;
        assert!(frac < 0.2, "train fraction {frac}");
        assert!(!d.train_nodes.is_empty());
    }

    #[test]
    fn altitude_controls_smoothness() {
        let mut rng_a = Rng::new(3);
        let mut rng_b = Rng::new(3);
        let low = generate(Altitude::Low, 10.0, &mut rng_a);
        let high = generate(Altitude::High, 10.0, &mut rng_b);
        // Scale-invariant roughness: edge variation / total variation.
        let roughness = |d: &RegressionData| {
            let g = &d.graph;
            let n = g.num_nodes();
            let mean: f64 = d.signal.iter().sum::<f64>() / n as f64;
            let total: f64 = d
                .signal
                .iter()
                .map(|v| (v - mean).powi(2))
                .sum::<f64>()
                / n as f64;
            let mut acc = 0.0;
            let mut cnt = 0;
            for i in 0..n {
                for &j in g.neighbors(i) {
                    acc += (d.signal[i] - d.signal[j as usize]).powi(2);
                    cnt += 1;
                }
            }
            acc / cnt as f64 / total.max(1e-12)
        };
        assert!(
            roughness(&low) > roughness(&high),
            "low altitude should be rougher: {} vs {}",
            roughness(&low),
            roughness(&high)
        );
    }
}
