//! SNAP social-network substitutes (paper Table 6).
//!
//! Paper: YouTube (1,134,890 / 2,987,624), Facebook (22,470 / 171,002),
//! Twitch (168,114 / 6,797,557), Enron (36,652 / 183,831); BO objective
//! = node degree ("most influential user", following Wan et al. 2023).
//!
//! Substitute: Barabási–Albert preferential attachment with exactly the
//! paper's node counts and `m` chosen to match the edge counts, which
//! reproduces the heavy-tailed degree distribution and hub structure
//! that degree-maximisation BO exercises. `scale` shrinks node counts
//! proportionally for CI-speed runs.

use crate::graph::generators::barabasi_albert;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// The four networks of Table 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Network {
    YouTube,
    Facebook,
    Twitch,
    Enron,
}

impl Network {
    pub fn label(self) -> &'static str {
        match self {
            Network::YouTube => "youtube",
            Network::Facebook => "facebook",
            Network::Twitch => "twitch",
            Network::Enron => "enron",
        }
    }

    /// (paper nodes, paper edges).
    pub fn paper_shape(self) -> (usize, usize) {
        match self {
            Network::YouTube => (1_134_890, 2_987_624),
            Network::Facebook => (22_470, 171_002),
            Network::Twitch => (168_114, 6_797_557),
            Network::Enron => (36_652, 183_831),
        }
    }

    /// BA attachment parameter m ≈ edges/nodes.
    pub fn ba_m(self) -> usize {
        let (n, e) = self.paper_shape();
        (e as f64 / n as f64).round().max(1.0) as usize
    }

    pub fn all() -> [Network; 4] {
        [Network::YouTube, Network::Facebook, Network::Twitch, Network::Enron]
    }
}

/// Generate the network at `scale` of the paper's size (1.0 = full).
pub fn generate(net: Network, scale: f64, rng: &mut Rng) -> Graph {
    let (n, _) = net.paper_shape();
    let n_scaled = ((n as f64 * scale) as usize).max(100);
    barabasi_albert(n_scaled, net.ba_m(), rng)
}

/// The BO objective for social networks: node degree.
pub fn degree_objective(g: &Graph) -> (Vec<f64>, f64) {
    let vals: Vec<f64> = (0..g.num_nodes()).map(|i| g.degree(i) as f64).collect();
    let max = vals.iter().cloned().fold(f64::MIN, f64::max);
    (vals, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ba_m_matches_paper_density() {
        assert_eq!(Network::YouTube.ba_m(), 3);
        assert_eq!(Network::Facebook.ba_m(), 8);
        assert_eq!(Network::Twitch.ba_m(), 40);
        assert_eq!(Network::Enron.ba_m(), 5);
    }

    #[test]
    fn scaled_generation_and_heavy_tail() {
        let mut rng = Rng::new(0);
        let g = generate(Network::Enron, 0.05, &mut rng);
        g.validate().unwrap();
        assert!(g.num_nodes() >= 1800);
        let (vals, max) = degree_objective(&g);
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!(max > 8.0 * mean, "hub degree {max} vs mean {mean}");
    }
}
