//! Cora citation-network substitute (paper App. C.7).
//!
//! Paper: largest connected component of Cora — 2,485 nodes, 5,069
//! edges, 7 topic classes, 80/20 split, structure-only features.
//!
//! Substitute: a stochastic block model with 7 communities matched to
//! Cora's class proportions and edge count. Labels = communities: the
//! homophily that GP classification on a graph kernel exploits.

use super::ClassificationData;
use crate::graph::generators::sbm;
use crate::graph::stats::largest_component;
use crate::util::rng::Rng;

pub const PAPER_NODES: usize = 2485;
pub const PAPER_EDGES: usize = 5069;
pub const N_CLASSES: usize = 7;

/// Cora's approximate class proportions (McCallum et al. 2000).
const CLASS_FRACTIONS: [f64; 7] = [0.30, 0.17, 0.15, 0.13, 0.11, 0.08, 0.06];

pub fn generate(rng: &mut Rng) -> ClassificationData {
    generate_scaled(1.0, rng)
}

/// `scale` < 1 shrinks the graph for CI-speed runs.
pub fn generate_scaled(scale: f64, rng: &mut Rng) -> ClassificationData {
    let total = ((PAPER_NODES as f64 * scale) as usize).max(140);
    let sizes: Vec<usize> = CLASS_FRACTIONS
        .iter()
        .map(|f| ((f * total as f64) as usize).max(10))
        .collect();
    let n: usize = sizes.iter().sum();
    // Edge budget ~ paper density: p_in/p_out tuned so that expected
    // edges ≈ PAPER_EDGES * scale with a ~85/15 within/between split.
    let target_edges = PAPER_EDGES as f64 * scale;
    let within_pairs: f64 = sizes
        .iter()
        .map(|&s| s as f64 * (s as f64 - 1.0) / 2.0)
        .sum();
    let total_pairs = n as f64 * (n as f64 - 1.0) / 2.0;
    let p_in = (0.85 * target_edges / within_pairs).min(0.5);
    let p_out = (0.15 * target_edges / (total_pairs - within_pairs)).min(0.5);
    let (g, labels) = sbm(&sizes, p_in, p_out, rng);
    let (g, keep) = largest_component(&g);
    let labels: Vec<usize> = keep.iter().map(|&i| labels[i]).collect();
    let n = g.num_nodes();
    // 80/20 split.
    let perm = rng.sample_without_replacement(n, n);
    let cut = (0.8 * n as f64) as usize;
    ClassificationData {
        graph: g,
        labels,
        n_classes: N_CLASSES,
        train_nodes: perm[..cut].to_vec(),
        test_nodes: perm[cut..].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_scale_matches_paper_shape() {
        let mut rng = Rng::new(0);
        let d = generate(&mut rng);
        let n = d.graph.num_nodes();
        let e = d.graph.num_edges();
        let node_err = (n as f64 - PAPER_NODES as f64).abs() / (PAPER_NODES as f64);
        let edge_err = (e as f64 - PAPER_EDGES as f64).abs() / (PAPER_EDGES as f64);
        assert!(node_err < 0.1, "nodes {n}");
        assert!(edge_err < 0.25, "edges {e}");
        assert_eq!(d.train_nodes.len() + d.test_nodes.len(), n);
    }

    #[test]
    fn labels_are_homophilous() {
        let mut rng = Rng::new(1);
        let d = generate_scaled(0.3, &mut rng);
        let g = &d.graph;
        let mut same = 0usize;
        let mut diff = 0usize;
        for i in 0..g.num_nodes() {
            for &j in g.neighbors(i) {
                if d.labels[i] == d.labels[j as usize] {
                    same += 1;
                } else {
                    diff += 1;
                }
            }
        }
        assert!(same > 2 * diff, "homophily: same={same} diff={diff}");
    }
}
