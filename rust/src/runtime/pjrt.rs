//! PJRT-backed [`Runtime`] implementation (enabled by the `pjrt`
//! feature): compiles the AOT HLO-text artifacts with the `xla` crate's
//! CPU client and executes them. See the module docs on
//! [`crate::runtime`] for the bucket-padding contract.

use super::manifest::{ArtifactInfo, Manifest};
use crate::sparse::EllArtifact;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Artifact-backed executor with a compile-once cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest from an artifacts directory and create the
    /// PJRT CPU client. Executables are compiled lazily on first use.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest bucket of `kind` with n ≥ rows, k ≥ width, kt ≥ width_t.
    pub fn pick(&self, kind: &str, rows: usize, width: usize, width_t: usize) -> Option<&ArtifactInfo> {
        self.manifest.pick(kind, rows, width, width_t)
    }

    fn executable(&self, info: &ArtifactInfo) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&info.name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", info.name))?;
        let rc = std::rc::Rc::new(exe);
        self.cache
            .borrow_mut()
            .insert(info.name.clone(), rc.clone());
        Ok(rc)
    }

    fn run(&self, info: &ArtifactInfo, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(info)?;
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", info.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {}: {e:?}", info.name))?;
        // aot.py lowers with return_tuple=True.
        lit.to_tuple()
            .map_err(|e| anyhow!("untuple result of {}: {e:?}", info.name))
    }

    // -- literal packing ------------------------------------------------

    fn lit_ell(&self, e: &EllArtifact, rows: usize, width: usize) -> Result<(xla::Literal, xla::Literal)> {
        let p = e.pad_to(rows, width);
        let idx = xla::Literal::vec1(&p.idx)
            .reshape(&[rows as i64, width as i64])
            .map_err(|e| anyhow!("reshape idx: {e:?}"))?;
        let val = xla::Literal::vec1(&p.val)
            .reshape(&[rows as i64, width as i64])
            .map_err(|e| anyhow!("reshape val: {e:?}"))?;
        Ok((idx, val))
    }

    fn lit_vec(&self, v: &[f32], rows: usize) -> xla::Literal {
        let mut padded = v.to_vec();
        padded.resize(rows, 0.0);
        xla::Literal::vec1(&padded)
    }

    fn lit_mat(&self, cols: &[Vec<f32>], rows: usize) -> Result<xla::Literal> {
        // Row-major [rows, R] from R column vectors.
        let r = cols.len();
        let mut flat = vec![0f32; rows * r];
        for (j, col) in cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                flat[i * r + j] = v;
            }
        }
        xla::Literal::vec1(&flat)
            .reshape(&[rows as i64, r as i64])
            .map_err(|e| anyhow!("reshape rhs: {e:?}"))
    }

    // -- public entry points ---------------------------------------------

    /// y = Φ Φᵀ x + σ² x via the `gram_matvec` artifact.
    pub fn gram_matvec(&self, phi: &EllArtifact, phi_t: &EllArtifact, x: &[f32], sigma2: f32) -> Result<Vec<f32>> {
        let info = self
            .pick("gram_matvec", phi.n_rows, phi.width, phi_t.width)
            .ok_or_else(|| anyhow!(
                "no gram_matvec bucket for n={} k={} kt={}",
                phi.n_rows, phi.width, phi_t.width
            ))?
            .clone();
        let (pi, pv) = self.lit_ell(phi, info.n, info.k)?;
        let (ti, tv) = self.lit_ell(phi_t, info.n, info.kt)?;
        let xl = self.lit_vec(x, info.n);
        let s = xla::Literal::scalar(sigma2);
        let out = self.run(&info, &[pi, pv, ti, tv, xl, s])?;
        let y: Vec<f32> = out[0]
            .to_vec()
            .map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(y[..phi.n_rows].to_vec())
    }

    /// Batched masked CG solve via the `cg_solve` artifact. `bs` are the
    /// right-hand sides (≤ the artifact's R; missing columns are zero).
    /// Returns the solutions and the final squared residuals.
    pub fn cg_solve(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        mask: &[f32],
        bs: &[Vec<f32>],
        sigma2: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let info = self
            .pick("cg_solve", phi.n_rows, phi.width, phi_t.width)
            .ok_or_else(|| anyhow!("no cg_solve bucket fits"))?
            .clone();
        if bs.len() > info.r {
            bail!("cg_solve artifact has R={} but {} rhs given", info.r, bs.len());
        }
        let n0 = phi.n_rows;
        let (pi, pv) = self.lit_ell(phi, info.n, info.k)?;
        let (ti, tv) = self.lit_ell(phi_t, info.n, info.kt)?;
        let ml = self.lit_vec(mask, info.n);
        let mut cols = bs.to_vec();
        while cols.len() < info.r {
            cols.push(vec![0.0; n0]);
        }
        let bl = self.lit_mat(&cols, info.n)?;
        let s = xla::Literal::scalar(sigma2);
        let out = self.run(&info, &[pi, pv, ti, tv, ml, bl, s])?;
        let flat: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let rs: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let mut xs = vec![vec![0f32; n0]; bs.len()];
        for (j, x) in xs.iter_mut().enumerate() {
            for i in 0..n0 {
                x[i] = flat[i * info.r + j];
            }
        }
        Ok((xs, rs[..bs.len()].to_vec()))
    }

    /// One fused pathwise-conditioning posterior draw (paper Eq. 12).
    #[allow(clippy::too_many_arguments)]
    pub fn posterior_sample(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        mask: &[f32],
        y: &[f32],
        w: &[f32],
        eps: &[f32],
        sigma2: f32,
    ) -> Result<Vec<f32>> {
        let info = self
            .pick("posterior_sample", phi.n_rows, phi.width, phi_t.width)
            .ok_or_else(|| anyhow!("no posterior_sample bucket fits"))?
            .clone();
        let n0 = phi.n_rows;
        let (pi, pv) = self.lit_ell(phi, info.n, info.k)?;
        let (ti, tv) = self.lit_ell(phi_t, info.n, info.kt)?;
        let args = [
            pi,
            pv,
            ti,
            tv,
            self.lit_vec(mask, info.n),
            self.lit_vec(y, info.n),
            self.lit_vec(w, info.n),
            self.lit_vec(eps, info.n),
            xla::Literal::scalar(sigma2),
        ];
        let out = self.run(&info, &args)?;
        let s: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        Ok(s[..n0].to_vec())
    }

    /// Posterior mean at all nodes via the `posterior_mean` artifact.
    pub fn posterior_mean(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        mask: &[f32],
        y: &[f32],
        sigma2: f32,
    ) -> Result<Vec<f32>> {
        let info = self
            .pick("posterior_mean", phi.n_rows, phi.width, phi_t.width)
            .ok_or_else(|| anyhow!("no posterior_mean bucket fits"))?
            .clone();
        let n0 = phi.n_rows;
        let (pi, pv) = self.lit_ell(phi, info.n, info.k)?;
        let (ti, tv) = self.lit_ell(phi_t, info.n, info.kt)?;
        let args = [
            pi,
            pv,
            ti,
            tv,
            self.lit_vec(mask, info.n),
            self.lit_vec(y, info.n),
            xla::Literal::scalar(sigma2),
        ];
        let out = self.run(&info, &args)?;
        let m: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        Ok(m[..n0].to_vec())
    }

    /// Exact dense diffusion kernel via the MXU-path artifact. `w_adj`
    /// is the row-major dense adjacency (n0 × n0, n0 ≤ bucket N).
    pub fn dense_diffusion(
        &self,
        w_adj: &[f32],
        n0: usize,
        beta: f32,
        sigma_f2: f32,
    ) -> Result<Vec<f32>> {
        let info = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "dense_diffusion" && a.n >= n0)
            .min_by_key(|a| a.n)
            .ok_or_else(|| anyhow!("no dense_diffusion bucket for n={n0}"))?
            .clone();
        let n = info.n;
        let mut padded = vec![0f32; n * n];
        for i in 0..n0 {
            padded[i * n..i * n + n0]
                .copy_from_slice(&w_adj[i * n0..(i + 1) * n0]);
        }
        let wl = xla::Literal::vec1(&padded)
            .reshape(&[n as i64, n as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let out = self.run(
            &info,
            &[wl, xla::Literal::scalar(beta), xla::Literal::scalar(sigma_f2)],
        )?;
        let k: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        // Slice the n0 x n0 block back out.
        let mut res = vec![0f32; n0 * n0];
        for i in 0..n0 {
            res[i * n0..(i + 1) * n0].copy_from_slice(&k[i * n..i * n + n0]);
        }
        Ok(res)
    }
}
