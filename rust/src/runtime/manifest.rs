//! Artifact manifest (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One lowered entry point.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// Entry-point family: gram_matvec | cg_solve | posterior_sample |
    /// posterior_mean | dense_diffusion.
    pub kind: String,
    /// Shape bucket.
    pub n: usize,
    pub k: usize,
    pub kt: usize,
    /// RHS batch width (cg_solve only).
    pub r: usize,
    /// Compiled-in CG iteration budget.
    pub iters: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub cg_iters: usize,
    pub rhs: usize,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Smallest bucket of `kind` with n ≥ rows, k ≥ width, kt ≥ width_t.
    /// Shared by the PJRT executor and its stub so bucket selection is
    /// testable without the `pjrt` feature.
    pub fn pick(
        &self,
        kind: &str,
        rows: usize,
        width: usize,
        width_t: usize,
    ) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind && a.n >= rows && a.k >= width && a.kt >= width_t
            })
            .min_by_key(|a| (a.n, a.k, a.kt))
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let cg_iters = j
            .get("cg_iters")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing cg_iters"))?;
        let rhs = j.get("rhs").and_then(Json::as_usize).unwrap_or(1);
        let arr = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            artifacts.push(ArtifactInfo {
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .unwrap_or(&format!("{name}.hlo.txt"))
                    .to_string(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                n: a.get("n").and_then(Json::as_usize).unwrap_or(0),
                k: a.get("k").and_then(Json::as_usize).unwrap_or(0),
                kt: a.get("kt").and_then(Json::as_usize).unwrap_or(0),
                r: a.get("r").and_then(Json::as_usize).unwrap_or(rhs),
                iters: a.get("iters").and_then(Json::as_usize).unwrap_or(cg_iters),
                name,
            });
        }
        Ok(Manifest { cg_iters, rhs, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let text = r#"{
            "cg_iters": 32, "rhs": 8,
            "artifacts": [
                {"name": "cg_solve_n256", "file": "cg_solve_n256.hlo.txt",
                 "kind": "cg_solve", "n": 256, "k": 16, "kt": 32,
                 "iters": 32},
                {"name": "dense_diffusion_n128", "kind": "dense_diffusion",
                 "n": 128}
            ]
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.cg_iters, 32);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].kt, 32);
        assert_eq!(m.artifacts[0].r, 8);
        assert_eq!(m.artifacts[1].file, "dense_diffusion_n128.hlo.txt");
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
