//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the Layer-2/Layer-1 execution path from Rust: the JAX model
//! graphs (with their Pallas kernels inlined via interpret-mode
//! lowering) run as compiled XLA executables. Python is **never**
//! invoked at run time; after `make artifacts` the binary is
//! self-contained.
//!
//! Shape buckets: every artifact was lowered for a fixed `(N, K, Kt)`;
//! [`Runtime::pick`] selects the smallest bucket that fits and the
//! call-sites pad inputs with zeros (padding rows of an ELL matrix are
//! all-zero ⇒ they contribute nothing to products; padded mask entries
//! are zero ⇒ padded coordinates decouple in the masked CG operator).
//!
//! The executor itself needs the `xla` crate, which is not available in
//! the offline build environment, so it is gated behind the `pjrt`
//! cargo feature. Without the feature [`Runtime::load`] returns an
//! error and every caller already degrades gracefully (the parity tests
//! and benches skip, `grfgp info` reports "no artifacts loaded").

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

#[cfg(test)]
mod tests {
    use super::manifest::{ArtifactInfo, Manifest};

    #[test]
    fn pick_prefers_smallest_fitting_bucket() {
        let mk = |n: usize, k: usize, kt: usize| ArtifactInfo {
            name: format!("cg_solve_n{n}"),
            file: String::new(),
            kind: "cg_solve".into(),
            n,
            k,
            kt,
            r: 8,
            iters: 32,
        };
        let manifest = Manifest {
            cg_iters: 32,
            rhs: 8,
            artifacts: vec![mk(1024, 32, 64), mk(4096, 32, 64), mk(256, 16, 32)],
        };
        // Exercises the shared Manifest::pick that both the PJRT
        // executor and the stub delegate to.
        let pick = |rows: usize, width: usize, wt: usize| {
            manifest.pick("cg_solve", rows, width, wt).map(|a| a.n)
        };
        assert_eq!(pick(100, 10, 20), Some(256));
        assert_eq!(pick(300, 16, 32), Some(1024));
        assert_eq!(pick(300, 20, 32), Some(1024));
        assert_eq!(pick(5000, 16, 32), None);
    }
}
