//! No-op [`Runtime`] used when the `pjrt` feature is disabled: keeps
//! every call-site compiling while `load` always fails, so the parity
//! tests, benches, and `grfgp info` all take their "no artifacts"
//! branch.

use super::manifest::{ArtifactInfo, Manifest};
use crate::sparse::EllArtifact;
use anyhow::{bail, Result};
use std::path::Path;

/// Stub executor; cannot be constructed (`load` always errors).
pub struct Runtime {
    pub manifest: Manifest,
}

const DISABLED: &str =
    "grfgp was built without the `pjrt` feature; the PJRT runtime is unavailable";

#[allow(unused_variables)]
impl Runtime {
    pub fn load(dir: &Path) -> Result<Runtime> {
        bail!("{DISABLED}");
    }

    pub fn platform(&self) -> String {
        "disabled".to_string()
    }

    /// Smallest bucket of `kind` with n ≥ rows, k ≥ width, kt ≥ width_t.
    pub fn pick(
        &self,
        kind: &str,
        rows: usize,
        width: usize,
        width_t: usize,
    ) -> Option<&ArtifactInfo> {
        self.manifest.pick(kind, rows, width, width_t)
    }

    pub fn gram_matvec(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        x: &[f32],
        sigma2: f32,
    ) -> Result<Vec<f32>> {
        bail!("{DISABLED}");
    }

    pub fn cg_solve(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        mask: &[f32],
        bs: &[Vec<f32>],
        sigma2: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        bail!("{DISABLED}");
    }

    #[allow(clippy::too_many_arguments)]
    pub fn posterior_sample(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        mask: &[f32],
        y: &[f32],
        w: &[f32],
        eps: &[f32],
        sigma2: f32,
    ) -> Result<Vec<f32>> {
        bail!("{DISABLED}");
    }

    pub fn posterior_mean(
        &self,
        phi: &EllArtifact,
        phi_t: &EllArtifact,
        mask: &[f32],
        y: &[f32],
        sigma2: f32,
    ) -> Result<Vec<f32>> {
        bail!("{DISABLED}");
    }

    pub fn dense_diffusion(
        &self,
        w_adj: &[f32],
        n0: usize,
        beta: f32,
        sigma_f2: f32,
    ) -> Result<Vec<f32>> {
        bail!("{DISABLED}");
    }
}
