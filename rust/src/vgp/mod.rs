//! Variational GP classification on graphs (paper §4.4 / App. C.7).
//!
//! Non-conjugate (softmax) inference handled variationally. We exploit
//! the GRF feature decomposition `K̂ = Φ Φᵀ`: a GP prior `h_c ~ GP(0, K̂)`
//! per class is exactly `h_c = Φ w_c`, `w_c ~ N(0, I)`, so variational
//! inference over the function values reduces to a mean-field Gaussian
//! `q(w_c) = N(μ_c, diag(σ_c²))` over the feature weights — the
//! whitened / weight-space parameterisation of SVGP where the GRF
//! features play the role of (sparse, N-dimensional) inducing features.
//!
//! ELBO = Σ_i E_q[log softmax(Φw)_{y_i}] − Σ_c KL(q(w_c) ‖ N(0, I)),
//! maximised with Adam on reparameterised Monte-Carlo gradients.

use crate::gp::adam::Adam;
use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Mean-field variational softmax classifier over GRF features.
pub struct VgpClassifier {
    /// Feature matrix Φ (N × N, sparse).
    pub phi: Csr,
    pub n_classes: usize,
    /// Variational means, one vector per class [C][N].
    pub mu: Vec<Vec<f64>>,
    /// Log standard deviations per class [C][N].
    pub log_sigma: Vec<Vec<f64>>,
    /// MC samples per gradient step.
    pub mc_samples: usize,
    /// KL weight (1.0 = exact ELBO; smaller = likelihood-weighted
    /// warm-up, standard practice).
    pub kl_scale: f64,
}

/// One training step's diagnostics.
#[derive(Clone, Copy, Debug)]
pub struct ElboStep {
    pub elbo: f64,
    pub log_lik: f64,
    pub kl: f64,
}

impl VgpClassifier {
    pub fn new(phi: Csr, n_classes: usize) -> VgpClassifier {
        let n = phi.n_cols;
        VgpClassifier {
            phi,
            n_classes,
            mu: vec![vec![0.0; n]; n_classes],
            log_sigma: vec![vec![-2.0; n]; n_classes],
            mc_samples: 4,
            kl_scale: 1.0,
        }
    }

    /// Logits at `nodes` for weight draws `w[c]`.
    fn logits(&self, nodes: &[usize], w: &[Vec<f64>]) -> Vec<Vec<f64>> {
        // h[i][c] = φ(node_i) · w_c — row-sparse dot products.
        nodes
            .iter()
            .map(|&i| {
                let (cols, vals) = self.phi.row(i);
                (0..self.n_classes)
                    .map(|c| {
                        cols.iter()
                            .zip(vals)
                            .map(|(j, v)| v * w[c][*j as usize])
                            .sum()
                    })
                    .collect()
            })
            .collect()
    }

    fn softmax(h: &[f64]) -> Vec<f64> {
        let m = h.iter().cloned().fold(f64::MIN, f64::max);
        let exps: Vec<f64> = h.iter().map(|v| (v - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }

    /// One ELBO estimate + gradient step (Adam states owned by caller).
    fn grad_step(
        &mut self,
        train: &[usize],
        labels: &[usize],
        opt_mu: &mut [Adam],
        opt_ls: &mut [Adam],
        rng: &mut Rng,
    ) -> ElboStep {
        let n = self.phi.n_cols;
        let c_count = self.n_classes;
        let m = self.mc_samples;
        let mut g_mu = vec![vec![0.0; n]; c_count];
        let mut g_ls = vec![vec![0.0; n]; c_count];
        let mut log_lik = 0.0;

        for _ in 0..m {
            // Reparameterised draw w_c = mu_c + sigma_c * eps_c.
            let mut eps = Vec::with_capacity(c_count);
            let mut w = Vec::with_capacity(c_count);
            for c in 0..c_count {
                let e = rng.normal_vec(n);
                let wc: Vec<f64> = (0..n)
                    .map(|j| self.mu[c][j] + self.log_sigma[c][j].exp() * e[j])
                    .collect();
                eps.push(e);
                w.push(wc);
            }
            let h = self.logits(train, &w);
            for (ti, (&node, &label)) in train.iter().zip(labels).enumerate() {
                let p = Self::softmax(&h[ti]);
                log_lik += p[label].max(1e-300).ln() / m as f64;
                // dELBO/dh_c = onehot - p (per sample, averaged).
                let (cols, vals) = self.phi.row(node);
                for c in 0..c_count {
                    let dh = (if c == label { 1.0 } else { 0.0 } - p[c]) / m as f64;
                    if dh == 0.0 {
                        continue;
                    }
                    for (j, v) in cols.iter().zip(vals) {
                        let j = *j as usize;
                        let contrib = dh * v;
                        g_mu[c][j] += contrib;
                        g_ls[c][j] +=
                            contrib * eps[c][j] * self.log_sigma[c][j].exp();
                    }
                }
            }
        }

        // KL(q || N(0,I)) = 0.5 Σ (mu² + σ² − 2 log σ − 1); gradients:
        // d/dmu = mu, d/dlogσ = σ² − 1.
        let mut kl = 0.0;
        for c in 0..c_count {
            for j in 0..n {
                let mu = self.mu[c][j];
                let ls = self.log_sigma[c][j];
                let s2 = (2.0 * ls).exp();
                kl += 0.5 * (mu * mu + s2 - 2.0 * ls - 1.0);
                g_mu[c][j] -= self.kl_scale * mu;
                g_ls[c][j] -= self.kl_scale * (s2 - 1.0);
            }
        }

        for c in 0..c_count {
            opt_mu[c].step_ascent(&mut self.mu[c], &g_mu[c]);
            opt_ls[c].step_ascent(&mut self.log_sigma[c], &g_ls[c]);
            for ls in &mut self.log_sigma[c] {
                *ls = ls.clamp(-6.0, 2.0);
            }
        }
        ElboStep { elbo: log_lik - self.kl_scale * kl, log_lik, kl }
    }

    /// Train with Adam for `iters` steps.
    pub fn fit(
        &mut self,
        train: &[usize],
        labels: &[usize],
        iters: usize,
        lr: f64,
        rng: &mut Rng,
    ) -> Vec<ElboStep> {
        assert_eq!(train.len(), labels.len());
        assert!(labels.iter().all(|&l| l < self.n_classes));
        let n = self.phi.n_cols;
        let mut opt_mu: Vec<Adam> =
            (0..self.n_classes).map(|_| Adam::new(n, lr)).collect();
        let mut opt_ls: Vec<Adam> =
            (0..self.n_classes).map(|_| Adam::new(n, lr)).collect();
        (0..iters)
            .map(|_| self.grad_step(train, labels, &mut opt_mu, &mut opt_ls, rng))
            .collect()
    }

    /// MAP class prediction at `nodes` (mean weights).
    pub fn predict(&self, nodes: &[usize]) -> Vec<usize> {
        let h = self.logits(nodes, &self.mu);
        h.iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(c, _)| c)
                    .unwrap()
            })
            .collect()
    }

    /// Predictive class probabilities via MC over q(w).
    pub fn predict_proba(&self, nodes: &[usize], samples: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
        let n = self.phi.n_cols;
        let mut acc = vec![vec![0.0; self.n_classes]; nodes.len()];
        for _ in 0..samples {
            let w: Vec<Vec<f64>> = (0..self.n_classes)
                .map(|c| {
                    (0..n)
                        .map(|j| {
                            self.mu[c][j]
                                + self.log_sigma[c][j].exp() * rng.normal()
                        })
                        .collect()
                })
                .collect();
            let h = self.logits(nodes, &w);
            for (ai, row) in acc.iter_mut().zip(&h) {
                let p = Self::softmax(row);
                for (a, v) in ai.iter_mut().zip(&p) {
                    *a += v / samples as f64;
                }
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::metrics::accuracy;
    use crate::graph::generators;
    use crate::walks::{WalkConfig, WalkSampler};

    fn community_problem(
        seed: u64,
    ) -> (Csr, Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let (g, labels) = generators::sbm(&[40, 40, 40], 0.25, 0.01, &mut rng);
        let cfg = WalkConfig { n_walks: 80, max_len: 3, threads: 1, ..Default::default() };
        let comps = WalkSampler::new(&g, &cfg, seed).components();
        let phi = comps.combine(&[1.0, 0.6, 0.3, 0.15]);
        let n = g.num_nodes();
        let perm = rng.sample_without_replacement(n, n);
        let split = (0.8 * n as f64) as usize;
        let train: Vec<usize> = perm[..split].to_vec();
        let test: Vec<usize> = perm[split..].to_vec();
        let train_labels: Vec<usize> = train.iter().map(|&i| labels[i]).collect();
        let test_labels: Vec<usize> = test.iter().map(|&i| labels[i]).collect();
        (phi, train, train_labels, test, test_labels)
    }

    #[test]
    fn learns_community_labels() {
        let (phi, train, train_l, test, test_l) = community_problem(0);
        let mut clf = VgpClassifier::new(phi, 3);
        let mut rng = Rng::new(1);
        let log = clf.fit(&train, &train_l, 150, 0.05, &mut rng);
        let acc = accuracy(&clf.predict(&test), &test_l);
        assert!(acc > 0.8, "test accuracy {acc}");
        // ELBO should improve over training.
        let first = log[..10].iter().map(|s| s.elbo).sum::<f64>() / 10.0;
        let last = log[log.len() - 10..].iter().map(|s| s.elbo).sum::<f64>() / 10.0;
        assert!(last > first, "ELBO should increase: {first} -> {last}");
    }

    #[test]
    fn probabilities_are_normalised_and_calibratedish() {
        let (phi, train, train_l, test, _) = community_problem(2);
        let mut clf = VgpClassifier::new(phi, 3);
        let mut rng = Rng::new(3);
        clf.fit(&train, &train_l, 60, 0.05, &mut rng);
        let proba = clf.predict_proba(&test, 16, &mut rng);
        for p in &proba {
            let z: f64 = p.iter().sum();
            assert!((z - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn kl_pulls_unused_weights_to_prior() {
        // With no data at all, training should keep q near N(0, I).
        let phi = Csr::scaled_identity(10, 1.0);
        let mut clf = VgpClassifier::new(phi, 2);
        let mut rng = Rng::new(4);
        clf.fit(&[], &[], 200, 0.05, &mut rng);
        for c in 0..2 {
            for j in 0..10 {
                assert!(clf.mu[c][j].abs() < 0.05, "mu {}", clf.mu[c][j]);
                assert!(
                    clf.log_sigma[c][j].abs() < 0.1,
                    "log_sigma {}",
                    clf.log_sigma[c][j]
                );
            }
        }
    }
}
