//! Power-law fitting in log-log space (paper Table 4).
//!
//! The paper summarises scaling as `y ≈ a·N^b`, fit by OLS on
//! (log N, log y), reporting `b` with a 95% t-interval and R².

/// Result of an OLS power-law fit `y = a * x^b`.
#[derive(Clone, Copy, Debug)]
pub struct PowerLawFit {
    pub a: f64,
    pub b: f64,
    /// Half-width of the 95% confidence interval on `b`.
    pub b_ci95: f64,
    pub r2: f64,
    pub n: usize,
}

/// Two-sided 97.5% quantile of Student's t with `df` degrees of freedom.
/// Table-based (exact for small df, 1.96 asymptote) — good to ~0.1%,
/// which is far below the run-to-run noise it brackets.
fn t975(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
        2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
        2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
        2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::INFINITY,
        d if d <= 30 => TABLE[d - 1],
        d if d <= 40 => 2.021,
        d if d <= 60 => 2.000,
        d if d <= 120 => 1.980,
        _ => 1.960,
    }
}

/// Fit `y = a x^b` by OLS in log-log space. Ignores non-positive pairs.
pub fn fit_powerlaw(xs: &[f64], ys: &[f64]) -> PowerLawFit {
    assert_eq!(xs.len(), ys.len());
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len();
    assert!(n >= 2, "need at least 2 positive points");
    let nf = n as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / nf;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / nf;
    let sxx: f64 = pts.iter().map(|p| (p.0 - mx).powi(2)).sum();
    let sxy: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let syy: f64 = pts.iter().map(|p| (p.1 - my).powi(2)).sum();
    let b = sxy / sxx;
    let a = (my - b * mx).exp();
    let ss_res: f64 = pts
        .iter()
        .map(|p| (p.1 - (my + b * (p.0 - mx))).powi(2))
        .sum();
    let r2 = if syy > 0.0 { 1.0 - ss_res / syy } else { 1.0 };
    let b_ci95 = if n > 2 {
        let se = (ss_res / (nf - 2.0) / sxx).sqrt();
        t975(n - 2) * se
    } else {
        f64::INFINITY
    };
    PowerLawFit { a, b, b_ci95, r2, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_powerlaw_recovered() {
        let xs: Vec<f64> = (5..15).map(|k| (1u64 << k) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.5 * x.powf(1.5)).collect();
        let fit = fit_powerlaw(&xs, &ys);
        assert!((fit.b - 1.5).abs() < 1e-9);
        assert!((fit.a - 3.5).abs() < 1e-6);
        assert!(fit.r2 > 0.999999);
        assert!(fit.b_ci95 < 1e-6);
    }

    #[test]
    fn noisy_fit_has_sane_ci() {
        let mut rng = crate::util::rng::Rng::new(1);
        let xs: Vec<f64> = (5..20).map(|k| (1u64 << k) as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x.powf(1.0) * (1.0 + 0.05 * rng.normal()).abs())
            .collect();
        let fit = fit_powerlaw(&xs, &ys);
        assert!((fit.b - 1.0).abs() < 0.05, "b={}", fit.b);
        assert!(fit.b_ci95 > 0.0 && fit.b_ci95 < 0.1);
        assert!(fit.r2 > 0.99);
    }

    #[test]
    fn skips_nonpositive() {
        let fit = fit_powerlaw(&[1.0, 2.0, 4.0, 8.0, 0.0], &[1.0, 2.0, 4.0, 8.0, -1.0]);
        assert!((fit.b - 1.0).abs() < 1e-12);
        assert_eq!(fit.n, 4);
    }
}
