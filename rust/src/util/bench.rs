//! Benchmark harness (criterion is not in the offline registry).
//!
//! Provides warmup + repeated timing with mean/σ/min, throughput
//! annotation, and a stable one-line-per-benchmark output format that
//! the EXPERIMENTS.md tables are generated from.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<48} mean {:>12} ± {:>10}   min {:>12}   ({} reps)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.reps
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured calls then `reps` measured calls.
/// A `black_box`-alike on the closure result prevents dead-code elision.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let (mean, std) = crate::util::timer::mean_std(&times);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        std_s: std,
        min_s: min,
        reps,
    };
    println!("{}", r.report());
    r
}

/// Adaptive variant: pick reps so total measured time ≈ `budget_s`.
pub fn bench_auto<T>(name: &str, budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // One probe call to estimate cost.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((budget_s / probe) as usize).clamp(3, 1000);
    bench(name, 1, reps, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(r.reps, 5);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
