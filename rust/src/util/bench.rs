//! Benchmark harness (criterion is not in the offline registry).
//!
//! Provides warmup + repeated timing with mean/σ/min, throughput
//! annotation, and a stable one-line-per-benchmark output format that
//! the EXPERIMENTS.md tables are generated from. The machine-readable
//! side ([`BenchRow`] / [`rows_to_json`]) is the schema behind
//! `BENCH_hotpath.json`, which tracks the perf trajectory of the
//! blocked/ELL solver paths across PRs — its shape is pinned by a
//! tier-1 test here so downstream tooling can rely on it.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<48} mean {:>12} ± {:>10}   min {:>12}   ({} reps)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.reps
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured calls then `reps` measured calls.
/// A `black_box`-alike on the closure result prevents dead-code elision.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let (mean, std) = crate::util::timer::mean_std(&times);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        std_s: std,
        min_s: min,
        reps,
    };
    println!("{}", r.report());
    r
}

/// Adaptive variant: pick reps so total measured time ≈ `budget_s`.
pub fn bench_auto<T>(name: &str, budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // One probe call to estimate cost.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((budget_s / probe) as usize).clamp(3, 1000);
    bench(name, 1, reps, f)
}

/// One machine-readable benchmark record: `name` identifies the
/// kernel/path, `n` the problem size, `b` the block width (1 for
/// single-RHS), `ns_per_op` the mean wall time. Two conventional
/// exceptions keep the schema stable for non-timing records:
/// `*_iters` rows carry an iteration count in `b` (ns_per_op 0), and
/// `metric_*` rows carry a dimensionless end-task value in
/// `ns_per_op`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    pub name: String,
    pub n: usize,
    pub b: usize,
    pub ns_per_op: f64,
}

impl BenchRow {
    pub fn new(name: &str, n: usize, b: usize, mean_s: f64) -> BenchRow {
        BenchRow {
            name: name.to_string(),
            n,
            b,
            ns_per_op: mean_s * 1e9,
        }
    }
}

/// Serialize bench rows as the stable `BENCH_*.json` schema: a JSON
/// array of objects with exactly the keys `name` (string), `n`, `b`
/// (integers), and `ns_per_op` (number, one decimal). The emission is
/// deterministic (fixed key order, fixed float formatting) so results
/// files diff cleanly between runs; `util::json::Json::parse` accepts
/// the output (pinned by `bench_json_schema_stable`).
pub fn rows_to_json(rows: &[BenchRow]) -> String {
    let mut json = String::from("[\n");
    for (i, row) in rows.iter().enumerate() {
        // Names are escaped through the shared serializer so a future
        // bench label with special characters cannot corrupt the file.
        let name = crate::util::json::Json::Str(row.name.clone()).to_string();
        json.push_str(&format!(
            "  {{\"name\": {}, \"n\": {}, \"b\": {}, \"ns_per_op\": {:.1}}}{}\n",
            name,
            row.n,
            row.b,
            row.ns_per_op,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    json
}

/// Write `rows` to `path` in the `BENCH_*.json` schema.
pub fn write_rows_json(path: &str, rows: &[BenchRow]) -> std::io::Result<()> {
    std::fs::write(path, rows_to_json(rows))
}

/// Parse a `BENCH_*.json` file back into rows (the inverse of
/// [`rows_to_json`], tolerant of any writer that emits the same
/// schema).
pub fn parse_rows_json(text: &str) -> Result<Vec<BenchRow>, String> {
    use crate::util::json::Json;
    let parsed = Json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let arr = parsed
        .as_arr()
        .ok_or_else(|| "top level must be an array".to_string())?;
    let mut rows = Vec::with_capacity(arr.len());
    for (i, obj) in arr.iter().enumerate() {
        let name = obj
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("row {i}: missing name"))?
            .to_string();
        let n = obj
            .get("n")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| format!("row {i}: missing n"))?;
        let b = obj
            .get("b")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| format!("row {i}: missing b"))?;
        let ns_per_op = obj
            .get("ns_per_op")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("row {i}: missing ns_per_op"))?;
        rows.push(BenchRow { name, n, b, ns_per_op });
    }
    Ok(rows)
}

/// One gated comparison of a bench row against the committed baseline
/// (see [`gate_rows`]).
#[derive(Clone, Debug)]
pub struct GateRow {
    pub name: String,
    pub n: usize,
    pub b: usize,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// current / baseline.
    pub ratio: f64,
    /// ratio / (median ratio across all matched rows) — the
    /// machine-speed-normalised slowdown the gate thresholds on.
    pub normalized: f64,
}

/// Outcome of [`gate_rows`].
#[derive(Clone, Debug)]
pub struct GateReport {
    /// Every row that was compared (regressions included), sorted by
    /// descending normalised ratio.
    pub matched: Vec<GateRow>,
    /// The subset whose normalised ratio exceeded the threshold.
    pub regressions: Vec<GateRow>,
    /// Rows skipped (non-timing rows, unmatched keys, sub-floor
    /// timings).
    pub skipped: usize,
    /// Median current/baseline ratio across matched rows (1.0 when
    /// nothing matched) — the machine-speed scale factor.
    pub median_ratio: f64,
}

/// The CI perf-regression gate: compare `current` bench rows against a
/// committed `baseline`, failing any row whose **median-normalised**
/// slowdown exceeds `threshold` (1.5 = "50% slower than the fleet-wide
/// drift of this run").
///
/// Rows are matched on the full `(name, n, b)` key. Skipped (never
/// gated): `metric_*` rows (dimensionless end-task values), `*_iters`
/// rows (counts ride in `b` with `ns_per_op` 0), rows absent from the
/// baseline (new benches must not fail the gate retroactively), and
/// rows where either side is below `min_ns` (micro-rows whose jitter
/// exceeds any honest threshold).
///
/// The **median normalisation** is what makes a committed baseline
/// portable across machines: a runner that is uniformly 2× slower
/// than the baseline host moves every ratio to ~2, the median absorbs
/// it, and only a *relative* regression of one path against the rest
/// of the suite trips the gate.
pub fn gate_rows(
    current: &[BenchRow],
    baseline: &[BenchRow],
    threshold: f64,
    min_ns: f64,
) -> GateReport {
    use std::collections::HashMap;
    let base: HashMap<(&str, usize, usize), f64> = baseline
        .iter()
        .map(|r| ((r.name.as_str(), r.n, r.b), r.ns_per_op))
        .collect();
    let mut matched: Vec<GateRow> = Vec::new();
    let mut skipped = 0usize;
    for row in current {
        let gateable = !row.name.starts_with("metric_")
            && !row.name.ends_with("_iters")
            && row.ns_per_op > 0.0;
        let Some(&baseline_ns) = (if gateable {
            base.get(&(row.name.as_str(), row.n, row.b))
        } else {
            None
        }) else {
            skipped += 1;
            continue;
        };
        if baseline_ns <= 0.0 || row.ns_per_op < min_ns || baseline_ns < min_ns {
            // Either side under the noise floor: micro-timings jitter
            // past any honest threshold, so the row never gates.
            skipped += 1;
            continue;
        }
        matched.push(GateRow {
            name: row.name.clone(),
            n: row.n,
            b: row.b,
            baseline_ns,
            current_ns: row.ns_per_op,
            ratio: row.ns_per_op / baseline_ns,
            normalized: 0.0, // filled below
        });
    }
    let median_ratio = if matched.is_empty() {
        1.0
    } else {
        let mut ratios: Vec<f64> = matched.iter().map(|m| m.ratio).collect();
        ratios.sort_by(f64::total_cmp);
        ratios[ratios.len() / 2]
    };
    let scale = if median_ratio > 0.0 { median_ratio } else { 1.0 };
    for m in &mut matched {
        m.normalized = m.ratio / scale;
    }
    // total_cmp: a NaN ratio (e.g. a 0/0 baseline row) must rank, not
    // panic the gate — NaN sorts above every real ratio here, so a
    // poisoned row surfaces at the top of the report instead of
    // killing it.
    matched.sort_by(|a, b| b.normalized.total_cmp(&a.normalized));
    let regressions = matched
        .iter()
        .filter(|m| m.normalized > threshold)
        .cloned()
        .collect();
    GateReport { matched, regressions, skipped, median_ratio }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(r.reps, 5);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_json_schema_stable() {
        // The emitter must produce valid JSON with the pinned schema:
        // array of objects with exactly {name, n, b, ns_per_op}, typed
        // string/int/int/number — the contract `BENCH_hotpath.json`
        // consumers (cross-PR perf tracking) rely on.
        use crate::util::json::Json;
        let rows = vec![
            BenchRow::new("csr_spmm", 16_384, 8, 1.25e-3),
            BenchRow::new("ell_spmm_f32", 131_072, 16, 9.87e-4),
            BenchRow::new("weird \"name\"\n", 1, 1, 0.0),
        ];
        let text = rows_to_json(&rows);
        let parsed = Json::parse(&text).expect("emitter must produce valid JSON");
        let arr = parsed.as_arr().expect("top level must be an array");
        assert_eq!(arr.len(), rows.len());
        for (row, obj) in rows.iter().zip(arr) {
            let Json::Obj(m) = obj else { panic!("entries must be objects") };
            let keys: Vec<&str> = m.keys().map(|k| k.as_str()).collect();
            let mut expect = vec!["name", "n", "b", "ns_per_op"];
            expect.sort_unstable();
            assert_eq!(keys, expect, "schema keys drifted");
            assert_eq!(obj.get("name").unwrap().as_str(), Some(row.name.as_str()));
            assert_eq!(obj.get("n").unwrap().as_usize(), Some(row.n));
            assert_eq!(obj.get("b").unwrap().as_usize(), Some(row.b));
            let ns = obj.get("ns_per_op").unwrap().as_f64().unwrap();
            assert!((ns - row.ns_per_op).abs() <= 0.05 + 1e-9 * row.ns_per_op.abs());
        }
        // Determinism: same rows, same bytes.
        assert_eq!(text, rows_to_json(&rows));
        // Empty input is still a valid (empty) array.
        assert_eq!(Json::parse(&rows_to_json(&[])).unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_rows_json_roundtrips() {
        let rows = vec![
            BenchRow::new("csr_spmm", 4096, 8, 1.25e-3),
            BenchRow::new("stream_delta", 4096, 1, 3.1e-5),
        ];
        let parsed = parse_rows_json(&rows_to_json(&rows)).unwrap();
        assert_eq!(parsed.len(), 2);
        for (a, b) in rows.iter().zip(&parsed) {
            assert_eq!(a.name, b.name);
            assert_eq!((a.n, a.b), (b.n, b.b));
            assert!((a.ns_per_op - b.ns_per_op).abs() <= 0.05);
        }
        assert!(parse_rows_json("not json").is_err());
        assert!(parse_rows_json("{\"a\": 1}").is_err());
        assert!(parse_rows_json("[{\"name\": \"x\"}]").is_err());
    }

    #[test]
    fn gate_flags_relative_regressions_only() {
        let mk = |name: &str, ns: f64| BenchRow::new(name, 4096, 1, ns * 1e-9);
        let baseline = vec![
            mk("a", 100_000.0),
            mk("b", 200_000.0),
            mk("c", 300_000.0),
            mk("d", 400_000.0),
        ];
        // Uniformly 2x slower machine: every ratio 2.0, median absorbs
        // it, nothing regresses.
        let uniform: Vec<BenchRow> = baseline
            .iter()
            .map(|r| BenchRow { ns_per_op: r.ns_per_op * 2.0, ..r.clone() })
            .collect();
        let rep = gate_rows(&uniform, &baseline, 1.5, 1_000.0);
        assert_eq!(rep.matched.len(), 4);
        assert!((rep.median_ratio - 2.0).abs() < 1e-9);
        assert!(rep.regressions.is_empty(), "{:?}", rep.regressions);
        // One path 4x slower while the rest hold: that one fails.
        let mut skewed = baseline.clone();
        skewed[2].ns_per_op *= 4.0;
        let rep = gate_rows(&skewed, &baseline, 1.5, 1_000.0);
        assert_eq!(rep.regressions.len(), 1);
        assert_eq!(rep.regressions[0].name, "c");
        assert!(rep.regressions[0].normalized > 3.0);
        // ...and a 1.4x drift stays under the 1.5 threshold.
        let mut mild = baseline.clone();
        mild[0].ns_per_op *= 1.4;
        let rep = gate_rows(&mild, &baseline, 1.5, 1_000.0);
        assert!(rep.regressions.is_empty(), "{:?}", rep.regressions);
    }

    #[test]
    fn gate_survives_poisoned_timings_without_panicking() {
        // Poisoned measurements must flow through the ranking instead
        // of panicking it — the old `partial_cmp().unwrap()` sorts
        // aborted the whole gate on the first non-comparable value.
        let mk = |name: &str, ns: f64| BenchRow::new(name, 4096, 1, ns * 1e-9);
        let baseline = vec![mk("a", 100_000.0), mk("b", 200_000.0)];
        // A NaN timing fails the `ns_per_op > 0.0` gateable filter and
        // is skipped; the healthy row's verdict is unaffected.
        let mut current = baseline.clone();
        current[1].ns_per_op = f64::NAN;
        let rep = gate_rows(&current, &baseline, 1.5, 1_000.0);
        assert_eq!(rep.matched.len(), 1);
        assert_eq!(rep.skipped, 1, "NaN timing must be skipped, not gated");
        assert!(rep.regressions.is_empty(), "{:?}", rep.regressions);
        // Infinite timings DO pass the filter: every ratio is inf, the
        // median scale is inf, and each normalized value is inf/inf =
        // NaN — the exact input that used to panic the final ranking
        // sort. Now it ranks (NaN first under total_cmp's descending
        // order) and, comparing false against any threshold, never
        // fabricates a regression verdict.
        let infinite: Vec<BenchRow> = baseline
            .iter()
            .map(|r| BenchRow { ns_per_op: f64::INFINITY, ..r.clone() })
            .collect();
        let rep = gate_rows(&infinite, &baseline, 1.5, 1_000.0);
        assert_eq!(rep.matched.len(), 2);
        assert!(rep.matched.iter().all(|m| m.normalized.is_nan()));
        assert!(rep.regressions.is_empty(), "{:?}", rep.regressions);
    }

    #[test]
    fn gate_skips_metrics_iters_unmatched_and_subfloor_rows() {
        let baseline = vec![
            BenchRow::new("spmv", 4096, 1, 1e-4),
            BenchRow::new("tiny", 4096, 1, 2e-9),
            BenchRow { name: "metric_bo_regret_f64".into(), n: 2048, b: 1, ns_per_op: 0.02 },
            BenchRow { name: "stream_delta_solve_warm_iters".into(), n: 4096, b: 12, ns_per_op: 0.0 },
        ];
        let current = vec![
            BenchRow::new("spmv", 4096, 1, 1.1e-4),
            // 100x "slower" but both sides under the noise floor.
            BenchRow::new("tiny", 4096, 1, 2e-7),
            // Metric value moved: not a timing, never gated.
            BenchRow { name: "metric_bo_regret_f64".into(), n: 2048, b: 1, ns_per_op: 0.9 },
            BenchRow { name: "stream_delta_solve_warm_iters".into(), n: 4096, b: 40, ns_per_op: 0.0 },
            // New bench absent from the baseline: skipped, not failed.
            BenchRow::new("brand_new", 4096, 1, 1e-3),
        ];
        let rep = gate_rows(&current, &baseline, 1.5, 10_000.0);
        assert_eq!(rep.matched.len(), 1, "{:?}", rep.matched);
        assert_eq!(rep.matched[0].name, "spmv");
        assert_eq!(rep.skipped, 4);
        assert!(rep.regressions.is_empty());
        // Empty baseline: everything skips, gate passes vacuously.
        let rep = gate_rows(&current, &[], 1.5, 10_000.0);
        assert!(rep.matched.is_empty() && rep.regressions.is_empty());
        assert_eq!(rep.median_ratio, 1.0);
    }
}
