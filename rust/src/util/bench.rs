//! Benchmark harness (criterion is not in the offline registry).
//!
//! Provides warmup + repeated timing with mean/σ/min, throughput
//! annotation, and a stable one-line-per-benchmark output format that
//! the EXPERIMENTS.md tables are generated from. The machine-readable
//! side ([`BenchRow`] / [`rows_to_json`]) is the schema behind
//! `BENCH_hotpath.json`, which tracks the perf trajectory of the
//! blocked/ELL solver paths across PRs — its shape is pinned by a
//! tier-1 test here so downstream tooling can rely on it.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<48} mean {:>12} ± {:>10}   min {:>12}   ({} reps)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.reps
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured calls then `reps` measured calls.
/// A `black_box`-alike on the closure result prevents dead-code elision.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let (mean, std) = crate::util::timer::mean_std(&times);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        std_s: std,
        min_s: min,
        reps,
    };
    println!("{}", r.report());
    r
}

/// Adaptive variant: pick reps so total measured time ≈ `budget_s`.
pub fn bench_auto<T>(name: &str, budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // One probe call to estimate cost.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((budget_s / probe) as usize).clamp(3, 1000);
    bench(name, 1, reps, f)
}

/// One machine-readable benchmark record: `name` identifies the
/// kernel/path, `n` the problem size, `b` the block width (1 for
/// single-RHS), `ns_per_op` the mean wall time. Two conventional
/// exceptions keep the schema stable for non-timing records:
/// `*_iters` rows carry an iteration count in `b` (ns_per_op 0), and
/// `metric_*` rows carry a dimensionless end-task value in
/// `ns_per_op`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    pub name: String,
    pub n: usize,
    pub b: usize,
    pub ns_per_op: f64,
}

impl BenchRow {
    pub fn new(name: &str, n: usize, b: usize, mean_s: f64) -> BenchRow {
        BenchRow {
            name: name.to_string(),
            n,
            b,
            ns_per_op: mean_s * 1e9,
        }
    }
}

/// Serialize bench rows as the stable `BENCH_*.json` schema: a JSON
/// array of objects with exactly the keys `name` (string), `n`, `b`
/// (integers), and `ns_per_op` (number, one decimal). The emission is
/// deterministic (fixed key order, fixed float formatting) so results
/// files diff cleanly between runs; `util::json::Json::parse` accepts
/// the output (pinned by `bench_json_schema_stable`).
pub fn rows_to_json(rows: &[BenchRow]) -> String {
    let mut json = String::from("[\n");
    for (i, row) in rows.iter().enumerate() {
        // Names are escaped through the shared serializer so a future
        // bench label with special characters cannot corrupt the file.
        let name = crate::util::json::Json::Str(row.name.clone()).to_string();
        json.push_str(&format!(
            "  {{\"name\": {}, \"n\": {}, \"b\": {}, \"ns_per_op\": {:.1}}}{}\n",
            name,
            row.n,
            row.b,
            row.ns_per_op,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    json
}

/// Write `rows` to `path` in the `BENCH_*.json` schema.
pub fn write_rows_json(path: &str, rows: &[BenchRow]) -> std::io::Result<()> {
    std::fs::write(path, rows_to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(r.reps, 5);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_json_schema_stable() {
        // The emitter must produce valid JSON with the pinned schema:
        // array of objects with exactly {name, n, b, ns_per_op}, typed
        // string/int/int/number — the contract `BENCH_hotpath.json`
        // consumers (cross-PR perf tracking) rely on.
        use crate::util::json::Json;
        let rows = vec![
            BenchRow::new("csr_spmm", 16_384, 8, 1.25e-3),
            BenchRow::new("ell_spmm_f32", 131_072, 16, 9.87e-4),
            BenchRow::new("weird \"name\"\n", 1, 1, 0.0),
        ];
        let text = rows_to_json(&rows);
        let parsed = Json::parse(&text).expect("emitter must produce valid JSON");
        let arr = parsed.as_arr().expect("top level must be an array");
        assert_eq!(arr.len(), rows.len());
        for (row, obj) in rows.iter().zip(arr) {
            let Json::Obj(m) = obj else { panic!("entries must be objects") };
            let keys: Vec<&str> = m.keys().map(|k| k.as_str()).collect();
            let mut expect = vec!["name", "n", "b", "ns_per_op"];
            expect.sort_unstable();
            assert_eq!(keys, expect, "schema keys drifted");
            assert_eq!(obj.get("name").unwrap().as_str(), Some(row.name.as_str()));
            assert_eq!(obj.get("n").unwrap().as_usize(), Some(row.n));
            assert_eq!(obj.get("b").unwrap().as_usize(), Some(row.b));
            let ns = obj.get("ns_per_op").unwrap().as_f64().unwrap();
            assert!((ns - row.ns_per_op).abs() <= 0.05 + 1e-9 * row.ns_per_op.abs());
        }
        // Determinism: same rows, same bytes.
        assert_eq!(text, rows_to_json(&rows));
        // Empty input is still a valid (empty) array.
        assert_eq!(Json::parse(&rows_to_json(&[])).unwrap(), Json::Arr(vec![]));
    }
}
