//! Deterministic, splittable pseudo-random number generation.
//!
//! The offline crate registry has no `rand`; this module implements
//! xoshiro256++ (Blackman & Vigna) seeded through SplitMix64, plus the
//! samplers the GRF-GP stack needs: uniforms, categorical draws for the
//! walk engine, and Ziggurat-free normal/Rademacher variates for
//! Hutchinson probes and pathwise conditioning.

/// xoshiro256++ generator. 2^256-1 period, passes BigCrush; cheap enough
/// for the walk engine's inner loop (one `next_u64` per step).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from the Box-Muller pair.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-thread walkers): mixes the
    /// stream id through SplitMix so streams don't overlap in practice.
    pub fn split(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: only loop when lo < n and lo < (2^64 mod n).
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// True with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Rademacher (+1/-1) probe vector for Hutchinson trace estimation.
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher-Yates over an index vector; O(n) memory is fine
        // for every use in this crate (train/test splits, BO inits).
        let mut ids: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            ids.swap(i, j);
        }
        ids.truncate(k);
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let base = Rng::new(7);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_mean_half() {
        let mut rng = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_uniform() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 7.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(),
                    "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Rng::new(5);
        let ids = rng.sample_without_replacement(100, 40);
        assert_eq!(ids.len(), 40);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
