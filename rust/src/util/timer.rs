//! Wall-clock timing + lightweight metrics instrumentation.

use std::time::Instant;

/// Time a closure; returns (result, seconds).
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// Percentile (nearest-rank) of a sample; `q` in [0, 1].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Named duration accumulator for profiling sections of a pipeline.
#[derive(Default, Debug)]
pub struct Stopwatch {
    entries: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (v, secs) = timeit(f);
        self.entries.push((name.to_string(), secs));
        v
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        self.entries.push((name.to_string(), secs));
    }

    pub fn total(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, s)| s)
            .sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    pub fn report(&self) -> String {
        use std::collections::BTreeMap;
        let mut totals: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
        for (n, s) in &self.entries {
            let e = totals.entry(n).or_insert((0.0, 0));
            e.0 += s;
            e.1 += 1;
        }
        let mut out = String::new();
        for (n, (s, c)) in totals {
            out.push_str(&format!("{n:>24}: {s:9.4}s  ({c} calls)\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.01), 1.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.add("a", 1.0);
        sw.add("a", 2.0);
        sw.add("b", 0.5);
        assert!((sw.total("a") - 3.0).abs() < 1e-12);
        assert!(sw.report().contains("a"));
    }
}
