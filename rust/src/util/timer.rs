//! Wall-clock timing + lightweight metrics instrumentation.
//!
//! New code should time through [`crate::obs::span`] (RAII spans and
//! [`crate::obs::span::timed`], which feed the global lock-free
//! metrics registry); the statistics helpers here (`mean_std`,
//! `percentile`) remain the summary layer the experiment scenarios
//! report with. [`Stopwatch`] is deprecated and kept only as a thin
//! shim over the registry.

use std::time::Instant;

/// Time a closure; returns (result, seconds).
///
/// Prefer [`crate::obs::span::timed`], which additionally records the
/// duration into a registry histogram; this helper remains for call
/// sites with no natural metric to feed.
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// Percentile (nearest-rank) of a sample; `q` in [0, 1].
///
/// NaN-tolerant: sorts with `f64::total_cmp` (IEEE total order, NaN
/// sorts above +∞), so a NaN in the sample — e.g. a failed-solve
/// timing — can surface *as* a NaN result at high ranks but can never
/// panic the reporting path (the old `partial_cmp().unwrap()` did).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Named duration accumulator for profiling sections of a pipeline.
///
/// Deprecated: time through [`crate::obs::span`] instead — spans feed
/// the global registry, which the server exports over the wire
/// (`{"op":"metrics"}`) and the benches snapshot. This shim still
/// works for callers that want a local per-name report, and every
/// `record` additionally lands in the registry's `stopwatch_ns`
/// catch-all histogram so legacy timings stay visible in scrapes.
#[deprecated(
    note = "use obs::span::Span / obs::span::timed; the registry \
            replaces local accumulators"
)]
#[derive(Default, Debug)]
pub struct Stopwatch {
    entries: Vec<(String, f64)>,
}

#[allow(deprecated)]
impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (v, secs) =
            crate::obs::span::timed(&crate::obs::registry::STOPWATCH_NS, f);
        self.entries.push((name.to_string(), secs));
        v
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        self.entries.push((name.to_string(), secs));
    }

    pub fn total(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, s)| s)
            .sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    pub fn report(&self) -> String {
        use std::collections::BTreeMap;
        let mut totals: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
        for (n, s) in &self.entries {
            let e = totals.entry(n).or_insert((0.0, 0));
            e.0 += s;
            e.1 += 1;
        }
        let mut out = String::new();
        for (n, (s, c)) in totals {
            out.push_str(&format!("{n:>24}: {s:9.4}s  ({c} calls)\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.01), 1.0);
    }

    #[test]
    fn percentile_tolerates_nan_without_panicking() {
        // A NaN sample (failed-solve timing) must not panic the
        // reporting path. Under total order NaN sorts last, so low
        // ranks still answer with real numbers and only the top rank
        // surfaces the NaN.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 0.25), 1.0);
        assert!(percentile(&xs, 1.0).is_nan());
        // All-NaN input degrades to NaN, not a panic.
        assert!(percentile(&[f64::NAN, f64::NAN], 0.5).is_nan());
    }

    #[test]
    #[allow(deprecated)]
    fn stopwatch_accumulates() {
        // `record` feeds the global registry — serialise with the obs
        // tests that assert deltas on the same histogram.
        let _g = crate::obs::registry::test_lock();
        let mut sw = Stopwatch::new();
        sw.add("a", 1.0);
        sw.add("a", 2.0);
        sw.add("b", 0.5);
        assert!((sw.total("a") - 3.0).abs() < 1e-12);
        assert!(sw.report().contains("a"));
        // The shim's `record` path goes through the registry.
        let v = sw.record("c", || 41 + 1);
        assert_eq!(v, 42);
        assert!(sw.total("c") >= 0.0);
    }
}
