//! Minimal JSON parser/serializer (no serde in the offline registry).
//!
//! Supports the full JSON grammar; numbers parse as f64. Used for the
//! artifact manifest, the experiment result files, and the server
//! protocol — the last of which makes this attacker-facing, so parsing
//! is hardened:
//!
//! - **Depth cap.** Nesting is depth-counted against
//!   [`ParseOptions::max_depth`] (default 128), so `[[[[…` bombs get a
//!   clean error instead of exhausting the stack. Recursion depth is
//!   bounded by the cap, never by the input.
//! - **Unicode modes** ([`UnicodeMode`]): `Strict` (default) rejects
//!   lone/unpaired `\uXXXX` surrogates and invalid UTF-8 bytes inside
//!   strings; `Replace` substitutes U+FFFD for them, for callers that
//!   prefer lossy decoding over rejection. Replace mode only relaxes
//!   *character validity* — malformed escape syntax is an error in both
//!   modes.
//! - **Byte input.** [`Json::parse_with`] takes `&[u8]`, so wire frames
//!   need not pass a UTF-8 pre-check to be rejected with a useful error.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// How to handle invalid Unicode in string literals: unpaired `\uXXXX`
/// surrogates and invalid UTF-8 byte sequences.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnicodeMode {
    /// Reject with a parse error (the default; matches RFC 8259's
    /// requirement that texts be valid Unicode).
    Strict,
    /// Substitute U+FFFD REPLACEMENT CHARACTER and continue.
    Replace,
}

/// Limits and decode policy for one parse. `Default` is what the
/// manifest/results readers use; the server wire layer passes its own
/// (see `server::wire::WireConfig`).
#[derive(Clone, Copy, Debug)]
pub struct ParseOptions {
    /// Maximum nesting depth (arrays + objects). Parsing deeper input
    /// fails cleanly; recursion is bounded by this cap.
    pub max_depth: usize,
    /// Lone-surrogate / invalid-UTF-8 policy for string literals.
    pub unicode: UnicodeMode,
}

impl Default for ParseOptions {
    fn default() -> ParseOptions {
        ParseOptions { max_depth: 128, unicode: UnicodeMode::Strict }
    }
}

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — results files diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        Json::parse_with(text.as_bytes(), &ParseOptions::default())
    }

    /// Parse raw bytes under explicit limits. Input need not be valid
    /// UTF-8: strict mode rejects invalid bytes inside strings, replace
    /// mode substitutes U+FFFD. Bytes outside strings must be JSON
    /// syntax either way.
    pub fn parse_with(bytes: &[u8], opts: &ParseOptions) -> Result<Json, String> {
        let mut p = Parser { b: bytes, i: 0, depth: 0, opts: *opts };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Index/count accessor: `Some` only when the value is a finite,
    /// non-negative whole number that a usize represents exactly (the
    /// 2^53 bound is where f64 stops representing every integer — a
    /// "count" past it is already corrupt). Negatives, NaN, and
    /// fractional values are `None`, never silently truncated into a
    /// nonsense index.
    pub fn as_usize(&self) -> Option<usize> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        let limit = MAX_EXACT.min(usize::MAX as f64);
        match self.as_f64() {
            Some(x) if x.is_finite() && x >= 0.0 && x <= limit && x.fract() == 0.0 => {
                Some(x as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: `j.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- constructors ----------------------------------------------------

    /// Emit-side twin of [`Json::as_usize`]: a counter/id becomes a
    /// number only while f64 still represents it exactly (≤ 2^53).
    /// Every server counter goes through here so a long-lived process
    /// can never silently emit a rounded count — past the bound the
    /// value is emitted as a decimal string, which clients treating it
    /// as an opaque token still round-trip, and `debug_assert` makes
    /// the (astronomically far) cliff loud in tests.
    pub fn from_uint(x: u64) -> Json {
        match Json::try_from_uint(x) {
            Ok(j) => j,
            Err(x) => {
                debug_assert!(
                    false,
                    "counter {x} exceeds 2^53; emitting as string"
                );
                Json::Str(x.to_string())
            }
        }
    }

    /// `Ok(Json::Num)` when `x` is exactly representable as f64
    /// (≤ 2^53, matching the [`Json::as_usize`] accept bound), `Err(x)`
    /// otherwise.
    pub fn try_from_uint(x: u64) -> Result<Json, u64> {
        const MAX_EXACT: u64 = 9_007_199_254_740_992; // 2^53
        if x <= MAX_EXACT {
            Ok(Json::Num(x as f64))
        } else {
            Err(x)
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no Inf/NaN; serialize as null like most tools.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    opts: ParseOptions,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    /// Count one level of nesting against the cap. Paired with a plain
    /// `self.depth -= 1` on the matching close; errors abandon the whole
    /// parse, so unwinding the counter on the error path is moot.
    fn descend(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > self.opts.max_depth {
            return Err(format!(
                "nesting deeper than max_depth={} at byte {}",
                self.opts.max_depth, self.i
            ));
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            Err(format!("bad keyword at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    self.escape(&mut out)?;
                }
                Some(_) => {
                    // Raw span up to the next quote/escape, validated as
                    // UTF-8 in one pass (not char-by-char: the old code
                    // re-validated the whole tail per char, O(n^2), and
                    // choked on invalid bytes anywhere after the span).
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    let span = &self.b[start..self.i];
                    match std::str::from_utf8(span) {
                        Ok(s) => out.push_str(s),
                        Err(e) => match self.opts.unicode {
                            UnicodeMode::Strict => {
                                return Err(format!(
                                    "invalid UTF-8 in string at byte {}",
                                    start + e.valid_up_to()
                                ));
                            }
                            UnicodeMode::Replace => {
                                out.push_str(&String::from_utf8_lossy(span));
                            }
                        },
                    }
                }
            }
        }
    }

    /// Decode one escape sequence (cursor already past the backslash).
    fn escape(&mut self, out: &mut String) -> Result<(), String> {
        let esc = self
            .peek()
            .ok_or_else(|| "truncated escape at end of input".to_string())?;
        self.i += 1;
        match esc {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{8}'),
            b'f' => out.push('\u{c}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let cp = self.hex4()?;
                if (0xD800..0xDC00).contains(&cp) {
                    // High surrogate: valid only when the next escape is
                    // a low surrogate (\uDC00..\uDFFF).
                    let followed = self.peek() == Some(b'\\')
                        && self.b.get(self.i + 1) == Some(&b'u');
                    if followed {
                        let save = self.i;
                        self.i += 2;
                        let lo = self.hex4()?;
                        if (0xDC00..0xE000).contains(&lo) {
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or("bad surrogate pair")?,
                            );
                        } else if self.opts.unicode == UnicodeMode::Replace {
                            // Unpaired high surrogate: substitute, then
                            // reprocess the second escape on its own.
                            out.push('\u{FFFD}');
                            self.i = save;
                        } else {
                            return Err(format!(
                                "unpaired high surrogate \\u{cp:04x} at byte {}",
                                self.i
                            ));
                        }
                    } else if self.opts.unicode == UnicodeMode::Replace {
                        out.push('\u{FFFD}');
                    } else {
                        return Err(format!(
                            "lone surrogate \\u{cp:04x} at byte {}",
                            self.i
                        ));
                    }
                } else if (0xDC00..0xE000).contains(&cp) {
                    if self.opts.unicode == UnicodeMode::Replace {
                        out.push('\u{FFFD}');
                    } else {
                        return Err(format!(
                            "lone low surrogate \\u{cp:04x} at byte {}",
                            self.i
                        ));
                    }
                } else {
                    out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                }
            }
            c => return Err(format!("bad escape \\{}", c as char)),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("short \\u escape".into());
        }
        // Hand-decoded: from_str_radix also accepts a leading '+',
        // which is not JSON.
        let mut cp = 0u32;
        for &d in &self.b[self.i..self.i + 4] {
            let v = match d {
                b'0'..=b'9' => d - b'0',
                b'a'..=b'f' => d - b'a' + 10,
                b'A'..=b'F' => d - b'A' + 10,
                _ => {
                    return Err(format!(
                        "bad hex digit in \\u escape at byte {}",
                        self.i
                    ))
                }
            };
            cp = (cp << 4) | v as u32;
        }
        self.i += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.descend()?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(format!(
                        "expected , or ] got {:?} at {}",
                        other, self.i
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.descend()?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(format!(
                        "expected , or }} got {:?} at {}",
                        other, self.i
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replace_opts() -> ParseOptions {
        ParseOptions { unicode: UnicodeMode::Replace, ..Default::default() }
    }

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"artifacts": [{"name": "cg", "n": 256,
                        "inputs": [{"shape": [256, 16], "dtype": "int32"}]}]}"#;
        let v = Json::parse(text).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("n").unwrap().as_usize().unwrap(), 256);
        let shape = a.path(&["inputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize().unwrap(), 16);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_surrogates() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // Escaped surrogate pair decodes to the same char.
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::Str("run".into())),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn as_usize_rejects_non_indices() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(-0.5).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
        // Largest exactly-representable integer is still accepted.
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_usize(),
                   Some(9_007_199_254_740_992));
    }

    #[test]
    fn from_uint_is_exact_up_to_2_53() {
        const MAX_EXACT: u64 = 9_007_199_254_740_992; // 2^53
        assert_eq!(Json::from_uint(0), Json::Num(0.0));
        assert_eq!(Json::from_uint(17), Json::Num(17.0));
        assert_eq!(
            Json::from_uint(MAX_EXACT),
            Json::Num(9_007_199_254_740_992.0)
        );
        // The boundary value round-trips through the index accessor.
        assert_eq!(
            Json::from_uint(MAX_EXACT).as_usize(),
            Some(9_007_199_254_740_992)
        );
        // Past the bound: try_from_uint refuses rather than rounding.
        assert_eq!(Json::try_from_uint(MAX_EXACT + 1), Err(MAX_EXACT + 1));
        assert_eq!(Json::try_from_uint(u64::MAX), Err(u64::MAX));
        assert!(Json::try_from_uint(MAX_EXACT).is_ok());
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn from_uint_release_fallback_is_a_decimal_string() {
        // Release builds degrade to a lossless string instead of a
        // rounded number (debug builds assert instead).
        let j = Json::from_uint(u64::MAX);
        assert_eq!(j.as_str(), Some("18446744073709551615"));
    }

    #[test]
    fn depth_bomb_errors_cleanly() {
        // 100k opens would previously recurse 100k frames deep; now the
        // cap fires long before the stack is at risk.
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.contains("max_depth"), "{err}");
        // Same for objects.
        let bomb = r#"{"a":"#.repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.contains("max_depth"), "{err}");
        // Nesting below the cap still parses.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn custom_depth_cap() {
        let opts = ParseOptions { max_depth: 3, ..Default::default() };
        assert!(Json::parse_with(b"[[[1]]]", &opts).is_ok());
        assert!(Json::parse_with(b"[[[[1]]]]", &opts).is_err());
    }

    #[test]
    fn lone_surrogates_strict_vs_replace() {
        // Lone high surrogate at end of string.
        assert!(Json::parse(r#""\ud800""#).is_err());
        let v = Json::parse_with(br#""\ud800""#, &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{FFFD}");
        // High surrogate followed by a non-surrogate escape: the old
        // parser underflowed `lo - 0xDC00` here (debug-build panic).
        assert!(Json::parse(r#""\ud800A""#).is_err());
        let v = Json::parse_with(br#""\ud800A""#, &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{FFFD}A");
        // High surrogate followed by raw text.
        assert!(Json::parse(r#""\ud800xy""#).is_err());
        let v = Json::parse_with(br#""\ud800xy""#, &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{FFFD}xy");
        // Lone low surrogate.
        assert!(Json::parse(r#""\udc00""#).is_err());
        let v = Json::parse_with(br#""\udc00""#, &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{FFFD}");
        // High + high: first replaced, second reprocessed and replaced.
        let v = Json::parse_with(br#""\ud800\ud800""#, &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{FFFD}\u{FFFD}");
        // Replace mode does not relax escape *syntax*.
        assert!(Json::parse_with(br#""\ud8zz""#, &replace_opts()).is_err());
        assert!(Json::parse_with(br#""\q""#, &replace_opts()).is_err());
    }

    #[test]
    fn invalid_utf8_strict_vs_replace() {
        assert!(Json::parse_with(b"\"\x80\"", &ParseOptions::default()).is_err());
        let v = Json::parse_with(b"\"\x80\"", &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{FFFD}");
        // Valid multibyte chars still pass through untouched either way.
        let v = Json::parse_with("\"héllo😀\"".as_bytes(), &replace_opts()).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo😀");
        // Invalid bytes outside a string are syntax errors in both modes.
        assert!(Json::parse_with(b"\xff\xfe", &replace_opts()).is_err());
    }

    #[test]
    fn hex_escape_is_strict() {
        // from_str_radix would accept "+abc"; the wire parser must not.
        assert!(Json::parse(r#""\u+abc""#).is_err());
        assert!(Json::parse(r#""\u00g0""#).is_err());
        assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str().unwrap(), "A");
    }
}
