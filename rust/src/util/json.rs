//! Minimal JSON parser/serializer (no serde in the offline registry).
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate
//! pairs are handled); numbers parse as f64.  Used for the artifact
//! manifest, the experiment result files, and the server protocol.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — results files diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: `j.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no Inf/NaN; serialize as null like most tools.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            Err(format!("bad keyword at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or("bad surrogate")?,
                                    );
                                } else {
                                    return Err("lone surrogate".into());
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or("bad codepoint")?,
                                );
                            }
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("short \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|e| e.to_string())?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|e| e.to_string())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(format!(
                        "expected , or ] got {:?} at {}",
                        other, self.i
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(format!(
                        "expected , or }} got {:?} at {}",
                        other, self.i
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"artifacts": [{"name": "cg", "n": 256,
                        "inputs": [{"shape": [256, 16], "dtype": "int32"}]}]}"#;
        let v = Json::parse(text).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("n").unwrap().as_usize().unwrap(), 256);
        let shape = a.path(&["inputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize().unwrap(), 16);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_surrogates() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::Str("run".into())),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }
}
