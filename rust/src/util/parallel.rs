//! Scoped-thread parallelism helpers (no rayon in the offline registry).
//!
//! The walk engine and the experiment sweeps are embarrassingly
//! parallel over nodes/seeds; `par_map_chunks` splits an index range
//! into contiguous chunks, one std scoped thread per chunk.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: respects `GRFGP_THREADS`, defaults
/// to available parallelism.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("GRFGP_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f(chunk_start, chunk_end, chunk_index)` in parallel over
/// contiguous chunks of `[0, n)`, collecting per-chunk outputs in chunk
/// order. Deterministic given deterministic `f`.
pub fn par_map_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        return vec![f(0, n, 0)];
    }
    let chunk = n.div_ceil(threads);
    let mut bounds = Vec::new();
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        bounds.push((start, end));
        start = end;
    }
    let mut out: Vec<Option<T>> = (0..bounds.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (ci, &(s, e)) in bounds.iter().enumerate() {
            let f = &f;
            handles.push(scope.spawn(move || (ci, f(s, e, ci))));
        }
        for h in handles {
            let (ci, v) = h.join().expect("worker panicked");
            out[ci] = Some(v);
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Parallel element-wise map over a slice, writing results into a new
/// Vec in input order.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send + Clone + Default,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let mut out = vec![U::default(); n];
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for (o, it) in out.iter_mut().zip(items) {
            *o = f(it);
        }
        return out;
    }
    let next = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let out_ptr = out_ptr;
            scope.spawn(move || {
                // Capture the wrapper (not its raw-pointer field) so the
                // closure stays Send under 2021 disjoint capture.
                let out_ptr = out_ptr;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&items[i]);
                    // SAFETY: each index is claimed by exactly one thread.
                    unsafe { *out_ptr.0.add(i) = v };
                }
            });
        }
    });
    out
}

/// Split `out` (logically `n_rows` rows of `row_len` contiguous items)
/// into per-thread row ranges and run `f(start_row, end_row, rows)` on
/// scoped threads, each with exclusive access to its slice. This is the
/// allocation-free backbone of the parallel SpMV/SpMM paths: callers
/// hand in a reusable output buffer instead of concatenating per-chunk
/// Vecs. Deterministic given deterministic `f`.
pub fn par_rows_mut<T, F>(out: &mut [T], row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert!(row_len > 0, "row_len must be positive");
    debug_assert_eq!(out.len() % row_len, 0);
    let n_rows = out.len() / row_len;
    let threads = threads.max(1).min(n_rows.max(1));
    if threads <= 1 {
        f(0, n_rows, out);
        return;
    }
    let chunk = n_rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut start = 0;
        let f = &f;
        while start < n_rows {
            let end = (start + chunk).min(n_rows);
            let (head, tail) = rest.split_at_mut((end - start) * row_len);
            rest = tail;
            scope.spawn(move || f(start, end, head));
            start = end;
        }
    });
}

/// Raw-pointer wrapper asserting Send/Sync; used where threads write
/// provably disjoint index sets of a shared buffer (par_map's slot
/// writes, the parallel transpose scatter).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_once() {
        let parts = par_map_chunks(101, 7, |s, e, _| (s, e));
        let mut covered = vec![false; 101];
        for (s, e) in parts {
            for c in covered.iter_mut().take(e).skip(s) {
                assert!(!*c);
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn par_map_matches_serial() {
        let xs: Vec<u64> = (0..1000).collect();
        let serial: Vec<u64> = xs.iter().map(|x| x * x + 1).collect();
        let parallel = par_map(&xs, 8, |x| x * x + 1);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_rows_mut_covers_disjointly() {
        // Each row written exactly once with its row index.
        let row_len = 3;
        let n_rows = 101;
        let mut out = vec![0u64; n_rows * row_len];
        par_rows_mut(&mut out, row_len, 7, |s, e, rows| {
            assert_eq!(rows.len(), (e - s) * row_len);
            for r in s..e {
                for k in 0..row_len {
                    rows[(r - s) * row_len + k] += r as u64 + 1;
                }
            }
        });
        for r in 0..n_rows {
            for k in 0..row_len {
                assert_eq!(out[r * row_len + k], r as u64 + 1, "row {r}");
            }
        }
        // Degenerate: zero rows.
        let mut empty: Vec<u64> = Vec::new();
        par_rows_mut(&mut empty, 4, 3, |_, _, _| {});
    }

    #[test]
    fn handles_empty_and_single() {
        assert_eq!(par_map::<u32, u32, _>(&[], 4, |x| *x), vec![]);
        assert_eq!(par_map(&[5u32], 4, |x| x + 1), vec![6]);
        let parts = par_map_chunks(0, 4, |s, e, _| (s, e));
        assert_eq!(parts, vec![(0, 0)]);
    }
}
