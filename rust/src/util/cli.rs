//! Tiny CLI argument parser (no clap in the offline registry).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `known_flags` lists boolean options that never take a value, so
    /// `--verbose positional` parses unambiguously.
    pub fn parse_known<I: IntoIterator<Item = String>>(
        raw: I,
        known_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if !known_flags.contains(&rest)
                    && iter
                        .peek()
                        .map(|next| !next.starts_with("--"))
                        .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        Args::parse_known(raw, &[])
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--sizes 32,64,128`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name}: bad entry {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_options_flags() {
        let a = Args::parse_known(
            ["exp", "--n", "100", "--verbose", "pos1", "--k=3"]
                .iter()
                .map(|s| s.to_string()),
            &["verbose"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.usize("n", 0), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.usize("k", 0), 3);
    }

    #[test]
    fn unknown_flag_greedily_takes_value() {
        let a = parse(&["exp", "--mode", "fast"]);
        assert_eq!(a.get("mode"), Some("fast"));
    }

    #[test]
    fn defaults() {
        let a = parse(&["run"]);
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f64("lr", 0.1), 0.1);
        assert!(!a.flag("x"));
        assert_eq!(a.usize_list("sizes", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--sizes", "32,64,128"]);
        assert_eq!(a.usize_list("sizes", &[]), vec![32, 64, 128]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["x", "--fast"]);
        assert!(a.flag("fast"));
    }
}
