//! In-tree infrastructure substrate.
//!
//! The offline crate registry only provides `xla`, `anyhow`, and
//! `num-traits`; everything a production crate would normally pull from
//! crates.io (rand, serde_json, clap, rayon, criterion, proptest) is
//! implemented here, scoped to exactly what the GRF-GP stack needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod powerlaw;
pub mod proptest;
pub mod rng;
pub mod timer;
