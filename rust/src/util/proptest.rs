//! In-tree property-testing harness (the `proptest` crate is not in the
//! offline registry; this reproduces its methodology: seeded random case
//! generation, many cases per property, and a reproducible failure
//! report naming the seed).
//!
//! Usage:
//! ```ignore
//! proptest(64, |rng| {
//!     let n = 1 + rng.below(50);
//!     let m = random_csr(rng, n);
//!     check!(m.transpose().transpose() == m, "transpose involution n={n}");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Run `cases` randomized cases of `property`, each with an independent
/// seeded RNG. On failure, panics with the case seed for reproduction.
pub fn proptest<F>(cases: usize, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    // Honor GRFGP_PROPTEST_SEED for replaying a failure.
    let base = std::env::var("GRFGP_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    if let Some(seed) = base {
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(0xD1B54A32D192ED03);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed on case {case}/{cases} \
                 (replay with GRFGP_PROPTEST_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert two f64s are within tolerance.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {a} vs {} = {b} differ by {} (tol {})",
                stringify!($a), stringify!($b), (a - b).abs(), $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        proptest(16, |rng| {
            let _ = rng.uniform();
            Ok(())
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        proptest(8, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.0, "x={x} is not negative");
            Ok(())
        });
    }
}
