//! Experiments: Figure 3 — regression NLPD/RMSE vs number of walkers.
//!
//! (a)-(b) Traffic (San Jose substitute): exact diffusion baseline +
//!         diffusion-shape GRF + fully-learnable GRF, n ∈ {1..8192}.
//! (c)-(d) Wind (ERA5 substitute): diffusion-shape + fully-learnable
//!         (exact baseline omitted — O(N^3) at 10K nodes, as in the
//!         paper).

use crate::datasets::{traffic, wind, RegressionData};
use crate::exp::{pm, write_result, Table};
use crate::gp::metrics::{nlpd, rmse};
use crate::gp::{ExactGp, ExactKernel, GpModel, Hypers, Modulation};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::mean_std;
use crate::walks::{Termination, WalkConfig, WalkSampler};

/// Evaluate one GRF kernel variant on a dataset.
fn eval_grf(
    data: &RegressionData,
    n_walks: usize,
    max_len: usize,
    learnable: bool,
    train_iters: usize,
    probes: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let cfg = WalkConfig {
        n_walks,
        p_halt: 0.1,
        max_len,
        reweight: true,
        normalize: true,
        termination: Termination::Iid,
        threads: 0,
    };
    let comps = WalkSampler::new(&data.graph, &cfg, seed).components();
    let modulation = if learnable {
        Modulation::learnable_init(max_len, &mut rng)
    } else {
        Modulation::diffusion(1.0, 1.0, max_len)
    };
    let hypers = Hypers::new(modulation, 0.1);
    let mut model = GpModel::new(comps, hypers, &data.train_nodes, &data.train_y);
    model.solve.probes = probes;
    model.fit(train_iters, 0.02, &mut rng);
    let (mean, var) = model.predict(32, &mut rng);
    let mu: Vec<f64> = data.test_nodes.iter().map(|&i| mean[i]).collect();
    let vv: Vec<f64> = data.test_nodes.iter().map(|&i| var[i]).collect();
    (rmse(&mu, &data.test_y), nlpd(&mu, &vv, &data.test_y))
}

struct Sweep {
    label: String,
    walks: usize,
    rmse: (f64, f64),
    nlpd: (f64, f64),
}

fn sweep_kernels(
    dataset: &str,
    make_data: &dyn Fn(u64) -> RegressionData,
    walk_counts: &[usize],
    seeds: usize,
    max_len: usize,
    train_iters: usize,
    with_exact: bool,
) -> Vec<Sweep> {
    let mut out = Vec::new();
    // Exact diffusion baseline (independent of walk count).
    if with_exact {
        let mut rs = Vec::new();
        let mut ns = Vec::new();
        for s in 0..seeds as u64 {
            let data = make_data(s);
            let mut gp = ExactGp::new(&data.graph, ExactKernel::Diffusion);
            gp.set_data(&data.train_nodes, &data.train_y);
            gp.fit(3).expect("exact fit");
            let (r, nl) = gp
                .evaluate(&data.test_nodes, &data.test_y)
                .expect("exact eval");
            rs.push(r);
            ns.push(nl);
        }
        out.push(Sweep {
            label: "exact-diffusion".into(),
            walks: 0,
            rmse: mean_std(&rs),
            nlpd: mean_std(&ns),
        });
    }
    for &(learnable, label) in
        &[(false, "diffusion-shape"), (true, "learnable")]
    {
        for &w in walk_counts {
            let mut rs = Vec::new();
            let mut ns = Vec::new();
            for s in 0..seeds as u64 {
                let data = make_data(s);
                let (r, nl) =
                    eval_grf(&data, w, max_len, learnable, train_iters, 6, s + 91);
                rs.push(r);
                ns.push(nl);
            }
            println!(
                "[{dataset}] {label} n={w}: RMSE {:.3}±{:.3} NLPD {:.3}±{:.3}",
                mean_std(&rs).0,
                mean_std(&rs).1,
                mean_std(&ns).0,
                mean_std(&ns).1
            );
            out.push(Sweep {
                label: label.into(),
                walks: w,
                rmse: mean_std(&rs),
                nlpd: mean_std(&ns),
            });
        }
    }
    out
}

fn print_and_json(dataset: &str, sweeps: &[Sweep]) -> Json {
    let mut table = Table::new(&["Kernel", "walks n", "RMSE", "NLPD"]);
    for s in sweeps {
        table.row(vec![
            s.label.clone(),
            if s.walks == 0 { "-".into() } else { s.walks.to_string() },
            pm(s.rmse.0, s.rmse.1, 3),
            pm(s.nlpd.0, s.nlpd.1, 3),
        ]);
    }
    println!("\n--- {dataset}: Figure 3 series ---");
    table.print();
    Json::Arr(
        sweeps
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("kernel", Json::Str(s.label.clone())),
                    ("walks", Json::Num(s.walks as f64)),
                    ("rmse_mean", Json::Num(s.rmse.0)),
                    ("rmse_sd", Json::Num(s.rmse.1)),
                    ("nlpd_mean", Json::Num(s.nlpd.0)),
                    ("nlpd_sd", Json::Num(s.nlpd.1)),
                ])
            })
            .collect(),
    )
}

/// Figure 3 (a)-(b): traffic.
pub fn run_traffic(args: &Args) -> Json {
    println!("=== Traffic regression (Fig. 3 a-b, Fig. 6) ===");
    let walk_counts =
        args.usize_list("walk-counts", &[4, 16, 64, 256, 1024]);
    let seeds = args.usize("seeds", 3);
    let train_iters = args.usize("train-iters", 60);
    let max_len = args.usize("max-len", 10);
    let sweeps = sweep_kernels(
        "traffic",
        &|s| traffic::generate(&mut Rng::new(s)),
        &walk_counts,
        seeds,
        max_len,
        train_iters,
        true,
    );
    let json = print_and_json("traffic", &sweeps);
    write_result("traffic_regression", &json);
    json
}

/// Figure 3 (c)-(d): wind.
pub fn run_wind(args: &Args) -> Json {
    println!("=== Wind regression (Fig. 3 c-d, Figs. 7-10) ===");
    let res = args.f64("res-deg", 5.0);
    let walk_counts = args.usize_list("walk-counts", &[4, 16, 64, 256]);
    let seeds = args.usize("seeds", 3);
    let train_iters = args.usize("train-iters", 40);
    let max_len = args.usize("max-len", 8);
    let sweeps = sweep_kernels(
        "wind",
        &|s| wind::generate(wind::Altitude::Low, res, &mut Rng::new(s)),
        &walk_counts,
        seeds,
        max_len,
        train_iters,
        false, // exact omitted: O(N^3) at 10K nodes (paper does the same)
    );
    let json = print_and_json("wind", &sweeps);
    write_result("wind_regression", &json);
    json
}
