//! Experiment: Table 1 + Figure 2 (+ raw Tables 2/3 and fits Table 4).
//!
//! Dense vs sparse GRF implementations on ring graphs of doubling size:
//! memory footprint, kernel-initialisation time, training time, and
//! inference time, with power-law exponents fitted in log-log space.
//!
//! Paper settings (App. C.2): ring graphs N = 2^5..2^20, 100 walks per
//! node, p_halt = 0.1, walks truncated at 3 hops, dense limited by
//! memory (we default the dense cap to 2^11; our dense path is CPU
//! Cholesky, so its *exponent* is ~3 rather than the paper's
//! GPU-masked ~2 — the sparse-vs-dense gap direction reproduces).

use crate::exp::{pm, write_result, Table};
use crate::gp::{GpModel, Hypers, Modulation};
use crate::graph::generators::ring;
use crate::linalg::chol::Cholesky;
use crate::linalg::{dot, Mat};
use crate::obs::registry::{EXP_INFER_NS, EXP_INIT_NS, EXP_TRAIN_NS};
use crate::obs::span::timed;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::powerlaw::fit_powerlaw;
use crate::util::rng::Rng;
use crate::util::timer::mean_std;
use crate::walks::{Termination, WalkConfig, WalkSampler};

#[derive(Clone, Copy, Debug, Default)]
struct Measure {
    memory_mb: f64,
    init_s: f64,
    train_s: f64,
    infer_s: f64,
}

/// Smooth periodic signal on the ring + noise (paper App. C.2).
fn make_signal(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let t = i as f64 / n as f64 * std::f64::consts::TAU;
            t.sin() + 0.5 * (3.0 * t).cos() + 0.1f64.sqrt() * rng.normal()
        })
        .collect()
}

fn walk_cfg(args: &Args) -> WalkConfig {
    WalkConfig {
        n_walks: args.usize("walks", 100),
        p_halt: args.f64("p-halt", 0.1),
        max_len: args.usize("max-len", 3),
        reweight: true,
        normalize: true,
        termination: Termination::Iid,
        threads: args.usize("threads", 0),
    }
}

/// Sparse path: the paper's contribution.
fn measure_sparse(n: usize, seed: u64, args: &Args) -> Measure {
    let mut rng = Rng::new(seed);
    let g = ring(n);
    let signal = make_signal(n, &mut rng);
    let train: Vec<usize> = (0..n).step_by(2).collect();
    let y: Vec<f64> = train.iter().map(|&i| signal[i]).collect();
    let cfg = walk_cfg(args);
    let steps = args.usize("train-steps", 10);

    let (comps, init_s) = timed(&EXP_INIT_NS, || WalkSampler::new(&g, &cfg, seed).components());
    let memory_mb = comps.memory_bytes() as f64 / 1e6;
    let hypers = Hypers::new(
        Modulation::diffusion(1.0, 1.0, cfg.max_len),
        0.1,
    );
    let mut model = GpModel::new(comps, hypers, &train, &y);
    model.solve.probes = args.usize("probes", 4);
    model.solve.max_iters = args.usize("cg-iters", 32);
    model.solve.tol = 1e-7;

    let (_, train_s) = timed(&EXP_TRAIN_NS, || model.fit(steps, 0.05, &mut rng));
    let (_, infer_s) = timed(&EXP_INFER_NS, || {
        let _ = model.posterior_mean();
        for _ in 0..4 {
            let _ = model.posterior_sample(&mut rng);
        }
    });
    Measure { memory_mb, init_s, train_s, infer_s }
}

/// Dense baseline: same GRF features, but the kernel approximation is
/// materialised as a dense N×N matrix and all solves are direct
/// (Cholesky), as in the paper's "GRFs (Dense)" ablation.
fn measure_dense(n: usize, seed: u64, args: &Args) -> Measure {
    let mut rng = Rng::new(seed);
    let g = ring(n);
    let signal = make_signal(n, &mut rng);
    let train: Vec<usize> = (0..n).step_by(2).collect();
    let is_train: Vec<f64> = (0..n)
        .map(|i| if i % 2 == 0 { 1.0 } else { 0.0 })
        .collect();
    let y_full: Vec<f64> = (0..n)
        .map(|i| if i % 2 == 0 { signal[i] } else { 0.0 })
        .collect();
    let cfg = walk_cfg(args);
    let steps = args.usize("train-steps", 10);
    let probes = args.usize("probes", 4);

    // Kernel init: walks + DENSE materialisation of K̂ = Φ Φᵀ.
    let (comps, walk_s) = timed(&EXP_INIT_NS, || WalkSampler::new(&g, &cfg, seed).components());
    let mut hypers = Hypers::new(
        Modulation::diffusion(1.0, 1.0, cfg.max_len),
        0.1,
    );
    let mut prepared = comps.prepare();
    let c_t: Vec<crate::sparse::Csr> =
        comps.c.iter().map(|c| c.transpose()).collect();
    let materialise = |prepared: &mut crate::walks::CombinedFeatures,
                       hypers: &Hypers| {
        let phi = prepared.combine_into(&hypers.modulation.coeffs()).clone();
        let phi_d = Mat::from_rows(&phi.to_dense());
        (phi.clone(), phi_d.matmul_par(&phi_d.transpose(), 0))
    };
    let ((phi0, k0), mat_s) = timed(&EXP_INIT_NS, || materialise(&mut prepared, &hypers));
    let memory_mb = (k0.memory_bytes() + phi0.to_dense().len()) as f64 / 1e6;
    let init_s = walk_s + mat_s;

    // Training: Adam on the LML with DENSE Cholesky solves.
    let mut opt = crate::gp::adam::Adam::new(hypers.n_params(), 0.05);
    let mut phi = phi0;
    let mut k = k0;
    let (_, train_s) = timed(&EXP_TRAIN_NS, || {
        for _ in 0..steps {
            let sigma2 = hypers.sigma_n2();
            let mut h = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    h[(i, j)] = is_train[i] * k[(i, j)] * is_train[j];
                }
                h[(i, i)] += sigma2;
            }
            let Ok(ch) = Cholesky::new(&h) else { return };
            let alpha = ch.solve(&y_full);
            // Hutchinson probes with dense solves.
            let mut solves = vec![alpha.clone()];
            let mut rhs = vec![y_full.clone()];
            for _ in 0..probes {
                let z: Vec<f64> = (0..n)
                    .map(|i| {
                        if is_train[i] == 1.0 {
                            if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
                        } else {
                            0.0
                        }
                    })
                    .collect();
                solves.push(ch.solve(&z));
                rhs.push(z);
            }
            // Same projection identities as the sparse path.
            let phi_t = phi.transpose();
            let n_coeff = comps.c.len();
            let mut grad_f = vec![0.0; n_coeff];
            let proj_phi: Vec<Vec<f64>> =
                solves.iter().map(|v| phi_t.matvec(v)).collect();
            let proj_phi_rhs: Vec<Vec<f64>> =
                rhs.iter().map(|v| phi_t.matvec(v)).collect();
            for l in 0..n_coeff {
                let quad =
                    2.0 * dot(&c_t[l].matvec(&solves[0]), &proj_phi[0]);
                let mut tr = 0.0;
                for s in 1..=probes {
                    tr += dot(&c_t[l].matvec(&solves[s]), &proj_phi_rhs[s])
                        + dot(&proj_phi[s], &c_t[l].matvec(&rhs[s]));
                }
                grad_f[l] = 0.5 * quad - 0.5 * tr / probes.max(1) as f64;
            }
            let quad_n = sigma2 * dot(&solves[0], &solves[0]);
            let mut tr_n = 0.0;
            for s in 1..=probes {
                tr_n += dot(&solves[s], &rhs[s]);
            }
            let g_noise =
                0.5 * quad_n - 0.5 * sigma2 * tr_n / probes.max(1) as f64;
            let jac = hypers.modulation.jacobian();
            let mut grad: Vec<f64> =
                jac.iter().map(|row| dot(row, &grad_f)).collect();
            grad.push(g_noise);
            let mut p = hypers.params();
            opt.step_ascent(&mut p, &grad);
            hypers.set_params(&p);
            let (np, nk) = materialise(&mut prepared, &hypers);
            phi = np;
            k = nk;
        }
    });

    // Inference: dense posterior mean + variance on the test half.
    let (_, infer_s) = timed(&EXP_INFER_NS, || {
        let sigma2 = hypers.sigma_n2();
        let mut h = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                h[(i, j)] = is_train[i] * k[(i, j)] * is_train[j];
            }
            h[(i, i)] += sigma2;
        }
        let Ok(ch) = Cholesky::new(&h) else { return };
        let alpha = ch.solve(&y_full);
        let malpha: Vec<f64> =
            (0..n).map(|i| is_train[i] * alpha[i]).collect();
        let _mean = k.matvec(&malpha);
        // Posterior covariance diag on the test half.
        for i in (1..n).step_by(2).take(256) {
            let k_i: Vec<f64> =
                (0..n).map(|j| is_train[j] * k[(i, j)]).collect();
            let w = ch.solve(&k_i);
            let _var = k[(i, i)] - dot(&k_i, &w) + sigma2;
        }
    });
    Measure { memory_mb, init_s, train_s, infer_s }
}

pub fn run(args: &Args) -> Json {
    let sparse_pows =
        args.usize_list("sparse-pows", &[5, 6, 7, 8, 9, 10, 11, 12, 13, 14]);
    let dense_pows = args.usize_list("dense-pows", &[5, 6, 7, 8, 9, 10, 11]);
    let seeds = args.usize("seeds", 3);

    println!("=== Scaling experiment (Table 1 / Fig. 2 / Tables 2-4) ===");
    let mut raw = Vec::new(); // (variant, n, field, mean, sd)
    let mut per_variant: Vec<(&str, Vec<usize>, Vec<[Vec<f64>; 4]>)> = Vec::new();

    for (variant, pows) in [("sparse", &sparse_pows), ("dense", &dense_pows)] {
        let mut table = Table::new(&[
            "Graph Size",
            "Memory (MB)",
            "Kernel init (s)",
            "Training (s)",
            "Inference (s)",
        ]);
        let mut collected = Vec::new();
        let sizes: Vec<usize> = pows.iter().map(|&p| 1usize << p).collect();
        for &n in &sizes {
            let mut fields: [Vec<f64>; 4] = Default::default();
            for seed in 0..seeds as u64 {
                let m = if variant == "sparse" {
                    measure_sparse(n, seed, args)
                } else {
                    measure_dense(n, seed, args)
                };
                fields[0].push(m.memory_mb);
                fields[1].push(m.init_s);
                fields[2].push(m.train_s);
                fields[3].push(m.infer_s);
            }
            let stats: Vec<(f64, f64)> =
                fields.iter().map(|f| mean_std(f)).collect();
            table.row(vec![
                n.to_string(),
                pm(stats[0].0, stats[0].1, 3),
                pm(stats[1].0, stats[1].1, 3),
                pm(stats[2].0, stats[2].1, 3),
                pm(stats[3].0, stats[3].1, 3),
            ]);
            for (fi, name) in
                ["memory_mb", "init_s", "train_s", "infer_s"].iter().enumerate()
            {
                raw.push((variant, n, *name, stats[fi].0, stats[fi].1));
            }
            collected.push(fields);
        }
        println!(
            "\n--- GRFs ({}) — Table {} raw measurements ---",
            variant,
            if variant == "dense" { 2 } else { 3 }
        );
        table.print();
        per_variant.push((variant, sizes, collected));
    }

    // Table 4 / Table 1: power-law fits on the asymptotic tail.
    println!("\n--- Table 1 / Table 4: fitted scaling exponents y ~ a N^b ---");
    let mut fit_table = Table::new(&["Quantity", "Kernel", "a", "b", "95% CI (b)", "R2"]);
    let mut fits_json = Vec::new();
    for (variant, sizes, collected) in &per_variant {
        // Fit on the top half of sizes (paper: dense N>=2^9, sparse N>=2^15).
        let start = sizes.len() / 2;
        for (fi, fname) in ["Memory (MB)", "Kernel init time (s)", "Training time (s)", "Inference time (s)"]
            .iter()
            .enumerate()
        {
            let xs: Vec<f64> =
                sizes[start..].iter().map(|&n| n as f64).collect();
            let ys: Vec<f64> = collected[start..]
                .iter()
                .map(|f| mean_std(&f[fi]).0)
                .collect();
            if xs.len() < 2 {
                continue;
            }
            let fit = fit_powerlaw(&xs, &ys);
            fit_table.row(vec![
                fname.to_string(),
                variant.to_string(),
                format!("{:.3e}", fit.a),
                format!("{:.2}", fit.b),
                format!("[{:.2}, {:.2}]", fit.b - fit.b_ci95, fit.b + fit.b_ci95),
                format!("{:.3}", fit.r2),
            ]);
            fits_json.push(Json::obj(vec![
                ("quantity", Json::Str(fname.to_string())),
                ("variant", Json::Str(variant.to_string())),
                ("a", Json::Num(fit.a)),
                ("b", Json::Num(fit.b)),
                ("b_ci95", Json::Num(fit.b_ci95)),
                ("r2", Json::Num(fit.r2)),
            ]));
        }
    }
    fit_table.print();

    // Headline: dense/sparse wall-clock ratio at the largest common size.
    let common = per_variant[1].1.last().cloned().unwrap_or(0);
    if let Some(si) = per_variant[0].1.iter().position(|&n| n == common) {
        let di = per_variant[1].1.len() - 1;
        let sparse_total: f64 = (1..4)
            .map(|fi| mean_std(&per_variant[0].2[si][fi]).0)
            .sum();
        let dense_total: f64 = (1..4)
            .map(|fi| mean_std(&per_variant[1].2[di][fi]).0)
            .sum();
        println!(
            "\nTotal wall-clock at N={common}: dense {dense_total:.2}s vs \
             sparse {sparse_total:.2}s  → {:.1}x speedup",
            dense_total / sparse_total.max(1e-9)
        );
    }

    let json = Json::obj(vec![
        (
            "raw",
            Json::Arr(
                raw.iter()
                    .map(|(v, n, f, m, s)| {
                        Json::obj(vec![
                            ("variant", Json::Str(v.to_string())),
                            ("n", Json::Num(*n as f64)),
                            ("field", Json::Str(f.to_string())),
                            ("mean", Json::Num(*m)),
                            ("sd", Json::Num(*s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("fits", Json::Arr(fits_json)),
    ]);
    write_result("scaling", &json);
    json
}
