//! Experiment: Table 7 + Figure 11 — Cora classification.
//!
//! Variational softmax classification on the Cora substitute, comparing
//! the exact diffusion kernel, the exact Matérn kernel, and the sparse
//! GRF kernel. All three run through the same weight-space variational
//! classifier: for the exact kernels we use the Cholesky factor L
//! (K = LLᵀ) as the (dense) feature matrix, mirroring K̂ = ΦΦᵀ.

use crate::datasets::cora;
use crate::exp::{pm, write_result, Table};
use crate::gp::metrics::accuracy;
use crate::gp::{ExactGp, ExactKernel};
use crate::linalg::chol::Cholesky;
use crate::sparse::{CooBuilder, Csr};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::mean_std;
use crate::vgp::VgpClassifier;
use crate::walks::{Termination, WalkConfig, WalkSampler};

fn dense_to_csr(l: &crate::linalg::Mat, threshold: f64) -> Csr {
    let n = l.rows;
    let mut b = CooBuilder::new(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = l[(i, j)];
            if v.abs() > threshold {
                b.push(i as u32, j as u32, v);
            }
        }
    }
    b.build()
}

fn run_one(
    kernel: &str,
    data: &crate::datasets::ClassificationData,
    args: &Args,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let iters = args.usize("train-iters", 150);
    let lr = args.f64("lr", 0.05);
    let (phi, sparsity) = match kernel {
        "grf" => {
            let cfg = WalkConfig {
                n_walks: args.usize("walks", 512),
                p_halt: 0.1,
                max_len: args.usize("max-len", 6),
                reweight: true,
                normalize: true,
                termination: Termination::Iid,
                threads: 0,
            };
            let comps = WalkSampler::new(&data.graph, &cfg, seed).components();
            // Diffusion-shaped modulation with a moderate lengthscale.
            let f: Vec<f64> = (0..=cfg.max_len)
                .map(|l| {
                    let beta: f64 = 1.0;
                    (0..l).fold(1.0, |acc, k| acc * (beta / 2.0) / (k + 1) as f64)
                })
                .collect();
            let phi = comps.combine(&f);
            let nnz_frac =
                phi.nnz() as f64 / (phi.n_rows * phi.n_cols) as f64;
            (phi, nnz_frac)
        }
        name => {
            let k = match name {
                "diffusion" => ExactKernel::Diffusion,
                _ => ExactKernel::Matern { nu: 2.0 },
            };
            let mut gp = ExactGp::new(&data.graph, k);
            gp.beta = 1.0;
            let kmat = gp.kernel_matrix();
            let mut kj = kmat.clone();
            kj.add_diag(1e-6);
            let l = Cholesky::new(&kj).expect("kernel PSD").l;
            (dense_to_csr(&l, 1e-10), 1.0)
        }
    };
    let mut clf = VgpClassifier::new(phi, data.n_classes);
    let train_labels: Vec<usize> =
        data.train_nodes.iter().map(|&i| data.labels[i]).collect();
    let test_labels: Vec<usize> =
        data.test_nodes.iter().map(|&i| data.labels[i]).collect();
    clf.fit(&data.train_nodes, &train_labels, iters, lr, &mut rng);
    let acc = accuracy(&clf.predict(&data.test_nodes), &test_labels);
    (acc, sparsity)
}

pub fn run(args: &Args) -> Json {
    println!("=== Cora classification (Table 7 / Fig. 11) ===");
    let seeds = args.usize("seeds", 3);
    let scale = args.f64("scale", 1.0);

    let mut table = Table::new(&["Kernel", "Accuracy (%)", "nnz frac"]);
    let mut rows = Vec::new();
    for kernel in ["diffusion", "grf", "matern"] {
        let mut accs = Vec::new();
        let mut spars = Vec::new();
        for s in 0..seeds as u64 {
            let mut rng = Rng::new(s);
            let data = cora::generate_scaled(scale, &mut rng);
            let (acc, sp) = run_one(kernel, &data, args, s + 31);
            accs.push(100.0 * acc);
            spars.push(sp);
        }
        let (m, sd) = mean_std(&accs);
        println!("[classify] {kernel}: {m:.2} ± {sd:.2} %");
        table.row(vec![
            kernel.to_string(),
            pm(m, sd, 2),
            format!("{:.3}", mean_std(&spars).0),
        ]);
        rows.push(Json::obj(vec![
            ("kernel", Json::Str(kernel.to_string())),
            ("accuracy_mean", Json::Num(m)),
            ("accuracy_sd", Json::Num(sd)),
            ("nnz_frac", Json::Num(mean_std(&spars).0)),
        ]));
    }
    table.print();
    let json = Json::Arr(rows);
    write_result("classification", &json);
    json
}
