//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §4 for the full index). Each driver prints the same
//! rows/series the paper reports and writes machine-readable JSON to
//! `results/`.

pub mod ablation;
pub mod bo;
pub mod classify;
pub mod regression;
pub mod scaling;

use crate::util::json::Json;
use std::path::PathBuf;

/// Where result JSON files go (override with GRFGP_RESULTS).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("GRFGP_RESULTS").unwrap_or_else(|_| "results".into());
    let p = PathBuf::from(dir);
    std::fs::create_dir_all(&p).ok();
    p
}

/// Write a result JSON document and report where.
pub fn write_result(name: &str, value: &Json) {
    let path = results_dir().join(format!("{name}.json"));
    match std::fs::write(&path, value.to_string_pretty()) {
        Ok(()) => println!("[results] wrote {}", path.display()),
        Err(e) => eprintln!("[results] FAILED to write {}: {e}", path.display()),
    }
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut out = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                out.push_str(&format!("| {c:>w$} "));
            }
            out.push('|');
            out
        };
        println!("{}", line(&self.headers));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// `mean ± sd` cell formatting.
pub fn pm(mean: f64, sd: f64, digits: usize) -> String {
    format!("{mean:.digits$} ± {sd:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2.5".into()]);
        t.print();
        assert_eq!(pm(1.23456, 0.1, 2), "1.23 ± 0.10");
    }
}
