//! Experiment: Table 5 + Figure 5 — importance-sampling ablation.
//!
//! 30×30 mesh, ground truth drawn from the exact diffusion kernel with
//! β* = 10 (hidden), 10% of nodes observed. Compare: exact diffusion
//! kernel, principled GRF kernel, and the ad-hoc random-walk kernel
//! with the 1/p(subwalk) reweighting removed (paper Eq. 13/16).

use crate::exp::{write_result, Table};
use crate::gp::metrics::{nlpd, rmse};
use crate::gp::{ExactGp, ExactKernel, GpModel, Hypers, Modulation};
use crate::graph::generators::grid2d;
use crate::linalg::chol::Cholesky;
use crate::linalg::expm::diffusion_kernel;
use crate::linalg::Mat;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::walks::{Termination, WalkConfig, WalkSampler};

pub struct AblationResult {
    pub kernel: String,
    pub rmse: f64,
    pub nlpd: f64,
}

pub fn run(args: &Args) -> Json {
    let side = args.usize("side", 30);
    let beta_star = args.f64("beta-star", 10.0);
    let obs_frac = args.f64("obs-frac", 0.1);
    let n_walks = args.usize("walks", 2000);
    let max_len = args.usize("max-len", 10);
    let train_iters = args.usize("train-iters", 200);
    let seed = args.u64("seed", 0);

    println!("=== Ablation experiment (Table 5 / Fig. 5) ===");
    println!(
        "mesh {side}x{side}, beta*={beta_star}, {:.0}% observed, \
         {n_walks} walks/node, l_max={max_len}",
        obs_frac * 100.0
    );
    let mut rng = Rng::new(seed);
    let g = grid2d(side, side);
    let n = g.num_nodes();

    // Ground truth: sample from K* = exp(-beta* L).
    let l = Mat::from_rows(&g.dense_laplacian());
    let mut kstar = diffusion_kernel(&l, beta_star, 1.0);
    kstar.add_diag(1e-8);
    let ch = Cholesky::new(&kstar).expect("K* PSD");
    let u = rng.normal_vec(n);
    let mut truth = ch.sample(&u);
    // Standardise the sampled field: exp(-10L) keeps only the lowest
    // Laplacian modes, so the raw sample has tiny variance — without
    // rescaling, observation noise would drown every kernel equally.
    let sd = (truth.iter().map(|v| v * v).sum::<f64>() / n as f64)
        .sqrt()
        .max(1e-12);
    truth.iter_mut().for_each(|v| *v /= sd);
    let noise = args.f64("noise", 0.01);
    let n_obs = ((n as f64) * obs_frac) as usize;
    let train = rng.sample_without_replacement(n, n_obs);
    let y: Vec<f64> = train
        .iter()
        .map(|&i| truth[i] + noise.sqrt() * rng.normal())
        .collect();
    let test: Vec<usize> =
        (0..n).filter(|i| !train.contains(i)).collect();
    let y_test: Vec<f64> = test.iter().map(|&i| truth[i]).collect();

    let mut results = Vec::new();

    // (1) Exact diffusion kernel. Initialise sigma_f^2 at the data
    // variance and give the coordinate search enough rounds to reach
    // beta* = 10 from 1.0.
    {
        let mut gp = ExactGp::new(&g, ExactKernel::Diffusion);
        gp.set_data(&train, &y);
        let var_y =
            y.iter().map(|v| v * v).sum::<f64>() / y.len().max(1) as f64;
        gp.sigma_f2 = var_y.max(0.1);
        gp.fit(6).expect("exact fit");
        let (r, nl) = gp.evaluate(&test, &y_test).expect("exact eval");
        results.push(AblationResult { kernel: "Diffusion".into(), rmse: r, nlpd: nl });
    }

    // (2) Principled GRFs and (3) ad-hoc GRFs.
    //
    // Both use the diffusion-shape modulation (learnable lengthscale +
    // scale). On a regular mesh a *fully* per-length-learnable
    // modulation can absorb the ad-hoc kernel's per-step rescaling,
    // hiding the reweighting gap; constraining the modulation shape
    // isolates exactly what Eq. 13 removes — the 1/p(subwalk) factor
    // that upweights long, unlikely walks. The ad-hoc walks also run on
    // the raw (unnormalised) weights, matching Eq. 13's plain
    // edge-weight product.
    for (label, reweight) in [("GRFs", true), ("Ad-hoc GRFs", false)] {
        let cfg = WalkConfig {
            n_walks,
            p_halt: 0.1,
            max_len,
            reweight,
            normalize: reweight,
            termination: Termination::Iid,
            threads: args.usize("threads", 0),
        };
        let comps = WalkSampler::new(&g, &cfg, seed + 1).components();
        let hypers = Hypers::new(
            Modulation::diffusion(1.0, 1.0, max_len),
            0.1,
        );
        let mut model = GpModel::new(comps, hypers, &train, &y);
        model.solve.probes = args.usize("probes", 6);
        model.fit(train_iters, 0.01, &mut rng);
        let (mean, var) = model.predict(32, &mut rng);
        let mu: Vec<f64> = test.iter().map(|&i| mean[i]).collect();
        let vv: Vec<f64> = test.iter().map(|&i| var[i]).collect();
        results.push(AblationResult {
            kernel: label.into(),
            rmse: rmse(&mu, &y_test),
            nlpd: nlpd(&mu, &vv, &y_test),
        });
    }

    let mut table = Table::new(&["Kernel", "RMSE", "NLPD"]);
    for r in &results {
        table.row(vec![
            r.kernel.clone(),
            format!("{:.3}", r.rmse),
            format!("{:.3}", r.nlpd),
        ]);
    }
    table.print();

    let json = Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("kernel", Json::Str(r.kernel.clone())),
                    ("rmse", Json::Num(r.rmse)),
                    ("nlpd", Json::Num(r.nlpd)),
                ])
            })
            .collect(),
    );
    write_result("ablation", &json);
    json
}
