//! Experiment: Figure 4 — BO regret curves, 11 panels.
//!
//! (a)-(d) synthetic benchmarks (unimodal / multimodal grid, SBM
//! community graph, circular kNN graph), (e)-(h) social networks
//! (max-degree user), (i)-(k) ERA5 wind-speed maximisation at three
//! altitudes. GRF Thompson sampling vs random / BFS / DFS.

use crate::bo::{run_policy, BfsPolicy, BoConfig, BoRun, DfsPolicy, Policy, RandomPolicy, ThompsonPolicy};
use crate::datasets::{social, wind};
use crate::exp::{write_result, Table};
use crate::graph::generators;
use crate::graph::Graph;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::mean_std;
use crate::walks::WalkConfig;

/// One benchmark: a graph + objective (values at all nodes).
pub struct Benchmark {
    pub name: String,
    pub graph: Graph,
    pub values: Vec<f64>,
    pub optimum: f64,
}

impl Benchmark {
    fn new(name: &str, graph: Graph, values: Vec<f64>) -> Benchmark {
        let optimum = values.iter().cloned().fold(f64::MIN, f64::max);
        Benchmark { name: name.into(), graph, values, optimum }
    }
}

/// Synthetic benchmarks (paper App. C.6 §1), scaled by `side`/`ring_n`.
pub fn synthetic_benchmarks(side: usize, ring_n: usize, rng: &mut Rng) -> Vec<Benchmark> {
    let mut out = Vec::new();
    // Unimodal function on a grid.
    {
        let g = generators::grid2d(side, side);
        let (cx, cy) = (side as f64 * 0.61, side as f64 * 0.37);
        let w = side as f64 * 0.15;
        let vals: Vec<f64> = (0..side * side)
            .map(|i| {
                let (r, c) = ((i / side) as f64, (i % side) as f64);
                (-((r - cy).powi(2) + (c - cx).powi(2)) / (2.0 * w * w)).exp()
            })
            .collect();
        out.push(Benchmark::new("unimodal-grid", g, vals));
    }
    // Multi-modal function on a grid.
    {
        let g = generators::grid2d(side, side);
        let peaks: Vec<(f64, f64, f64)> = (0..5)
            .map(|_| {
                (
                    rng.uniform() * side as f64,
                    rng.uniform() * side as f64,
                    0.4 + 0.6 * rng.uniform(),
                )
            })
            .collect();
        let w = side as f64 * 0.08;
        let vals: Vec<f64> = (0..side * side)
            .map(|i| {
                let (r, c) = ((i / side) as f64, (i % side) as f64);
                peaks
                    .iter()
                    .map(|&(px, py, a)| {
                        a * (-((r - py).powi(2) + (c - px).powi(2))
                            / (2.0 * w * w))
                            .exp()
                    })
                    .sum()
            })
            .collect();
        out.push(Benchmark::new("multimodal-grid", g, vals));
    }
    // Community graph (SBM): community scores ~ N(mu_c, sigma_c).
    {
        let k = 20;
        let per = (side * side / k).max(10);
        let sizes = vec![per; k];
        let (g, labels) = generators::sbm(&sizes, 0.05, 0.0005, rng);
        let mus: Vec<f64> = (0..k).map(|_| 2.0 * rng.normal()).collect();
        let vals: Vec<f64> = labels
            .iter()
            .map(|&c| mus[c] + 0.3 * rng.normal())
            .collect();
        out.push(Benchmark::new("community-sbm", g, vals));
    }
    // Circular (ring kNN) graph with a sinusoidal objective.
    {
        let g = generators::circular_knn(ring_n, 6);
        let vals: Vec<f64> = (0..ring_n)
            .map(|i| {
                let t = i as f64 / ring_n as f64 * std::f64::consts::TAU;
                t.sin() + 0.5 * (2.0 * t + 0.7).sin()
            })
            .collect();
        out.push(Benchmark::new("circular-knn", g, vals));
    }
    out
}

/// Social-network benchmarks (paper App. C.6 §2): objective = degree.
pub fn social_benchmarks(scale: f64, rng: &mut Rng) -> Vec<Benchmark> {
    social::Network::all()
        .iter()
        .map(|&net| {
            let g = social::generate(net, scale, rng);
            let (vals, _) = social::degree_objective(&g);
            Benchmark::new(net.label(), g, vals)
        })
        .collect()
}

/// Wind benchmarks (paper App. C.6 §3): objective = wind speed.
pub fn wind_benchmarks(res_deg: f64, rng: &mut Rng) -> Vec<Benchmark> {
    [wind::Altitude::Low, wind::Altitude::Mid, wind::Altitude::High]
        .iter()
        .map(|&alt| {
            let d = wind::generate(alt, res_deg, rng);
            Benchmark::new(
                &format!("wind-{}", alt.label()),
                d.graph,
                d.signal,
            )
        })
        .collect()
}

/// Run all four policies on one benchmark across seeds; returns
/// per-policy mean regret curves.
pub fn run_benchmark(
    b: &Benchmark,
    cfg: &BoConfig,
    seeds: usize,
) -> Vec<(String, Vec<f64>, Vec<BoRun>)> {
    let n = b.graph.num_nodes();
    let h = |i: usize| b.values[i];
    let mut out = Vec::new();
    for policy_kind in ["grf-thompson", "random", "bfs", "dfs"] {
        let mut runs = Vec::new();
        for seed in 0..seeds as u64 {
            let mut rng = Rng::new(1000 + seed);
            let run = match policy_kind {
                "grf-thompson" => {
                    let mut p = ThompsonPolicy::new(&b.graph, cfg, &mut rng);
                    let run = run_policy(&mut p, &h, b.optimum, n, cfg, &mut rng);
                    // Warm-start observability (ROADMAP item): the
                    // policy carries the previous step's posterior
                    // solve, so this count is strictly lower than a
                    // cold-start run of the same trajectory.
                    println!(
                        "[bo] {} seed {seed}: grf-thompson spent {} block-CG \
                         iterations across {} draws (warm-started)",
                        b.name,
                        p.cg_iters,
                        run.queries.len() - cfg.n_init.min(n)
                    );
                    run
                }
                "random" => {
                    let mut p = RandomPolicy::new(n);
                    run_policy(&mut p, &h, b.optimum, n, cfg, &mut rng)
                }
                "bfs" => {
                    let mut p = BfsPolicy::new(&b.graph);
                    run_policy(&mut p, &h, b.optimum, n, cfg, &mut rng)
                }
                _ => {
                    let mut p = DfsPolicy::new(&b.graph);
                    run_policy(&mut p, &h, b.optimum, n, cfg, &mut rng)
                }
            };
            runs.push(run);
        }
        let len = runs[0].regret.len();
        let mean_curve: Vec<f64> = (0..len)
            .map(|t| {
                runs.iter().map(|r| r.regret[t]).sum::<f64>() / seeds as f64
            })
            .collect();
        out.push((policy_kind.to_string(), mean_curve, runs));
    }
    out
}

fn summarise(benchmarks: &[Benchmark], cfg: &BoConfig, seeds: usize, tag: &str) -> Json {
    let mut panels = Vec::new();
    let mut table = Table::new(&[
        "Benchmark",
        "N",
        "grf-thompson",
        "random",
        "bfs",
        "dfs",
    ]);
    for b in benchmarks {
        println!(
            "[bo:{tag}] {} — N={} optimum={:.3}",
            b.name,
            b.graph.num_nodes(),
            b.optimum
        );
        let results = run_benchmark(b, cfg, seeds);
        let finals: Vec<String> = results
            .iter()
            .map(|(_, curve, runs)| {
                let last: Vec<f64> =
                    runs.iter().map(|r| *r.regret.last().unwrap()).collect();
                let (m, s) = mean_std(&last);
                let _ = curve;
                format!("{m:.3}±{s:.3}")
            })
            .collect();
        table.row({
            let mut row = vec![b.name.clone(), b.graph.num_nodes().to_string()];
            row.extend(finals);
            row
        });
        panels.push(Json::obj(vec![
            ("name", Json::Str(b.name.clone())),
            ("n", Json::Num(b.graph.num_nodes() as f64)),
            ("optimum", Json::Num(b.optimum)),
            (
                "curves",
                Json::Obj(
                    results
                        .iter()
                        .map(|(p, c, _)| (p.clone(), Json::arr_f64(c)))
                        .collect(),
                ),
            ),
        ]));
    }
    println!("\n--- Figure 4 ({tag}): final simple regret (mean±sd) ---");
    table.print();
    Json::Arr(panels)
}

/// Figure 4 (a)-(d).
pub fn run_synthetic(args: &Args) -> Json {
    println!("=== BO on synthetic graphs (Fig. 4 a-d) ===");
    let side = args.usize("side", 60);
    let ring_n = args.usize("ring-n", 20000);
    let seeds = args.usize("seeds", 3);
    let cfg = BoConfig {
        n_init: args.usize("n-init", 30),
        n_steps: args.usize("n-steps", 150),
        noise: 0.1,
        walk: WalkConfig {
            n_walks: args.usize("walks", 100),
            p_halt: 0.1,
            max_len: 5,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut rng = Rng::new(7);
    let benchmarks = synthetic_benchmarks(side, ring_n, &mut rng);
    let json = summarise(&benchmarks, &cfg, seeds, "synthetic");
    write_result("bo_synthetic", &json);
    json
}

/// Figure 4 (e)-(h).
pub fn run_social(args: &Args) -> Json {
    println!("=== BO on social networks (Fig. 4 e-h) ===");
    let scale = args.f64("scale", 0.02);
    let seeds = args.usize("seeds", 3);
    let cfg = BoConfig {
        n_init: args.usize("n-init", 50),
        n_steps: args.usize("n-steps", 200),
        noise: 0.1,
        log_transform: true,
        walk: WalkConfig {
            n_walks: args.usize("walks", 100),
            p_halt: 0.1,
            // Raw (unnormalised) adjacency, as in the paper: on raw W
            // the GRF prior variance K̂_ii grows with closed-walk counts
            // (≈ degree), which is precisely the signal hub-finding BO
            // needs. Short walks keep the loads bounded.
            max_len: 3,
            normalize: false,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut rng = Rng::new(8);
    let benchmarks = social_benchmarks(scale, &mut rng);
    let json = summarise(&benchmarks, &cfg, seeds, "social");
    write_result("bo_social", &json);
    json
}

/// Figure 4 (i)-(k).
pub fn run_wind(args: &Args) -> Json {
    println!("=== BO on wind fields (Fig. 4 i-k) ===");
    let res = args.f64("res-deg", 5.0);
    let seeds = args.usize("seeds", 3);
    let cfg = BoConfig {
        n_init: args.usize("n-init", 30),
        n_steps: args.usize("n-steps", 150),
        noise: 0.05,
        walk: WalkConfig {
            n_walks: args.usize("walks", 100),
            p_halt: 0.1,
            max_len: 5,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut rng = Rng::new(9);
    let benchmarks = wind_benchmarks(res, &mut rng);
    let json = summarise(&benchmarks, &cfg, seeds, "wind");
    write_result("bo_wind", &json);
    json
}
