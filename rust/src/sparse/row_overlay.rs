//! Delta row-store overlay over a compacted base CSR — the model-side
//! half of the streaming subsystem's sub-linear patching story.
//!
//! [`crate::stream::StreamingFeatures`] stages patched feature rows in
//! an overlay so a graph delta costs O(touched rows), not an O(nnz)
//! splice. Before this type existed the *model* still paid O(nnz)
//! memcpys per delta batch: Φ was cloned out of the recombiner and Φᵀ
//! spliced through [`Csr::with_replaced_rows`]. [`RowOverlay`] mirrors
//! the stream's overlay inside the model: reads (`row`, the
//! SpMV/SpMM kernels) dispatch overlay-then-base per row, writes
//! ([`RowOverlay::patch_row`]) stage O(row nnz) patches, and
//! [`RowOverlay::compact`] folds everything back into canonical CSR on
//! the same cadence the stream compacts its own overlay.
//!
//! Numerical contract: every kernel replays the CSR per-row
//! accumulation order exactly — a row's entries come either from the
//! overlay patch or the base slice, both sorted by column — so an
//! overlay matrix is **bitwise** interchangeable with its materialised
//! CSR ([`RowOverlay::to_csr`]) in every product. The ELL fast path
//! ([`RowOverlay::select_ell`]) is only offered while compacted, like
//! the stream's `phi_ell`; between compactions the per-row dispatch
//! kernels serve.
//!
//! [`RowOverlay::patch_transpose_rows`] is the shared incremental
//! transpose maintenance: given that rows `R` of a primal matrix
//! changed, it updates `self = primalᵀ` by column-scatter into overlay
//! rows — O(touched rows/entries), bitwise equal to a fresh
//! [`Csr::transpose_par`] of the patched primal. Both
//! `GpModel::apply_graph_delta_batch` and
//! [`crate::sparse::ops::GramOperator::patch_phi_rows`] go through it.

use super::{Csr, Ell, FeatureLayout};
use crate::obs;
use crate::util::parallel;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// A sparse matrix as (compacted base CSR) + (per-row patch overlay).
///
/// The base is held behind an [`Arc`] so cloning an overlay (e.g. to
/// publish an immutable server read snapshot) costs O(overlay rows),
/// not O(nnz): the compacted base is shared, only the patch map is
/// deep-copied. [`RowOverlay::compact`] installs a *new* `Arc`, so
/// clones taken before a compaction keep reading their original base —
/// snapshot isolation for free.
#[derive(Clone, Debug)]
pub struct RowOverlay {
    /// Compacted base; rows not in the overlay read from here.
    base: Arc<Csr>,
    /// Patched rows (sorted by column) staged since the last
    /// compaction. Keys may exceed `base.n_rows` (appended rows).
    overlay: BTreeMap<u32, (Vec<u32>, Vec<f64>)>,
    /// Logical shape (>= base shape while grown rows are pending).
    n_rows: usize,
    n_cols: usize,
    /// Lifetime compaction count — observability for the counter tests
    /// guarding the sub-linear delta path.
    compactions: usize,
}

impl From<Csr> for RowOverlay {
    fn from(base: Csr) -> RowOverlay {
        let (n_rows, n_cols) = (base.n_rows, base.n_cols);
        RowOverlay {
            base: Arc::new(base),
            overlay: BTreeMap::new(),
            n_rows,
            n_cols,
            compactions: 0,
        }
    }
}

impl RowOverlay {
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Rows currently staged in the overlay.
    pub fn overlay_rows(&self) -> usize {
        self.overlay.len()
    }

    /// Lifetime count of [`RowOverlay::compact`] calls that folded a
    /// non-empty overlay (the O(nnz) splices the delta path avoids).
    pub fn compactions(&self) -> usize {
        self.compactions
    }

    /// Whether reads can go straight to the base CSR (no overlay rows,
    /// no pending growth).
    pub fn is_compacted(&self) -> bool {
        self.overlay.is_empty()
            && self.base.n_rows == self.n_rows
            && self.base.n_cols == self.n_cols
    }

    /// The compacted base. Rows in the overlay shadow it; callers that
    /// need exact current content should use [`RowOverlay::row`].
    pub fn base(&self) -> &Csr {
        self.base.as_ref()
    }

    /// Logical stored nonzeros (base rows not shadowed + overlay rows).
    pub fn nnz(&self) -> usize {
        let mut nnz = self.base.nnz();
        for (&r, (cols, _)) in &self.overlay {
            if (r as usize) < self.base.n_rows {
                let (bc, _) = self.base.row(r as usize);
                nnz -= bc.len();
            }
            nnz += cols.len();
        }
        nnz
    }

    /// Row `i`, overlay-then-base dispatch. Rows beyond the base that
    /// have no patch yet are empty.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        debug_assert!(i < self.n_rows);
        if let Some((cols, vals)) = self.overlay.get(&(i as u32)) {
            (cols, vals)
        } else if i < self.base.n_rows {
            self.base.row(i)
        } else {
            (&[], &[])
        }
    }

    /// Grow the logical shape (monotone; node insertion). Reads of the
    /// new rows return empty until they are patched.
    pub fn grow(&mut self, n_rows: usize, n_cols: usize) {
        assert!(n_rows >= self.n_rows && n_cols >= self.n_cols);
        self.n_rows = n_rows;
        self.n_cols = n_cols;
    }

    /// Stage new content for row `r` (sorted by column, `< n_cols`) —
    /// O(row nnz), no splice. `r` may address a freshly grown row.
    pub fn patch_row(&mut self, r: u32, cols: Vec<u32>, vals: Vec<f64>) {
        assert!((r as usize) < self.n_rows, "row {r} out of range");
        assert_eq!(cols.len(), vals.len());
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "row not sorted");
        // Hard bound check once per patch: the SpMV/SpMM inner loops
        // gather x unchecked against this invariant.
        for &c in &cols {
            assert!((c as usize) < self.n_cols, "col {c} out of range");
        }
        self.overlay.insert(r, (cols, vals));
    }

    /// Fold the overlay into the base (one O(nnz) splice) and clear it.
    /// No-op while compacted, so it is safe to call on any cadence.
    pub fn compact(&mut self) {
        if self.is_compacted() {
            return;
        }
        self.base = Arc::new(self.base.with_replaced_rows(
            self.n_rows,
            self.n_cols,
            &self.overlay,
        ));
        self.overlay.clear();
        self.compactions += 1;
    }

    /// Materialise the current content as canonical CSR (clone of the
    /// base when compacted).
    pub fn to_csr(&self) -> Csr {
        if self.is_compacted() {
            return self.base.as_ref().clone();
        }
        self.base
            .with_replaced_rows(self.n_rows, self.n_cols, &self.overlay)
    }

    /// Dense expansion (tests / small-N oracles only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0; self.n_cols]; self.n_rows];
        for (r, row) in out.iter_mut().enumerate() {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                row[*c as usize] += v;
            }
        }
        out
    }

    /// Transpose of the current content as CSR (tests / construction).
    pub fn transpose(&self) -> Csr {
        self.to_csr().transpose()
    }

    /// Thread-parallel transpose of the current content, bitwise equal
    /// to [`RowOverlay::transpose`]. Skips the materialise clone when
    /// compacted.
    pub fn transpose_par(&self, threads: usize) -> Csr {
        if self.is_compacted() {
            self.base.transpose_par(threads)
        } else {
            self.to_csr().transpose_par(threads)
        }
    }

    /// Run the ELL layout policy — only while compacted (an overlay
    /// pre-empts the packed operand exactly like the stream's
    /// `phi_ell`; the per-row dispatch kernels serve until the next
    /// compaction re-runs `to_ell_auto`).
    pub fn select_ell(&self, layout: FeatureLayout) -> Option<Ell> {
        if self.is_compacted() {
            self.base.select_ell(layout)
        } else {
            None
        }
    }

    // ------------------------------------------------------------------
    // Kernels: bitwise the CSR kernels on the same logical matrix.
    // ------------------------------------------------------------------

    /// Rows [s, e) of y = A x into `out[0..e-s]` — the CSR inner loop
    /// with per-row overlay dispatch.
    #[inline]
    fn rows_matvec(&self, x: &[f64], s: usize, e: usize, out: &mut [f64]) {
        for i in s..e {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                // SAFETY: *c < n_cols == x.len(); base rows by CSR
                // construction, overlay rows asserted in `patch_row`.
                acc += v * unsafe { x.get_unchecked(*c as usize) };
            }
            out[i - s] = acc;
        }
    }

    /// Rows [s, e) of Y = A X (row-major `ncols` block) into `out`.
    #[inline]
    fn rows_matmat(&self, x: &[f64], ncols: usize, s: usize, e: usize, out: &mut [f64]) {
        for i in s..e {
            let (cols, vals) = self.row(i);
            let yi = &mut out[(i - s) * ncols..(i - s + 1) * ncols];
            yi.fill(0.0);
            for (c, v) in cols.iter().zip(vals) {
                let base = *c as usize * ncols;
                // SAFETY: *c < n_cols (see rows_matvec), so the slice is
                // in bounds by the callers' hard-asserted shape contract.
                let xr = unsafe { x.get_unchecked(base..base + ncols) };
                for (yj, xj) in yi.iter_mut().zip(xr) {
                    *yj += v * xj;
                }
            }
        }
    }

    /// y = A x into a caller-provided buffer (serial).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        if self.is_compacted() {
            return self.base.matvec_into(x, y);
        }
        self.rows_matvec(x, 0, self.n_rows, y);
    }

    /// Allocating wrapper over [`RowOverlay::matvec_into`].
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Thread-parallel y = A x over disjoint row chunks,
    /// allocation-free.
    pub fn matvec_par_into(&self, x: &[f64], y: &mut [f64], threads: usize) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        if self.is_compacted() {
            return self.base.matvec_par_into(x, y, threads);
        }
        parallel::par_rows_mut(y, 1, threads, |s, e, ys| {
            self.rows_matvec(x, s, e, ys);
        });
    }

    /// Allocating wrapper over [`RowOverlay::matvec_par_into`].
    pub fn matvec_par(&self, x: &[f64], threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_par_into(x, &mut y, threads);
        y
    }

    /// SpMM Y = A X over a row-major `n_cols × ncols` block.
    pub fn matmat_into(&self, x: &[f64], ncols: usize, y: &mut [f64]) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        if self.is_compacted() {
            return self.base.matmat_into(x, ncols, y);
        }
        self.rows_matmat(x, ncols, 0, self.n_rows, y);
    }

    /// Allocating wrapper over [`RowOverlay::matmat_into`].
    pub fn matmat(&self, x: &[f64], ncols: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_into(x, ncols, &mut y);
        y
    }

    /// Thread-parallel SpMM over disjoint row chunks, allocation-free.
    pub fn matmat_par_into(&self, x: &[f64], ncols: usize, y: &mut [f64], threads: usize) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        if self.is_compacted() {
            return self.base.matmat_par_into(x, ncols, y, threads);
        }
        parallel::par_rows_mut(y, ncols, threads, |s, e, rows| {
            self.rows_matmat(x, ncols, s, e, rows);
        });
    }

    /// Allocating wrapper over [`RowOverlay::matmat_par_into`].
    pub fn matmat_par(&self, x: &[f64], ncols: usize, threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_par_into(x, ncols, &mut y, threads);
        y
    }

    /// y = A x through the selected operand: the ELL when a layout
    /// policy produced one (valid only while compacted — callers hold
    /// selections from [`RowOverlay::select_ell`], which returns `None`
    /// otherwise), the overlay-aware CSR path else. The overlay-aware
    /// sibling of [`crate::sparse::ell::spmv_dispatch`].
    #[inline]
    pub fn spmv(&self, ell: Option<&Ell>, x: &[f64], y: &mut [f64], threads: usize, par: bool) {
        // Dispatch time by selected layout (obs spans are inert —
        // skipping even `Instant::now` — when telemetry is off).
        match ell {
            Some(e) => {
                obs::registry::SPMV_ELL.inc();
                let _s = obs::span::Span::new(&obs::registry::SPMV_ELL_NS);
                if par {
                    e.matvec_par_into(x, y, threads)
                } else {
                    e.matvec_into(x, y)
                }
            }
            None => {
                obs::registry::SPMV_CSR.inc();
                let _s = obs::span::Span::new(&obs::registry::SPMV_CSR_NS);
                if par {
                    self.matvec_par_into(x, y, threads)
                } else {
                    self.matvec_into(x, y)
                }
            }
        }
    }

    /// Blocked Y = A X through the selected operand (see
    /// [`RowOverlay::spmv`]) — the overlay-aware sibling of
    /// [`crate::sparse::ell::spmm_dispatch`].
    #[inline]
    pub fn spmm(
        &self,
        ell: Option<&Ell>,
        x: &[f64],
        ncols: usize,
        y: &mut [f64],
        threads: usize,
        par: bool,
    ) {
        match ell {
            Some(e) => {
                obs::registry::SPMM_ELL.inc();
                let _s = obs::span::Span::new(&obs::registry::SPMM_ELL_NS);
                if par {
                    e.matmat_par_into(x, ncols, y, threads)
                } else {
                    e.matmat_into(x, ncols, y)
                }
            }
            None => {
                obs::registry::SPMM_CSR.inc();
                let _s = obs::span::Span::new(&obs::registry::SPMM_CSR_NS);
                if par {
                    self.matmat_par_into(x, ncols, y, threads)
                } else {
                    self.matmat_into(x, ncols, y)
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Incremental transpose maintenance
    // ------------------------------------------------------------------

    /// Column-scatter the changed primal rows into `self = primalᵀ`.
    ///
    /// `affected` (sorted ascending) are the primal rows whose content
    /// changed; `old_supports` their column supports *before* the
    /// change (the transpose rows that must drop entries — additions
    /// are read off the current `primal`). Changing primal rows `R`
    /// changes exactly the transpose rows in
    /// `∪_r (old support ∪ new support)`: each such row drops its
    /// entries with column ∈ R and merge-inserts the fresh entries
    /// (sorted by source row, values copied). The merged rows are
    /// staged as overlay patches — O(touched rows + touched nnz), no
    /// splice — and the result is **bitwise** the full
    /// [`Csr::transpose_par`] of the patched primal (same per-row
    /// ordering: source rows ascending, same value bits).
    ///
    /// The shape is grown to `primal`'s transposed shape first, so a
    /// freshly appended primal row (a new column of the transpose)
    /// scatters into a correctly sized matrix rather than a
    /// stale-width one.
    pub fn patch_transpose_rows(
        &mut self,
        primal: &RowOverlay,
        affected: &[u32],
        old_supports: &[(u32, Vec<u32>)],
    ) {
        debug_assert!(affected.windows(2).all(|w| w[0] < w[1]));
        self.grow(primal.n_cols(), primal.n_rows());
        // Fresh entries of the affected primal rows, bucketed per
        // column j. `affected` is sorted ascending, so each bucket
        // comes out sorted by source row.
        let mut adds: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = BTreeMap::new();
        for &r in affected {
            let (cols, vals) = primal.row(r as usize);
            for (c, v) in cols.iter().zip(vals) {
                let e = adds.entry(*c).or_default();
                e.0.push(r);
                e.1.push(*v);
            }
        }
        let mut touched: BTreeSet<u32> = adds.keys().copied().collect();
        for (_, cols) in old_supports {
            touched.extend(cols.iter().copied());
        }
        // Merge each touched transpose row against its current content
        // (overlay-aware read), then stage the results. The reads all
        // complete before the first write, so a row merged later never
        // sees a half-patched sibling.
        let empty = (Vec::new(), Vec::new());
        let mut patches: Vec<(u32, Vec<u32>, Vec<f64>)> =
            Vec::with_capacity(touched.len());
        for &j in &touched {
            let (oc, ov) = self.row(j as usize);
            let (ac, av) = adds.get(&j).unwrap_or(&empty);
            let mut cols = Vec::with_capacity(oc.len() + ac.len());
            let mut vals = Vec::with_capacity(oc.len() + ac.len());
            let mut ai = 0;
            for (c, v) in oc.iter().zip(ov) {
                if affected.binary_search(c).is_ok() {
                    continue; // this column's primal row was rebuilt: drop
                }
                while ai < ac.len() && ac[ai] < *c {
                    cols.push(ac[ai]);
                    vals.push(av[ai]);
                    ai += 1;
                }
                cols.push(*c);
                vals.push(*v);
            }
            while ai < ac.len() {
                cols.push(ac[ai]);
                vals.push(av[ai]);
                ai += 1;
            }
            patches.push((j, cols, vals));
        }
        for (j, cols, vals) in patches {
            self.patch_row(j, cols, vals);
        }
    }
}

/// Logical equality: same shape, same per-row content (bitwise values)
/// regardless of how rows are split between base and overlay.
impl PartialEq for RowOverlay {
    fn eq(&self, other: &RowOverlay) -> bool {
        if self.n_rows != other.n_rows || self.n_cols != other.n_cols {
            return false;
        }
        (0..self.n_rows).all(|r| self.row(r) == other.row(r))
    }
}

/// Logical equality against a materialised CSR (shape + rows).
impl PartialEq<Csr> for RowOverlay {
    fn eq(&self, other: &Csr) -> bool {
        if self.n_rows != other.n_rows || self.n_cols != other.n_cols {
            return false;
        }
        (0..self.n_rows).all(|r| self.row(r) == other.row(r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sparse::CooBuilder;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, n_rows: usize, n_cols: usize, nnz: usize) -> Csr {
        let mut b = CooBuilder::new(n_rows, n_cols);
        for _ in 0..nnz {
            b.push(
                rng.below(n_rows) as u32,
                rng.below(n_cols) as u32,
                rng.normal(),
            );
        }
        b.build()
    }

    fn random_row(rng: &mut Rng, n_cols: usize, width: usize) -> (Vec<u32>, Vec<f64>) {
        let mut cols: Vec<u32> =
            (0..width).map(|_| rng.below(n_cols) as u32).collect();
        cols.sort_unstable();
        cols.dedup();
        let vals: Vec<f64> = cols.iter().map(|_| rng.normal()).collect();
        (cols, vals)
    }

    /// Patch random rows (including grown ones), then compare every
    /// read and every kernel bitwise against the materialised CSR.
    #[test]
    fn overlay_reads_and_kernels_match_materialised_csr_bitwise() {
        proptest(16, |rng| {
            let n = 4 + rng.below(20);
            let m = 4 + rng.below(20);
            let base = random_csr(rng, n, m, 3 * n);
            let mut ov = RowOverlay::from(base.clone());
            let (gn, gm) = (n + rng.below(3), m + rng.below(3));
            ov.grow(gn, gm);
            let n_patch = 1 + rng.below(5);
            for _ in 0..n_patch {
                let r = rng.below(gn) as u32;
                let (cols, vals) = random_row(rng, gm, 1 + rng.below(5));
                ov.patch_row(r, cols, vals);
            }
            let reference = ov.to_csr();
            prop_assert!(ov == reference, "PartialEq<Csr> disagrees");
            for r in 0..gn {
                let (oc, ovl) = ov.row(r);
                let (rc, rv) = reference.row(r);
                prop_assert!(oc == rc && ovl == rv, "row {r} differs");
            }
            prop_assert!(ov.nnz() == reference.nnz(), "nnz accounting");
            let x: Vec<f64> = (0..gm).map(|_| rng.normal()).collect();
            let y = ov.matvec(&x);
            prop_assert!(y == reference.matvec(&x), "matvec differs");
            prop_assert!(
                ov.matvec_par(&x, 4) == y,
                "parallel matvec differs from serial"
            );
            let b = 1 + rng.below(4);
            let xb: Vec<f64> = (0..gm * b).map(|_| rng.normal()).collect();
            let yb = ov.matmat(&xb, b);
            prop_assert!(yb == reference.matmat(&xb, b), "matmat differs");
            prop_assert!(
                ov.matmat_par(&xb, b, 3) == yb,
                "parallel matmat differs from serial"
            );
            // Compaction folds without changing a bit, and re-enables
            // the packed operand selection.
            let comp_before = ov.compactions();
            ov.compact();
            prop_assert!(ov.is_compacted(), "compact must clear the overlay");
            prop_assert!(ov.compactions() == comp_before + 1, "counter");
            prop_assert!(ov == reference, "compaction changed content");
            prop_assert!(ov.matvec(&x) == y, "compacted matvec differs");
            Ok(())
        });
    }

    #[test]
    fn select_ell_only_when_compacted() {
        let mut rng = Rng::new(5);
        // Near-uniform rows so Auto accepts.
        let mut b = CooBuilder::new(32, 32);
        for i in 0..32u32 {
            for k in 0..4u32 {
                b.push(i, (i + k) % 32, rng.normal());
            }
        }
        let csr = b.build();
        let mut ov = RowOverlay::from(csr);
        assert!(ov.select_ell(FeatureLayout::Auto).is_some());
        ov.patch_row(3, vec![1, 5], vec![0.5, -0.5]);
        assert!(
            ov.select_ell(FeatureLayout::Auto).is_none(),
            "overlay must pre-empt the packed operand"
        );
        ov.compact();
        assert!(ov.select_ell(FeatureLayout::Auto).is_some());
    }

    /// patch_transpose_rows == transpose_par of the patched primal,
    /// bitwise, across repeated patch generations and growth.
    #[test]
    fn patch_transpose_rows_matches_full_transpose_bitwise() {
        proptest(16, |rng| {
            let n = 5 + rng.below(15);
            let base = random_csr(rng, n, n, 3 * n);
            let mut primal = RowOverlay::from(base.clone());
            let mut t = RowOverlay::from(base.transpose());
            for generation in 0..3 {
                // Maybe grow (square: node insertion semantics).
                let gn = primal.n_rows() + rng.below(2);
                primal.grow(gn, gn);
                let mut rows: Vec<u32> =
                    (0..1 + rng.below(4)).map(|_| rng.below(gn) as u32).collect();
                rows.sort_unstable();
                rows.dedup();
                let old_supports: Vec<(u32, Vec<u32>)> = rows
                    .iter()
                    .map(|&r| (r, primal.row(r as usize).0.to_vec()))
                    .collect();
                for &r in &rows {
                    let (cols, vals) = random_row(rng, gn, 1 + rng.below(5));
                    primal.patch_row(r, cols, vals);
                }
                t.patch_transpose_rows(&primal, &rows, &old_supports);
                let full = primal.to_csr().transpose_par(2);
                prop_assert!(
                    t == full,
                    "generation {generation}: patched transpose != full"
                );
            }
            Ok(())
        });
    }
}
