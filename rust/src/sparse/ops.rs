//! Gram-matrix operators built from GRF feature matrices.
//!
//! The whole GP hot path reduces to products with
//! `A = m (Φ Φᵀ) m + σ² I` (mask m selects training nodes). `K = ΦΦᵀ`
//! is never materialised: each product is two sparse matvecs
//! (paper §3.2, Theorem 2 property 1).
//!
//! For multi-RHS solves, [`GramOperator::apply_block`] evaluates the
//! operator on a whole row-major `n × B` block with **two SpMMs**
//! instead of `2B` SpMVs, and [`GramOperator::jacobi_diag`] extracts
//! `diag(A)` in `O(nnz(Φ))` from masked row norms for Jacobi
//! preconditioning of the block-CG.
//!
//! The SpMV/SpMM operands are selected per matrix by a
//! [`FeatureLayout`] policy (default [`FeatureLayout::Auto`]): when
//! Φ's row widths are regular enough, the applications run through the
//! native ELL layout — bit-identical in f64, and with optionally
//! f32-stored values ([`FeatureLayout::EllF32`]) that halve the value
//! traffic of the bandwidth-bound kernels.
//!
//! Φ and Φᵀ are held as [`RowOverlay`]s: a streaming caller can patch
//! individual feature rows ([`GramOperator::patch_phi_rows`]) in
//! O(touched nnz) — Φᵀ maintained by incremental column-scatter, no
//! splice, no transpose — and every apply path dispatches
//! overlay-then-base per row, bitwise identical to the compacted
//! operator. The packed ELL operands are only selected while the
//! overlays are compacted (an overlay pre-empts them, exactly as in
//! `GpModel`).

use super::{Csr, Ell, FeatureLayout, RowOverlay};
use crate::util::parallel;

/// Reusable operator around Φ (and its incrementally maintained
/// transpose).
pub struct GramOperator {
    pub phi: RowOverlay,
    pub phi_t: RowOverlay,
    /// Observation-noise variance σ².
    pub sigma2: f64,
    /// Optional {0,1} training mask (None = all nodes).
    pub mask: Option<Vec<f64>>,
    /// Worker threads for the two SpMVs (1 = serial).
    pub threads: usize,
    // Layout policy + the ELL operands it selected (None = CSR).
    // Built lazily on first application (so a `with_layout` right
    // after `new` never pays for a discarded selection); `phi`/`phi_t`
    // stay the source of truth for everything that needs exact f64
    // entries.
    layout: FeatureLayout,
    ops_ready: bool,
    phi_ell: Option<Ell>,
    phi_t_ell: Option<Ell>,
    // Scratch buffers so repeated applies don't allocate.
    buf_mid: Vec<f64>,
    buf_in: Vec<f64>,
    // Block-sized scratch for apply_block (lazily grown to n·B / k·B).
    blk_mid: Vec<f64>,
    blk_in: Vec<f64>,
}

impl GramOperator {
    /// Build from a feature matrix — a CSR (wrapped as a compacted
    /// overlay) or an existing [`RowOverlay`]; the transpose operand
    /// is derived fresh either way.
    pub fn new(phi: impl Into<RowOverlay>, sigma2: f64) -> GramOperator {
        let phi: RowOverlay = phi.into();
        // Bit-identical to the serial transpose; pays off at the sizes
        // where the gram operator is actually used.
        let phi_t = RowOverlay::from(phi.transpose_par(parallel::num_threads()));
        let mid = phi.n_cols();
        let n = phi.n_rows();
        GramOperator {
            phi,
            phi_t,
            sigma2,
            mask: None,
            threads: 1,
            layout: FeatureLayout::Auto,
            ops_ready: false,
            phi_ell: None,
            phi_t_ell: None,
            buf_mid: vec![0.0; mid],
            buf_in: vec![0.0; n],
            blk_mid: Vec::new(),
            blk_in: Vec::new(),
        }
    }

    pub fn with_mask(mut self, mask: Vec<f64>) -> Self {
        assert_eq!(mask.len(), self.phi.n_rows());
        self.mask = Some(mask);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Re-select the SpMV/SpMM operands under `layout` (per matrix:
    /// Φ and Φᵀ decide independently under [`FeatureLayout::Auto`]).
    /// Like construction, the selection itself runs lazily at the next
    /// application.
    pub fn with_layout(mut self, layout: FeatureLayout) -> Self {
        if layout != self.layout {
            self.layout = layout;
            self.ops_ready = false;
            self.phi_ell = None;
            self.phi_t_ell = None;
        }
        self
    }

    pub fn layout(&self) -> FeatureLayout {
        self.layout
    }

    /// Build the ELL operands for the current layout if not done yet.
    fn ensure_ops(&mut self) {
        if !self.ops_ready {
            self.phi_ell = self.phi.select_ell(self.layout);
            self.phi_t_ell = self.phi_t.select_ell(self.layout);
            self.ops_ready = true;
        }
    }

    /// Human-readable operand selection, e.g. `"ell(w=6)/csr"` for
    /// (Φ, Φᵀ) — surfaced by benches and diagnostics.
    pub fn layout_desc(&mut self) -> String {
        self.ensure_ops();
        let one = |e: &Option<Ell>| match e {
            Some(e) if e.uses_f32() => format!("ell_f32(w={})", e.width),
            Some(e) => format!("ell(w={})", e.width),
            None => "csr".to_string(),
        };
        format!("{}/{}", one(&self.phi_ell), one(&self.phi_t_ell))
    }

    pub fn n(&self) -> usize {
        self.phi.n_rows()
    }

    /// Number of stored nonzeros in Φ (the paper's O(N) memory object).
    pub fn nnz(&self) -> usize {
        self.phi.nnz()
    }

    /// y = m Φ Φᵀ m x + σ² x  (in-place into `y`).
    pub fn apply_into(&mut self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);
        self.ensure_ops();
        let par = self.threads > 1 && n > 4096;
        let masked_x: &[f64] = match &self.mask {
            Some(m) => {
                for i in 0..n {
                    self.buf_in[i] = m[i] * x[i];
                }
                &self.buf_in
            }
            None => x,
        };
        // Same scratch discipline on every operand/thread combination:
        // no allocation per application.
        self.phi_t.spmv(
            self.phi_t_ell.as_ref(),
            masked_x,
            &mut self.buf_mid,
            self.threads,
            par,
        );
        self.phi.spmv(
            self.phi_ell.as_ref(),
            &self.buf_mid,
            y,
            self.threads,
            par,
        );
        match &self.mask {
            Some(m) => {
                for i in 0..n {
                    y[i] = m[i] * y[i] + self.sigma2 * x[i];
                }
            }
            None => {
                for i in 0..n {
                    y[i] += self.sigma2 * x[i];
                }
            }
        }
    }

    pub fn apply(&mut self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n()];
        self.apply_into(x, &mut y);
        y
    }

    /// Blocked operator application: `Y = m Φ Φᵀ m X + σ² X` for a
    /// row-major `n × ncols` block, computed as two SpMMs. One pass
    /// over Φᵀ and one over Φ serve all `ncols` right-hand sides, so
    /// the (bandwidth-bound) matrix traffic is amortised `ncols`×.
    /// Scratch blocks are reused across calls; nothing is allocated
    /// after the first application at a given width.
    pub fn apply_block_into(&mut self, x: &[f64], ncols: usize, y: &mut [f64]) {
        assert!(ncols > 0, "block width must be positive");
        let n = self.n();
        let k = self.phi.n_cols();
        debug_assert_eq!(x.len(), n * ncols);
        debug_assert_eq!(y.len(), n * ncols);
        self.ensure_ops();
        self.blk_mid.resize(k * ncols, 0.0);
        let masked_x: &[f64] = match &self.mask {
            Some(m) => {
                self.blk_in.resize(n * ncols, 0.0);
                for i in 0..n {
                    let mi = m[i];
                    let base = i * ncols;
                    for j in 0..ncols {
                        self.blk_in[base + j] = mi * x[base + j];
                    }
                }
                &self.blk_in
            }
            None => x,
        };
        let par = self.threads > 1 && n > 4096;
        self.phi_t.spmm(
            self.phi_t_ell.as_ref(),
            masked_x,
            ncols,
            &mut self.blk_mid,
            self.threads,
            par,
        );
        self.phi.spmm(
            self.phi_ell.as_ref(),
            &self.blk_mid,
            ncols,
            y,
            self.threads,
            par,
        );
        match &self.mask {
            Some(m) => {
                for i in 0..n {
                    let mi = m[i];
                    let base = i * ncols;
                    for j in 0..ncols {
                        y[base + j] = mi * y[base + j] + self.sigma2 * x[base + j];
                    }
                }
            }
            None => {
                for (yi, xi) in y.iter_mut().zip(x) {
                    *yi += self.sigma2 * xi;
                }
            }
        }
    }

    /// Allocating convenience wrapper over [`GramOperator::apply_block_into`].
    pub fn apply_block(&mut self, x: &[f64], ncols: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n() * ncols];
        self.apply_block_into(x, ncols, &mut y);
        y
    }

    /// Diagonal of the operator, `diag(A)_i = m_i ‖φ_i‖² + σ²`. See
    /// [`jacobi_diag`].
    pub fn jacobi_diag(&self) -> Vec<f64> {
        jacobi_diag(&self.phi, self.mask.as_deref(), self.sigma2)
    }

    /// Kernel product without noise or mask: y = Φ (Φᵀ x).
    pub fn kernel_apply(&mut self, x: &[f64]) -> Vec<f64> {
        self.phi_t.matvec_into(x, &mut self.buf_mid);
        let mut y = vec![0.0; self.n()];
        self.phi.matvec_into(&self.buf_mid, &mut y);
        y
    }

    /// Patch feature rows through the overlays — the streaming caller's
    /// O(touched nnz) path: Φ rows `(r, cols, vals)` (sorted by row
    /// index AND by column within a row) replace their predecessors,
    /// and Φᵀ is maintained by incremental column-scatter
    /// ([`RowOverlay::patch_transpose_rows`], bitwise equal to a full
    /// transpose of the patched Φ). `n` grows the operator for appended
    /// rows; the packed ELL operands re-select lazily at the next
    /// application (pre-empted while an overlay is live).
    pub fn patch_phi_rows(&mut self, n: usize, rows: &[(u32, Vec<u32>, Vec<f64>)]) {
        debug_assert!(rows.windows(2).all(|w| w[0].0 < w[1].0));
        // Growth conflates rows and columns, which is only meaningful
        // for the square (node-feature) operator; a rectangular Φ must
        // not be silently widened.
        assert_eq!(
            self.phi.n_rows(),
            self.phi.n_cols(),
            "patch_phi_rows growth requires a square Φ"
        );
        let affected: Vec<u32> = rows.iter().map(|(r, _, _)| *r).collect();
        self.phi.grow(n, n);
        let old_supports: Vec<(u32, Vec<u32>)> = affected
            .iter()
            .map(|&r| (r, self.phi.row(r as usize).0.to_vec()))
            .collect();
        for (r, cols, vals) in rows {
            self.phi.patch_row(*r, cols.clone(), vals.clone());
        }
        self.phi_t
            .patch_transpose_rows(&self.phi, &affected, &old_supports);
        if let Some(m) = &mut self.mask {
            m.resize(n, 0.0);
        }
        self.buf_in.resize(n, 0.0);
        self.buf_mid.resize(self.phi.n_cols(), 0.0);
        self.ops_ready = false;
        self.phi_ell = None;
        self.phi_t_ell = None;
    }

    /// Fold the Φ/Φᵀ overlays back into compacted bases (one O(nnz)
    /// splice each) and let the layout policy re-select.
    pub fn compact(&mut self) {
        self.phi.compact();
        self.phi_t.compact();
        self.ops_ready = false;
        self.phi_ell = None;
        self.phi_t_ell = None;
    }

    /// Prior sample g = Φ w, Cov(g) = ΦΦᵀ = K̂ (paper §3.2).
    pub fn prior_sample(&self, w: &[f64]) -> Vec<f64> {
        debug_assert_eq!(w.len(), self.phi.n_cols());
        if self.threads > 1 && self.n() > 4096 {
            self.phi.matvec_par(w, self.threads)
        } else {
            self.phi.matvec(w)
        }
    }

    /// Single kernel entry K̂[i,j] = φ(i)·φ(j) (sorted-row merge).
    pub fn kernel_entry(&self, i: usize, j: usize) -> f64 {
        let (ci, vi) = self.phi.row(i);
        let (cj, vj) = self.phi.row(j);
        let mut a = 0;
        let mut b = 0;
        let mut acc = 0.0;
        while a < ci.len() && b < cj.len() {
            match ci[a].cmp(&cj[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    acc += vi[a] * vj[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        acc
    }

    /// Materialise one row of K̂ (used by small exact comparisons).
    pub fn kernel_row(&mut self, i: usize) -> Vec<f64> {
        let n = self.n();
        let mut e = vec![0.0; n];
        e[i] = 1.0;
        self.kernel_apply(&e)
    }
}

/// Jacobi preconditioner diagonal of `m Φ Φᵀ m + σ² I` in one
/// `O(nnz(Φ))` pass: `d_i = m_i ‖φ_i‖² + σ²` (masked-out rows of the
/// operator are `σ² e_i`, and `m_i ∈ {0,1}` makes `m_i² = m_i`).
/// Shared by [`GramOperator::jacobi_diag`] and `GpModel::jacobi_diag`
/// so the preconditioner has exactly one definition. Rows read through
/// the overlay dispatch, so a patched-but-uncompacted Φ contributes
/// its current content.
pub fn jacobi_diag(phi: &RowOverlay, mask: Option<&[f64]>, sigma2: f64) -> Vec<f64> {
    let n = phi.n_rows();
    let mut d = vec![sigma2; n];
    for i in 0..n {
        if let Some(m) = mask {
            if m[i] == 0.0 {
                continue;
            }
        }
        let (_, vals) = phi.row(i);
        let mut acc = 0.0;
        for v in vals {
            acc += v * v;
        }
        d[i] += acc;
    }
    d
}

/// Batched gram matvec over R right-hand sides (column-major layout:
/// `x[r]` is the r-th vector). Parallelises over RHS — the Hutchinson
/// probe batch in LML training.
pub fn gram_matmat(op_phi: &Csr, op_phi_t: &Csr, mask: Option<&[f64]>,
                   sigma2: f64, xs: &[Vec<f64>], threads: usize) -> Vec<Vec<f64>> {
    parallel::par_map(xs, threads, |x| {
        let n = op_phi.n_rows;
        let masked: Vec<f64> = match mask {
            Some(m) => m.iter().zip(x).map(|(mi, xi)| mi * xi).collect(),
            None => x.clone(),
        };
        let mid = op_phi_t.matvec(&masked);
        let mut y = op_phi.matvec(&mid);
        match mask {
            Some(m) => {
                for i in 0..n {
                    y[i] = m[i] * y[i] + sigma2 * x[i];
                }
            }
            None => {
                for i in 0..n {
                    y[i] += sigma2 * x[i];
                }
            }
        }
        y
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sparse::CooBuilder;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    fn random_phi(rng: &mut Rng, n: usize) -> Csr {
        let mut b = CooBuilder::new(n, n);
        for i in 0..n {
            for _ in 0..3 {
                b.push(i as u32, rng.below(n) as u32, 0.4 * rng.normal());
            }
        }
        b.build()
    }

    fn dense_gram(phi: &Csr) -> Vec<Vec<f64>> {
        let d = phi.to_dense();
        let n = phi.n_rows;
        let mut k = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                k[i][j] = (0..phi.n_cols).map(|c| d[i][c] * d[j][c]).sum();
            }
        }
        k
    }

    #[test]
    fn gram_apply_matches_dense() {
        proptest(24, |rng| {
            let n = 2 + rng.below(30);
            let phi = random_phi(rng, n);
            let k = dense_gram(&phi);
            let mask: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 }).collect();
            let sigma2 = 0.3;
            let mut op = GramOperator::new(phi, sigma2).with_mask(mask.clone());
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = op.apply(&x);
            for i in 0..n {
                let kmx: f64 = (0..n).map(|j| k[i][j] * mask[j] * x[j]).sum();
                let expect = mask[i] * kmx + sigma2 * x[i];
                prop_assert!(
                    (y[i] - expect).abs() < 1e-9,
                    "i={i}: {} vs {expect}",
                    y[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn gram_is_symmetric_psd() {
        proptest(12, |rng| {
            let n = 2 + rng.below(20);
            let phi = random_phi(rng, n);
            let mut op = GramOperator::new(phi, 0.0);
            // Symmetry: x'A y == y'A x; PSD: x'A x >= 0.
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ax = op.kernel_apply(&x);
            let ay = op.kernel_apply(&y);
            let xay: f64 = x.iter().zip(&ay).map(|(a, b)| a * b).sum();
            let yax: f64 = y.iter().zip(&ax).map(|(a, b)| a * b).sum();
            prop_assert!((xay - yax).abs() < 1e-8 * (1.0 + xay.abs()), "symmetry");
            let xax: f64 = x.iter().zip(&ax).map(|(a, b)| a * b).sum();
            prop_assert!(xax >= -1e-9, "psd violated: {xax}");
            Ok(())
        });
    }

    #[test]
    fn apply_block_matches_per_column_apply() {
        proptest(16, |rng| {
            let n = 2 + rng.below(30);
            let ncols = 1 + rng.below(6);
            let phi = random_phi(rng, n);
            let mask: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 }).collect();
            let mut op = GramOperator::new(phi, 0.3).with_mask(mask);
            let cols: Vec<Vec<f64>> = (0..ncols)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            let mut block = vec![0.0; n * ncols];
            for (j, c) in cols.iter().enumerate() {
                for i in 0..n {
                    block[i * ncols + j] = c[i];
                }
            }
            let yb = op.apply_block(&block, ncols);
            for (j, c) in cols.iter().enumerate() {
                let y = op.apply(c);
                for i in 0..n {
                    prop_assert!(
                        yb[i * ncols + j] == y[i],
                        "col {j} row {i}: block {} vs single {}",
                        yb[i * ncols + j],
                        y[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn jacobi_diag_matches_operator_diagonal() {
        proptest(16, |rng| {
            let n = 2 + rng.below(25);
            let phi = random_phi(rng, n);
            let mask: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            let sigma2 = 0.17;
            let mut op = GramOperator::new(phi, sigma2).with_mask(mask);
            let d = op.jacobi_diag();
            for i in 0..n {
                let mut e = vec![0.0; n];
                e[i] = 1.0;
                let a_e = op.apply(&e);
                prop_assert!(
                    (d[i] - a_e[i]).abs() < 1e-10 * (1.0 + a_e[i].abs()),
                    "diag {i}: {} vs {}",
                    d[i],
                    a_e[i]
                );
                prop_assert!(d[i] >= sigma2, "diag {i} below sigma2");
            }
            Ok(())
        });
    }

    #[test]
    fn preconditioned_block_cg_on_illconditioned_gram() {
        // Diffusion-style ill conditioning: tiny noise floor makes
        // kappa(H) large. Jacobi-preconditioned block CG must agree
        // with the unpreconditioned solve and use no more iterations.
        use crate::linalg::cg::block_cg_solve;
        let mut rng = Rng::new(11);
        let n = 120;
        let mut b = CooBuilder::new(n, n);
        for i in 0..n {
            // Strong diagonal with wildly varying row scales plus a few
            // off-diagonal couplings (kappa(H) ~ 1e4 against the 1e-4
            // noise floor, so CG error stays ~kappa·tol ≈ 1e-5).
            let scale = 10f64.powf(2.0 * (i as f64 / n as f64) - 1.0);
            b.push(i as u32, i as u32, scale);
            for _ in 0..2 {
                b.push(i as u32, rng.below(n) as u32, 0.05 * rng.normal());
            }
        }
        let phi = b.build();
        let sigma2 = 1e-4;
        let ncols = 4;
        let mut op = GramOperator::new(phi, sigma2);
        let diag = op.jacobi_diag();
        let block: Vec<f64> = (0..n * ncols).map(|_| rng.normal()).collect();
        let tol = 1e-9;
        let (x_plain, st_plain) = {
            let mut op2 = GramOperator::new(op.phi.clone(), sigma2);
            block_cg_solve(
                |x, y| op2.apply_block_into(x, ncols, y),
                &block,
                ncols,
                None,
                None,
                tol,
                4000,
            )
        };
        let (x_pre, st_pre) = block_cg_solve(
            |x, y| op.apply_block_into(x, ncols, y),
            &block,
            ncols,
            None,
            Some(&diag),
            tol,
            4000,
        );
        for j in 0..ncols {
            assert!(st_plain[j].converged, "plain col {j}: {:?}", st_plain[j]);
            assert!(st_pre[j].converged, "precond col {j}: {:?}", st_pre[j]);
            assert!(
                st_pre[j].iterations <= st_plain[j].iterations,
                "col {j}: precond {} > plain {}",
                st_pre[j].iterations,
                st_plain[j].iterations
            );
        }
        // Same linear system, same solution (up to kappa·tol CG error).
        let mut max_rel: f64 = 0.0;
        for i in 0..n * ncols {
            let denom = 1.0 + x_plain[i].abs();
            max_rel = max_rel.max((x_plain[i] - x_pre[i]).abs() / denom);
        }
        assert!(max_rel < 1e-4, "solutions diverge: {max_rel}");
    }

    #[test]
    fn layout_selection_preserves_apply_bitwise_in_f64() {
        // Forced CSR, forced ELL(f64), and Auto must agree BITWISE on
        // both the single-vector and the blocked application: the ELL
        // kernels replay the CSR per-row accumulation order.
        proptest(12, |rng| {
            let n = 2 + rng.below(30);
            let ncols = 1 + rng.below(5);
            let phi = random_phi(rng, n);
            let mask: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 }).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let block: Vec<f64> = (0..n * ncols).map(|_| rng.normal()).collect();
            let mut ops: Vec<GramOperator> = [
                FeatureLayout::Csr,
                FeatureLayout::Ell,
                FeatureLayout::Auto,
            ]
            .into_iter()
            .map(|l| {
                GramOperator::new(phi.clone(), 0.3)
                    .with_mask(mask.clone())
                    .with_layout(l)
            })
            .collect();
            let y_ref = ops[0].apply(&x);
            let yb_ref = ops[0].apply_block(&block, ncols);
            for op in &mut ops[1..] {
                prop_assert!(
                    op.apply(&x) == y_ref,
                    "layout {:?} ({}) apply differs",
                    op.layout(),
                    op.layout_desc()
                );
                prop_assert!(
                    op.apply_block(&block, ncols) == yb_ref,
                    "layout {:?} apply_block differs",
                    op.layout()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ell_f32_gram_within_rounding_tolerance() {
        // The f32 value path perturbs Φ's entries by ≤ ~6e-8 relative;
        // the gram product must stay within that rounding envelope of
        // the f64 operator (MC estimation error in Φ is ~1e-2, so this
        // is statistically free).
        let mut rng = Rng::new(31);
        let n = 60;
        let phi = random_phi(&mut rng, n);
        let mut op64 = GramOperator::new(phi.clone(), 0.1);
        let mut op32 =
            GramOperator::new(phi, 0.1).with_layout(FeatureLayout::EllF32);
        assert!(op32.layout_desc().contains("ell_f32"));
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y64 = op64.apply(&x);
        let y32 = op32.apply(&x);
        let scale = y64.iter().fold(0.0f64, |a, v| a.max(v.abs()));
        for i in 0..n {
            assert!(
                (y32[i] - y64[i]).abs() <= 1e-5 * (scale + 1.0),
                "node {i}: {} vs {}",
                y32[i],
                y64[i]
            );
        }
    }

    #[test]
    fn patched_operator_matches_rebuilt_operator_bitwise() {
        // Overlay-aware apply path: patch rows through the overlays,
        // then compare every application bitwise against an operator
        // rebuilt from the materialised patched Φ — before and after
        // compaction.
        proptest(12, |rng| {
            let n = 4 + rng.below(20);
            let phi = random_phi(rng, n);
            let mask: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 })
                .collect();
            let mut op =
                GramOperator::new(phi, 0.25).with_mask(mask.clone());
            // Warm the operand selection, then patch: the selection
            // must refresh (overlay pre-empts ELL) instead of serving
            // stale packed values.
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let _ = op.apply(&x);
            let mut rows: Vec<u32> =
                (0..1 + rng.below(4)).map(|_| rng.below(n) as u32).collect();
            rows.sort_unstable();
            rows.dedup();
            let patches: Vec<(u32, Vec<u32>, Vec<f64>)> = rows
                .iter()
                .map(|&r| {
                    let mut cols: Vec<u32> =
                        (0..3).map(|_| rng.below(n) as u32).collect();
                    cols.sort_unstable();
                    cols.dedup();
                    let vals: Vec<f64> =
                        cols.iter().map(|_| 0.4 * rng.normal()).collect();
                    (r, cols, vals)
                })
                .collect();
            op.patch_phi_rows(n, &patches);
            let mut reference =
                GramOperator::new(op.phi.to_csr(), 0.25).with_mask(mask);
            prop_assert!(
                op.phi_t == op.phi.to_csr().transpose(),
                "patched Φᵀ != transpose of patched Φ"
            );
            let y = op.apply(&x);
            prop_assert!(y == reference.apply(&x), "patched apply differs");
            let b = 1 + rng.below(4);
            let xb: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            prop_assert!(
                op.apply_block(&xb, b) == reference.apply_block(&xb, b),
                "patched apply_block differs"
            );
            prop_assert!(
                op.jacobi_diag() == reference.jacobi_diag(),
                "patched jacobi differs"
            );
            op.compact();
            prop_assert!(op.apply(&x) == y, "compaction moved the operator");
            Ok(())
        });
    }

    #[test]
    fn kernel_entry_matches_apply() {
        let mut rng = Rng::new(0);
        let n = 12;
        let phi = random_phi(&mut rng, n);
        let mut op = GramOperator::new(phi, 0.0);
        for i in 0..n {
            let row = op.kernel_row(i);
            for j in 0..n {
                assert!((op.kernel_entry(i, j) - row[j]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn parallel_path_matches_serial() {
        let mut rng = Rng::new(1);
        // Big enough to trigger the threaded branch.
        let n = 5000;
        let phi = random_phi(&mut rng, n);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut serial = GramOperator::new(phi.clone(), 0.1);
        let mut par = GramOperator::new(phi, 0.1).with_threads(4);
        let ys = serial.apply(&x);
        let yp = par.apply(&x);
        for i in 0..n {
            assert!((ys[i] - yp[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn gram_matmat_matches_apply() {
        let mut rng = Rng::new(2);
        let n = 40;
        let phi = random_phi(&mut rng, n);
        let phi_t = phi.transpose();
        let xs: Vec<Vec<f64>> =
            (0..5).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let mut op = GramOperator::new(phi.clone(), 0.2);
        let batch = gram_matmat(&phi, &phi_t, None, 0.2, &xs, 3);
        for (x, yb) in xs.iter().zip(&batch) {
            let y = op.apply(x);
            for i in 0..n {
                assert!((y[i] - yb[i]).abs() < 1e-10);
            }
        }
    }
}
