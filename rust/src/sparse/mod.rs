//! Sparse linear-algebra substrate: CSR matrices, COO builders, native
//! ELL matrices for the solver hot path (see [`ell`]), the f32/i32 ELL
//! artifact layout the PJRT runtime consumes, and the gram-matvec that
//! dominates the GP hot path.
//!
//! ## Dense-block (SpMM) kernels
//!
//! SpMV is memory-bandwidth-bound: every CG iteration streams the whole
//! CSR from memory to produce one vector. The blocked kernels
//! ([`Csr::matmat_into`] / [`Csr::matmat_par_into`]) multiply against a
//! **row-major `n_cols × B` dense block** instead, so one pass over the
//! matrix feeds `B` right-hand sides — the matrix traffic is amortised
//! `B`× and the inner loop over the `B` contiguous columns vectorises.
//! This is what makes the multi-RHS solver path (Hutchinson probes in
//! training, pathwise samples in prediction) scale with bandwidth
//! rather than RHS count.
//!
//! Block layout convention used across the crate: a dense block `X` of
//! `B` column vectors over `r` coordinates is stored row-major as
//! `x[i * B + j]` = coordinate `i` of column `j`.

pub mod ell;
pub mod ops;
pub mod row_overlay;

pub use ell::{Ell, FeatureLayout, RowWidthStats};
pub use row_overlay::RowOverlay;

use crate::util::parallel;
use crate::util::parallel::SendPtr;

/// CSR sparse matrix over f64. Rows sorted by column, duplicates merged.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub offsets: Vec<usize>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

/// COO triplet accumulator; `build()` sorts, merges duplicates, and
/// produces canonical CSR.
#[derive(Clone, Debug, Default)]
pub struct CooBuilder {
    pub n_rows: usize,
    pub n_cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl CooBuilder {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        CooBuilder { n_rows, n_cols, entries: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, r: u32, c: u32, v: f64) {
        debug_assert!((r as usize) < self.n_rows && (c as usize) < self.n_cols);
        self.entries.push((r, c, v));
    }

    pub fn nnz_upper_bound(&self) -> usize {
        self.entries.len()
    }

    pub fn build(mut self) -> Csr {
        self.entries
            .sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut offsets = vec![0usize; self.n_rows + 1];
        let mut cols = Vec::with_capacity(self.entries.len());
        let mut vals = Vec::with_capacity(self.entries.len());
        let mut i = 0;
        while i < self.entries.len() {
            let (r, c, _) = self.entries[i];
            let mut v = 0.0;
            while i < self.entries.len()
                && self.entries[i].0 == r
                && self.entries[i].1 == c
            {
                v += self.entries[i].2;
                i += 1;
            }
            if v != 0.0 {
                cols.push(c);
                vals.push(v);
                offsets[r as usize + 1] += 1;
            }
        }
        for r in 0..self.n_rows {
            offsets[r + 1] += offsets[r];
        }
        Csr {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            offsets,
            cols,
            vals,
        }
    }
}

impl Csr {
    pub fn zeros(n_rows: usize, n_cols: usize) -> Csr {
        Csr {
            n_rows,
            n_cols,
            offsets: vec![0; n_rows + 1],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Identity matrix scaled by `s`.
    pub fn scaled_identity(n: usize, s: f64) -> Csr {
        Csr {
            n_rows: n,
            n_cols: n,
            offsets: (0..=n).collect(),
            cols: (0..n as u32).collect(),
            vals: vec![s; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.offsets[i], self.offsets[i + 1]);
        (&self.cols[s..e], &self.vals[s..e])
    }

    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows)
            .map(|i| self.offsets[i + 1] - self.offsets[i])
            .max()
            .unwrap_or(0)
    }

    /// Memory footprint in bytes (cols + vals + offsets).
    pub fn memory_bytes(&self) -> usize {
        self.cols.len() * 4 + self.vals.len() * 8 + self.offsets.len() * 8
    }

    /// y = A x (serial).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.n_cols);
        let mut y = vec![0.0; self.n_rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x, writing into a caller-provided buffer (hot path:
    /// no allocation per CG iteration).
    ///
    /// The inner gather uses unchecked indexing: `cols` entries are
    /// validated < n_cols at construction (CooBuilder asserts, CSR
    /// stitching preserves), so the bound holds by construction; this
    /// is worth ~20% on the CG hot path (EXPERIMENTS.md §Perf).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_cols);
        debug_assert_eq!(y.len(), self.n_rows);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                // SAFETY: *c < n_cols == x.len() by CSR construction.
                acc += v * unsafe { x.get_unchecked(*c as usize) };
            }
            y[i] = acc;
        }
    }

    /// Parallel y = A x across row chunks.
    pub fn matvec_par(&self, x: &[f64], threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_par_into(x, &mut y, threads);
        y
    }

    /// Parallel y = A x into a caller-provided buffer: threads write
    /// disjoint row ranges of `y` directly, so repeated applications
    /// (every CG iteration) allocate nothing.
    pub fn matvec_par_into(&self, x: &[f64], y: &mut [f64], threads: usize) {
        // Hard asserts, not debug: the row loop below reads x with
        // unchecked indices justified by these lengths, and a mis-sized
        // caller buffer must panic rather than read out of bounds in
        // release builds.
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        parallel::par_rows_mut(y, 1, threads, |s, e, ys| {
            for i in s..e {
                let (cols, vals) = self.row(i);
                let mut acc = 0.0;
                for (c, v) in cols.iter().zip(vals) {
                    // SAFETY: *c < n_cols == x.len() by CSR construction.
                    acc += v * unsafe { x.get_unchecked(*c as usize) };
                }
                ys[i - s] = acc;
            }
        });
    }

    /// Rows [s, e) of the SpMM Y = A X, written into `out` (row-major
    /// `(e-s) × ncols`). Shared inner kernel of the serial and parallel
    /// block products.
    #[inline]
    fn matmat_rows(&self, x: &[f64], ncols: usize, s: usize, e: usize, out: &mut [f64]) {
        for i in s..e {
            let (cols, vals) = self.row(i);
            let yi = &mut out[(i - s) * ncols..(i - s + 1) * ncols];
            yi.fill(0.0);
            for (c, v) in cols.iter().zip(vals) {
                let base = *c as usize * ncols;
                // SAFETY: *c < n_cols, so base + ncols <= x.len() by the
                // caller's (debug-asserted) shape contract.
                let xr = unsafe { x.get_unchecked(base..base + ncols) };
                for (yj, xj) in yi.iter_mut().zip(xr) {
                    *yj += v * xj;
                }
            }
        }
    }

    /// SpMM Y = A X over a row-major `n_cols × ncols` dense block,
    /// writing into the caller's row-major `n_rows × ncols` buffer.
    /// One pass over the CSR serves all `ncols` right-hand sides.
    pub fn matmat_into(&self, x: &[f64], ncols: usize, y: &mut [f64]) {
        assert!(ncols > 0, "block width must be positive");
        // Hard asserts: matmat_rows reads x unchecked against these
        // lengths; a wrongly packed block must panic, not read OOB.
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        self.matmat_rows(x, ncols, 0, self.n_rows, y);
    }

    /// Allocating convenience wrapper over [`Csr::matmat_into`].
    pub fn matmat(&self, x: &[f64], ncols: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_into(x, ncols, &mut y);
        y
    }

    /// Thread-parallel SpMM over row chunks, allocation-free: threads
    /// write disjoint row ranges of `y`.
    pub fn matmat_par_into(&self, x: &[f64], ncols: usize, y: &mut [f64], threads: usize) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        parallel::par_rows_mut(y, ncols, threads, |s, e, rows| {
            self.matmat_rows(x, ncols, s, e, rows);
        });
    }

    /// Allocating convenience wrapper over [`Csr::matmat_par_into`].
    pub fn matmat_par(&self, x: &[f64], ncols: usize, threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_par_into(x, ncols, &mut y, threads);
        y
    }

    /// Transpose (CSR -> CSR of A^T) via counting sort; O(nnz).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.cols {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cols = vec![0u32; self.nnz()];
        let mut vals = vec![0f64; self.nnz()];
        let mut cursor = counts;
        for r in 0..self.n_rows {
            let (rc, rv) = self.row(r);
            for (c, v) in rc.iter().zip(rv) {
                let k = cursor[*c as usize];
                cols[k] = r as u32;
                vals[k] = *v;
                cursor[*c as usize] += 1;
            }
        }
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            offsets,
            cols,
            vals,
        }
    }

    /// Thread-parallel transpose, bit-identical to [`Csr::transpose`].
    ///
    /// Classic two-pass parallel counting sort: each thread histograms
    /// the columns of its row chunk, a serial scan turns the per-chunk
    /// histograms into disjoint per-(thread, column) cursor ranges, then
    /// each thread re-walks its chunk scattering into its own ranges.
    /// Entries of earlier rows land earlier within every column segment,
    /// exactly like the serial scatter. `refresh_features` transposes Φ
    /// on every Adam step, so this is on the training hot path.
    pub fn transpose_par(&self, threads: usize) -> Csr {
        let threads = threads.max(1).min(self.n_rows.max(1));
        if threads <= 1 || self.n_rows < 2048 {
            return self.transpose();
        }
        let chunk = self.n_rows.div_ceil(threads);
        let mut bounds = Vec::new();
        let mut start = 0;
        while start < self.n_rows {
            let end = (start + chunk).min(self.n_rows);
            bounds.push((start, end));
            start = end;
        }
        // Phase 1: per-chunk column histograms.
        let mut hists: Vec<Vec<usize>> = Vec::with_capacity(bounds.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = bounds
                .iter()
                .map(|&(s, e)| {
                    scope.spawn(move || {
                        let mut h = vec![0usize; self.n_cols];
                        for &c in &self.cols[self.offsets[s]..self.offsets[e]] {
                            h[c as usize] += 1;
                        }
                        h
                    })
                })
                .collect();
            for handle in handles {
                hists.push(handle.join().expect("histogram worker panicked"));
            }
        });
        // Serial scan: global column offsets + per-chunk cursors.
        let mut offsets = vec![0usize; self.n_cols + 1];
        for h in &hists {
            for (c, &v) in h.iter().enumerate() {
                offsets[c + 1] += v;
            }
        }
        for c in 0..self.n_cols {
            offsets[c + 1] += offsets[c];
        }
        let mut cursors: Vec<Vec<usize>> = Vec::with_capacity(bounds.len());
        let mut running = offsets[..self.n_cols].to_vec();
        for h in &hists {
            cursors.push(running.clone());
            for c in 0..self.n_cols {
                running[c] += h[c];
            }
        }
        // Phase 2: scatter. Each (thread, column) owns the disjoint
        // range [cursors[t][c], cursors[t][c] + hists[t][c]).
        let nnz = self.nnz();
        let mut cols = vec![0u32; nnz];
        let mut vals = vec![0f64; nnz];
        let cols_ptr = SendPtr(cols.as_mut_ptr());
        let vals_ptr = SendPtr(vals.as_mut_ptr());
        std::thread::scope(|scope| {
            for (&(s, e), mut cur) in bounds.iter().zip(std::mem::take(&mut cursors)) {
                let cols_ptr = cols_ptr;
                let vals_ptr = vals_ptr;
                scope.spawn(move || {
                    let cols_ptr = cols_ptr;
                    let vals_ptr = vals_ptr;
                    for r in s..e {
                        let (rc, rv) = self.row(r);
                        for (c, v) in rc.iter().zip(rv) {
                            let k = cur[*c as usize];
                            // SAFETY: k is taken from this thread's own
                            // cursor range, disjoint across threads and
                            // in-bounds by construction of `offsets`.
                            unsafe {
                                *cols_ptr.0.add(k) = r as u32;
                                *vals_ptr.0.add(k) = *v;
                            }
                            cur[*c as usize] += 1;
                        }
                    }
                });
            }
        });
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            offsets,
            cols,
            vals,
        }
    }

    /// Linear combination Σ_l coeff[l] * mats[l] (same shape). Used to
    /// assemble Φ(f) = Σ_l f_l C_l from walk component matrices.
    pub fn linear_combination(mats: &[&Csr], coeffs: &[f64]) -> Csr {
        assert_eq!(mats.len(), coeffs.len());
        assert!(!mats.is_empty());
        let (nr, nc) = (mats[0].n_rows, mats[0].n_cols);
        let mut b = CooBuilder::new(nr, nc);
        for (m, &w) in mats.iter().zip(coeffs) {
            assert_eq!((m.n_rows, m.n_cols), (nr, nc));
            if w == 0.0 {
                continue;
            }
            for r in 0..nr {
                let (cols, vals) = m.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    b.push(r as u32, *c, w * v);
                }
            }
        }
        b.build()
    }

    /// Copy with the given rows replaced and the shape possibly grown —
    /// the compaction/patch primitive of the streaming subsystem. Each
    /// patch row must be sorted by column; indices `>= self.n_rows`
    /// append new rows (gaps become empty rows). One linear pass, no
    /// sorting: O(nnz) memcpy.
    pub fn with_replaced_rows(
        &self,
        n_rows: usize,
        n_cols: usize,
        patches: &std::collections::BTreeMap<u32, (Vec<u32>, Vec<f64>)>,
    ) -> Csr {
        assert!(n_rows >= self.n_rows && n_cols >= self.n_cols);
        let extra: usize = patches.values().map(|(c, _)| c.len()).sum();
        let mut offsets = Vec::with_capacity(n_rows + 1);
        offsets.push(0usize);
        let mut cols = Vec::with_capacity(self.cols.len() + extra);
        let mut vals = Vec::with_capacity(self.vals.len() + extra);
        for r in 0..n_rows {
            if let Some((pc, pv)) = patches.get(&(r as u32)) {
                debug_assert_eq!(pc.len(), pv.len());
                debug_assert!(pc.windows(2).all(|w| w[0] < w[1]));
                cols.extend_from_slice(pc);
                vals.extend_from_slice(pv);
            } else if r < self.n_rows {
                let (rc, rv) = self.row(r);
                cols.extend_from_slice(rc);
                vals.extend_from_slice(rv);
            }
            offsets.push(cols.len());
        }
        Csr { n_rows, n_cols, offsets, cols, vals }
    }

    /// Dense expansion (tests / small-N baselines only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0; self.n_cols]; self.n_rows];
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                out[r][*c as usize] += v;
            }
        }
        out
    }

    /// Convert to the ELL **artifact** layout (fixed row width,
    /// f32/i32 payloads) — what the PJRT artifacts consume. Pads with
    /// (idx 0, val 0). Returns None if any row exceeds `width`.
    ///
    /// For the native solver-side ELL (f64/f32 values, f64
    /// accumulators, spill remainder) see [`Csr::to_ell`] in [`ell`].
    pub fn to_ell_artifact(&self, width: usize) -> Option<EllArtifact> {
        if self.max_row_nnz() > width {
            return None;
        }
        let n = self.n_rows;
        let mut idx = vec![0i32; n * width];
        let mut val = vec![0f32; n * width];
        for r in 0..n {
            let (cols, vals) = self.row(r);
            for (k, (c, v)) in cols.iter().zip(vals).enumerate() {
                idx[r * width + k] = *c as i32;
                val[r * width + k] = *v as f32;
            }
        }
        Some(EllArtifact { n_rows: n, n_cols: self.n_cols, width, idx, val })
    }
}

/// ELL (padded fixed-width) sparse matrix with f32/i32 payloads —
/// the interchange layout for the PJRT artifacts (see python/compile).
/// The native solver-side ELL type is [`ell::Ell`].
#[derive(Clone, Debug)]
pub struct EllArtifact {
    pub n_rows: usize,
    pub n_cols: usize,
    pub width: usize,
    /// Row-major [n_rows, width] column indices.
    pub idx: Vec<i32>,
    /// Row-major [n_rows, width] values.
    pub val: Vec<f32>,
}

impl EllArtifact {
    /// Pad to a larger (rows, width) bucket, preserving content.
    pub fn pad_to(&self, rows: usize, width: usize) -> EllArtifact {
        assert!(rows >= self.n_rows && width >= self.width);
        let mut idx = vec![0i32; rows * width];
        let mut val = vec![0f32; rows * width];
        for r in 0..self.n_rows {
            let src = r * self.width;
            let dst = r * width;
            idx[dst..dst + self.width]
                .copy_from_slice(&self.idx[src..src + self.width]);
            val[dst..dst + self.width]
                .copy_from_slice(&self.val[src..src + self.width]);
        }
        EllArtifact { n_rows: rows, n_cols: self.n_cols.max(rows), width, idx, val }
    }

    /// Reference matvec (f32 accumulation matches the artifact numerics).
    pub fn matvec_f32(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.n_rows];
        for r in 0..self.n_rows {
            let mut acc = 0f32;
            for k in 0..self.width {
                let e = r * self.width + k;
                acc += self.val[e] * x[self.idx[e] as usize];
            }
            y[r] = acc;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    pub fn random_csr(rng: &mut Rng, n_rows: usize, n_cols: usize, nnz: usize) -> Csr {
        let mut b = CooBuilder::new(n_rows, n_cols);
        for _ in 0..nnz {
            b.push(
                rng.below(n_rows) as u32,
                rng.below(n_cols) as u32,
                rng.normal(),
            );
        }
        b.build()
    }

    #[test]
    fn with_replaced_rows_splices_and_grows() {
        use std::collections::BTreeMap;
        proptest(16, |prng| {
            let n = 4 + prng.below(12);
            let m = random_csr(prng, n, n, 3 * n);
            let mut patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = BTreeMap::new();
            // Replace a couple of rows, empty one, append one past the end.
            patches.insert(0, (vec![1u32, 3], vec![2.5, -1.0]));
            patches.insert((n / 2) as u32, (Vec::new(), Vec::new()));
            patches.insert(n as u32 + 1, (vec![0u32], vec![7.0]));
            let out = m.with_replaced_rows(n + 2, n + 2, &patches);
            prop_assert!(out.n_rows == n + 2 && out.n_cols == n + 2, "shape");
            prop_assert!(
                *out.offsets.last().unwrap() == out.cols.len(),
                "offsets consistent"
            );
            for r in 0..n + 2 {
                let (cols, vals) = out.row(r);
                if let Some((pc, pv)) = patches.get(&(r as u32)) {
                    prop_assert!(cols == &pc[..] && vals == &pv[..], "patched row {r}");
                } else if r < n {
                    let (oc, ov) = m.row(r);
                    prop_assert!(cols == oc && vals == ov, "kept row {r}");
                } else {
                    prop_assert!(cols.is_empty(), "gap row {r} should be empty");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn coo_merges_duplicates() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 1, 1.0);
        b.push(0, 1, 2.0);
        b.push(1, 0, -1.0);
        b.push(1, 0, 1.0); // cancels to zero -> dropped
        let m = b.build();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0), (&[1u32][..], &[3.0][..]));
        assert_eq!(m.row(1).0.len(), 0);
    }

    #[test]
    fn matvec_matches_dense() {
        proptest(32, |rng| {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let a = random_csr(rng, n, m, 3 * n);
            let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let y = a.matvec(&x);
            let dense = a.to_dense();
            for i in 0..n {
                let expect: f64 =
                    dense[i].iter().zip(&x).map(|(a, b)| a * b).sum();
                prop_assert!(
                    (y[i] - expect).abs() < 1e-9,
                    "row {i}: {} vs {expect}",
                    y[i]
                );
            }
            let y_par = a.matvec_par(&x, 4);
            prop_assert!(y == y_par, "parallel matvec differs");
            Ok(())
        });
    }

    #[test]
    fn spmm_matches_repeated_spmv() {
        // Property: one SpMM over a B-column block == B independent
        // SpMVs, bitwise (same per-output accumulation order), for the
        // serial and the thread-parallel kernel.
        proptest(24, |rng| {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let b = 1 + rng.below(7);
            let a = random_csr(rng, n, m, 3 * n);
            // Column vectors + their row-major block packing.
            let cols_x: Vec<Vec<f64>> = (0..b)
                .map(|_| (0..m).map(|_| rng.normal()).collect())
                .collect();
            let mut block = vec![0.0; m * b];
            for (j, col) in cols_x.iter().enumerate() {
                for i in 0..m {
                    block[i * b + j] = col[i];
                }
            }
            let y_block = a.matmat(&block, b);
            let y_par = a.matmat_par(&block, b, 4);
            prop_assert!(y_block == y_par, "parallel SpMM differs from serial");
            for (j, col) in cols_x.iter().enumerate() {
                let y = a.matvec(col);
                for i in 0..n {
                    prop_assert!(
                        y_block[i * b + j] == y[i],
                        "SpMM col {j} row {i}: {} vs {}",
                        y_block[i * b + j],
                        y[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_par_matches_serial() {
        // Above the serial-fallback threshold so the histogram/scatter
        // path actually runs.
        let mut rng = Rng::new(17);
        for &threads in &[2usize, 3, 8] {
            let a = random_csr(&mut rng, 3000, 500, 12_000);
            let serial = a.transpose();
            let par = a.transpose_par(threads);
            assert!(serial == par, "transpose_par({threads}) differs");
        }
        // Below the threshold it falls back to (and equals) the serial path.
        let small = random_csr(&mut rng, 40, 40, 100);
        assert!(small.transpose() == small.transpose_par(4));
    }

    #[test]
    fn matvec_par_into_reuses_buffer() {
        let mut rng = Rng::new(23);
        let a = random_csr(&mut rng, 300, 200, 1500);
        let x: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let expect = a.matvec(&x);
        let mut y = vec![f64::NAN; 300];
        a.matvec_par_into(&x, &mut y, 4);
        assert_eq!(y, expect);
        // Second application into the same buffer overwrites cleanly.
        a.matvec_par_into(&x, &mut y, 2);
        assert_eq!(y, expect);
    }

    #[test]
    fn transpose_involution_and_shape() {
        proptest(32, |rng| {
            let n = 1 + rng.below(30);
            let m = 1 + rng.below(30);
            let a = random_csr(rng, n, m, 2 * n);
            let t = a.transpose();
            prop_assert!(t.n_rows == m && t.n_cols == n, "shape");
            let tt = t.transpose();
            prop_assert!(tt == a, "transpose twice != identity");
            Ok(())
        });
    }

    #[test]
    fn linear_combination_matches_dense() {
        proptest(16, |rng| {
            let n = 1 + rng.below(20);
            let a = random_csr(rng, n, n, 2 * n);
            let b = random_csr(rng, n, n, 2 * n);
            let combo = Csr::linear_combination(&[&a, &b], &[2.0, -0.5]);
            let (da, db, dc) = (a.to_dense(), b.to_dense(), combo.to_dense());
            for i in 0..n {
                for j in 0..n {
                    let expect = 2.0 * da[i][j] - 0.5 * db[i][j];
                    prop_assert!(
                        (dc[i][j] - expect).abs() < 1e-10,
                        "entry ({i},{j})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ell_artifact_roundtrip() {
        let mut rng = Rng::new(3);
        let a = random_csr(&mut rng, 10, 10, 25);
        let w = a.max_row_nnz();
        let e = a.to_ell_artifact(w).unwrap();
        let x: Vec<f32> = (0..10).map(|_| rng.normal() as f32).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y32 = e.matvec_f32(&x);
        let y64 = a.matvec(&x64);
        for i in 0..10 {
            assert!((y32[i] as f64 - y64[i]).abs() < 1e-4);
        }
        assert!(a.to_ell_artifact(w.saturating_sub(1)).is_none() || w == 0);
    }

    #[test]
    fn ell_artifact_pad_preserves_product() {
        let mut rng = Rng::new(5);
        let a = random_csr(&mut rng, 8, 8, 20);
        let e = a.to_ell_artifact(a.max_row_nnz()).unwrap();
        let p = e.pad_to(16, e.width + 3);
        let mut x: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        x.resize(16, 0.0);
        let y = p.matvec_f32(&x);
        let y0 = e.matvec_f32(&x[..8]);
        for i in 0..8 {
            assert!((y[i] - y0[i]).abs() < 1e-6);
        }
        for v in &y[8..] {
            assert_eq!(*v, 0.0);
        }
    }

    #[test]
    fn scaled_identity() {
        let m = Csr::scaled_identity(4, 2.5);
        let y = m.matvec(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(y, vec![2.5, 5.0, 7.5, 10.0]);
    }
}
