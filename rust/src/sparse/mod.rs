//! Sparse linear-algebra substrate: CSR matrices, COO builders, ELL
//! conversion (the PJRT interchange layout), and the gram-matvec that
//! dominates the GP hot path.

pub mod ops;

use crate::util::parallel;

/// CSR sparse matrix over f64. Rows sorted by column, duplicates merged.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub offsets: Vec<usize>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

/// COO triplet accumulator; `build()` sorts, merges duplicates, and
/// produces canonical CSR.
#[derive(Clone, Debug, Default)]
pub struct CooBuilder {
    pub n_rows: usize,
    pub n_cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl CooBuilder {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        CooBuilder { n_rows, n_cols, entries: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, r: u32, c: u32, v: f64) {
        debug_assert!((r as usize) < self.n_rows && (c as usize) < self.n_cols);
        self.entries.push((r, c, v));
    }

    pub fn nnz_upper_bound(&self) -> usize {
        self.entries.len()
    }

    pub fn build(mut self) -> Csr {
        self.entries
            .sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut offsets = vec![0usize; self.n_rows + 1];
        let mut cols = Vec::with_capacity(self.entries.len());
        let mut vals = Vec::with_capacity(self.entries.len());
        let mut i = 0;
        while i < self.entries.len() {
            let (r, c, _) = self.entries[i];
            let mut v = 0.0;
            while i < self.entries.len()
                && self.entries[i].0 == r
                && self.entries[i].1 == c
            {
                v += self.entries[i].2;
                i += 1;
            }
            if v != 0.0 {
                cols.push(c);
                vals.push(v);
                offsets[r as usize + 1] += 1;
            }
        }
        for r in 0..self.n_rows {
            offsets[r + 1] += offsets[r];
        }
        Csr {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            offsets,
            cols,
            vals,
        }
    }
}

impl Csr {
    pub fn zeros(n_rows: usize, n_cols: usize) -> Csr {
        Csr {
            n_rows,
            n_cols,
            offsets: vec![0; n_rows + 1],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Identity matrix scaled by `s`.
    pub fn scaled_identity(n: usize, s: f64) -> Csr {
        Csr {
            n_rows: n,
            n_cols: n,
            offsets: (0..=n).collect(),
            cols: (0..n as u32).collect(),
            vals: vec![s; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.offsets[i], self.offsets[i + 1]);
        (&self.cols[s..e], &self.vals[s..e])
    }

    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows)
            .map(|i| self.offsets[i + 1] - self.offsets[i])
            .max()
            .unwrap_or(0)
    }

    /// Memory footprint in bytes (cols + vals + offsets).
    pub fn memory_bytes(&self) -> usize {
        self.cols.len() * 4 + self.vals.len() * 8 + self.offsets.len() * 8
    }

    /// y = A x (serial).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.n_cols);
        let mut y = vec![0.0; self.n_rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x, writing into a caller-provided buffer (hot path:
    /// no allocation per CG iteration).
    ///
    /// The inner gather uses unchecked indexing: `cols` entries are
    /// validated < n_cols at construction (CooBuilder asserts, CSR
    /// stitching preserves), so the bound holds by construction; this
    /// is worth ~20% on the CG hot path (EXPERIMENTS.md §Perf).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_cols);
        debug_assert_eq!(y.len(), self.n_rows);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                // SAFETY: *c < n_cols == x.len() by CSR construction.
                acc += v * unsafe { x.get_unchecked(*c as usize) };
            }
            y[i] = acc;
        }
    }

    /// Parallel y = A x across row chunks.
    pub fn matvec_par(&self, x: &[f64], threads: usize) -> Vec<f64> {
        let parts = parallel::par_map_chunks(self.n_rows, threads, |s, e, _| {
            let mut part = vec![0.0; e - s];
            for i in s..e {
                let (cols, vals) = self.row(i);
                let mut acc = 0.0;
                for (c, v) in cols.iter().zip(vals) {
                    acc += v * x[*c as usize];
                }
                part[i - s] = acc;
            }
            part
        });
        parts.concat()
    }

    /// Transpose (CSR -> CSR of A^T) via counting sort; O(nnz).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.cols {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cols = vec![0u32; self.nnz()];
        let mut vals = vec![0f64; self.nnz()];
        let mut cursor = counts;
        for r in 0..self.n_rows {
            let (rc, rv) = self.row(r);
            for (c, v) in rc.iter().zip(rv) {
                let k = cursor[*c as usize];
                cols[k] = r as u32;
                vals[k] = *v;
                cursor[*c as usize] += 1;
            }
        }
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            offsets,
            cols,
            vals,
        }
    }

    /// Linear combination Σ_l coeff[l] * mats[l] (same shape). Used to
    /// assemble Φ(f) = Σ_l f_l C_l from walk component matrices.
    pub fn linear_combination(mats: &[&Csr], coeffs: &[f64]) -> Csr {
        assert_eq!(mats.len(), coeffs.len());
        assert!(!mats.is_empty());
        let (nr, nc) = (mats[0].n_rows, mats[0].n_cols);
        let mut b = CooBuilder::new(nr, nc);
        for (m, &w) in mats.iter().zip(coeffs) {
            assert_eq!((m.n_rows, m.n_cols), (nr, nc));
            if w == 0.0 {
                continue;
            }
            for r in 0..nr {
                let (cols, vals) = m.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    b.push(r as u32, *c, w * v);
                }
            }
        }
        b.build()
    }

    /// Dense expansion (tests / small-N baselines only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0; self.n_cols]; self.n_rows];
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                out[r][*c as usize] += v;
            }
        }
        out
    }

    /// Convert to ELL (fixed row width) with f32/i32 payloads — the
    /// layout the PJRT artifacts consume. Pads with (idx 0, val 0).
    /// Returns None if any row exceeds `width`.
    pub fn to_ell(&self, width: usize) -> Option<Ell> {
        if self.max_row_nnz() > width {
            return None;
        }
        let n = self.n_rows;
        let mut idx = vec![0i32; n * width];
        let mut val = vec![0f32; n * width];
        for r in 0..n {
            let (cols, vals) = self.row(r);
            for (k, (c, v)) in cols.iter().zip(vals).enumerate() {
                idx[r * width + k] = *c as i32;
                val[r * width + k] = *v as f32;
            }
        }
        Some(Ell { n_rows: n, n_cols: self.n_cols, width, idx, val })
    }
}

/// ELL (padded fixed-width) sparse matrix with f32/i32 payloads —
/// the interchange layout for the PJRT artifacts (see python/compile).
#[derive(Clone, Debug)]
pub struct Ell {
    pub n_rows: usize,
    pub n_cols: usize,
    pub width: usize,
    /// Row-major [n_rows, width] column indices.
    pub idx: Vec<i32>,
    /// Row-major [n_rows, width] values.
    pub val: Vec<f32>,
}

impl Ell {
    /// Pad to a larger (rows, width) bucket, preserving content.
    pub fn pad_to(&self, rows: usize, width: usize) -> Ell {
        assert!(rows >= self.n_rows && width >= self.width);
        let mut idx = vec![0i32; rows * width];
        let mut val = vec![0f32; rows * width];
        for r in 0..self.n_rows {
            let src = r * self.width;
            let dst = r * width;
            idx[dst..dst + self.width]
                .copy_from_slice(&self.idx[src..src + self.width]);
            val[dst..dst + self.width]
                .copy_from_slice(&self.val[src..src + self.width]);
        }
        Ell { n_rows: rows, n_cols: self.n_cols.max(rows), width, idx, val }
    }

    /// Reference matvec (f32 accumulation matches the artifact numerics).
    pub fn matvec_f32(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.n_rows];
        for r in 0..self.n_rows {
            let mut acc = 0f32;
            for k in 0..self.width {
                let e = r * self.width + k;
                acc += self.val[e] * x[self.idx[e] as usize];
            }
            y[r] = acc;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    pub fn random_csr(rng: &mut Rng, n_rows: usize, n_cols: usize, nnz: usize) -> Csr {
        let mut b = CooBuilder::new(n_rows, n_cols);
        for _ in 0..nnz {
            b.push(
                rng.below(n_rows) as u32,
                rng.below(n_cols) as u32,
                rng.normal(),
            );
        }
        b.build()
    }

    #[test]
    fn coo_merges_duplicates() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 1, 1.0);
        b.push(0, 1, 2.0);
        b.push(1, 0, -1.0);
        b.push(1, 0, 1.0); // cancels to zero -> dropped
        let m = b.build();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0), (&[1u32][..], &[3.0][..]));
        assert_eq!(m.row(1).0.len(), 0);
    }

    #[test]
    fn matvec_matches_dense() {
        proptest(32, |rng| {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let a = random_csr(rng, n, m, 3 * n);
            let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let y = a.matvec(&x);
            let dense = a.to_dense();
            for i in 0..n {
                let expect: f64 =
                    dense[i].iter().zip(&x).map(|(a, b)| a * b).sum();
                prop_assert!(
                    (y[i] - expect).abs() < 1e-9,
                    "row {i}: {} vs {expect}",
                    y[i]
                );
            }
            let y_par = a.matvec_par(&x, 4);
            prop_assert!(y == y_par, "parallel matvec differs");
            Ok(())
        });
    }

    #[test]
    fn transpose_involution_and_shape() {
        proptest(32, |rng| {
            let n = 1 + rng.below(30);
            let m = 1 + rng.below(30);
            let a = random_csr(rng, n, m, 2 * n);
            let t = a.transpose();
            prop_assert!(t.n_rows == m && t.n_cols == n, "shape");
            let tt = t.transpose();
            prop_assert!(tt == a, "transpose twice != identity");
            Ok(())
        });
    }

    #[test]
    fn linear_combination_matches_dense() {
        proptest(16, |rng| {
            let n = 1 + rng.below(20);
            let a = random_csr(rng, n, n, 2 * n);
            let b = random_csr(rng, n, n, 2 * n);
            let combo = Csr::linear_combination(&[&a, &b], &[2.0, -0.5]);
            let (da, db, dc) = (a.to_dense(), b.to_dense(), combo.to_dense());
            for i in 0..n {
                for j in 0..n {
                    let expect = 2.0 * da[i][j] - 0.5 * db[i][j];
                    prop_assert!(
                        (dc[i][j] - expect).abs() < 1e-10,
                        "entry ({i},{j})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ell_roundtrip() {
        let mut rng = Rng::new(3);
        let a = random_csr(&mut rng, 10, 10, 25);
        let w = a.max_row_nnz();
        let e = a.to_ell(w).unwrap();
        let x: Vec<f32> = (0..10).map(|_| rng.normal() as f32).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y32 = e.matvec_f32(&x);
        let y64 = a.matvec(&x64);
        for i in 0..10 {
            assert!((y32[i] as f64 - y64[i]).abs() < 1e-4);
        }
        assert!(a.to_ell(w.saturating_sub(1)).is_none() || w == 0);
    }

    #[test]
    fn ell_pad_preserves_product() {
        let mut rng = Rng::new(5);
        let a = random_csr(&mut rng, 8, 8, 20);
        let e = a.to_ell(a.max_row_nnz()).unwrap();
        let p = e.pad_to(16, e.width + 3);
        let mut x: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        x.resize(16, 0.0);
        let y = p.matvec_f32(&x);
        let y0 = e.matvec_f32(&x[..8]);
        for i in 0..8 {
            assert!((y[i] - y0[i]).abs() < 1e-6);
        }
        for v in &y[8..] {
            assert_eq!(*v, 0.0);
        }
    }

    #[test]
    fn scaled_identity() {
        let m = Csr::scaled_identity(4, 2.5);
        let y = m.matvec(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(y, vec![2.5, 5.0, 7.5, 10.0]);
    }
}
