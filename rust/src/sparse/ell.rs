//! Native ELL (padded fixed-width) sparse matrices for the solver hot
//! path.
//!
//! The GRF feature matrix Φ has near-uniform row widths (Theorem 1
//! bounds nonzeros-per-feature w.h.p.), so packing rows to a common
//! width turns the CSR's per-row offset chasing into a regular
//! `[n_rows × width]` strided gather: the inner SpMV/SpMM loop has a
//! fixed trip count, no `offsets` traffic, and vectorises cleanly.
//! Rows wider than the chosen width keep their overflow entries in a
//! small CSR *spill* remainder, so any matrix converts losslessly.
//!
//! The type carries up to two value arrays:
//!
//! * `vals` (f64, always present) — bit-identical arithmetic with the
//!   CSR kernels (same per-row accumulation order; padding contributes
//!   exact `+0.0` terms).
//! * `vals32` (f32, materialized only when the f32 path is selected) —
//!   the same entries rounded once. Φ's entries are Monte-Carlo
//!   estimates with ~1e-2 relative error, so the ~6e-8 rounding is
//!   statistically free while halving the value-array traffic of the
//!   bandwidth-bound SpMM. Accumulation stays in f64 either way.
//!
//! [`FeatureLayout`] is the per-matrix selection policy used by
//! `GpModel::refresh_features` and `GramOperator`: `Auto` converts to
//! ELL only when the row widths are regular enough (width ≤
//! [`ELL_WIDTH_FACTOR`]·mean row nnz with bounded padding and spill),
//! falling back to CSR on irregular (power-law) patterns.

use super::Csr;
use crate::util::parallel;

/// Row-width distribution of a sparse matrix — the signal the ELL
/// auto-layout policy (and the walk-engine diagnostics) decide on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowWidthStats {
    pub n_rows: usize,
    pub nnz: usize,
    pub max: usize,
    pub mean: f64,
}

impl RowWidthStats {
    /// Padding overhead of packing every row to `width` slots:
    /// stored-slot count over real nonzeros (1.0 = no padding).
    pub fn pad_ratio(&self, width: usize) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        (self.n_rows * width) as f64 / self.nnz as f64
    }
}

/// Auto-layout width multiplier: ELL width is capped at
/// `ceil(ELL_WIDTH_FACTOR * mean_row_nnz)` so a few fat rows spill
/// instead of padding every row to the maximum.
pub const ELL_WIDTH_FACTOR: f64 = 1.5;
/// Auto layout rejects ELL when more than this fraction of nonzeros
/// would land in the spill remainder (the pattern is too irregular for
/// a fixed width to pay off).
pub const ELL_MAX_SPILL_FRAC: f64 = 0.10;
/// Auto layout rejects ELL when padding would inflate stored slots
/// beyond this factor over the real nonzeros.
pub const ELL_MAX_PAD_RATIO: f64 = 2.0;

/// Per-matrix operator layout policy (selected at `refresh_features`
/// time by the GP model, or via `GramOperator::with_layout`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureLayout {
    /// ELL with f64 values when the row widths are regular enough
    /// (bit-identical results, pure memory-layout win); CSR otherwise.
    Auto,
    /// Always the CSR kernels (the pre-ELL behavior).
    Csr,
    /// Force ELL with f64 values (spill absorbs any irregularity).
    Ell,
    /// Force ELL with f32 values / f64 accumulators: halves the value
    /// traffic at ~6e-8 relative rounding of Φ's MC-estimated entries.
    EllF32,
}

impl FeatureLayout {
    pub fn uses_f32(self) -> bool {
        matches!(self, FeatureLayout::EllF32)
    }
}

/// Native ELL matrix: fixed-width padded rows + CSR spill remainder.
///
/// Entries of row `i` occupy `cols/vals[i*width ..]` in the same
/// column-sorted order as the source CSR, padded with `(col 0, 0.0)`;
/// overflow entries (beyond `width`) continue, still in order, in
/// `spill` row `i`. Every kernel accumulates a row as: ELL slots left
/// to right, then spill entries — exactly the CSR entry order, which
/// is what makes the f64 path bit-identical to [`Csr::matvec_into`] /
/// [`Csr::matmat_into`].
#[derive(Clone, Debug)]
pub struct Ell {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Padded row width (0 for an empty matrix).
    pub width: usize,
    /// Row-major `[n_rows × width]` column indices (padding: 0).
    pub cols: Vec<u32>,
    /// Row-major `[n_rows × width]` f64 values (padding: 0.0). Always
    /// present — the source of truth the f32 array is derived from.
    pub vals: Vec<f64>,
    /// The same entries rounded to f32 once. Materialized only when
    /// the f32 path is (or has ever been) selected, so the default
    /// f64 layout carries no dead copy.
    pub vals32: Vec<f32>,
    /// Which value array the kernels read (accumulators are f64 both
    /// ways). Private: flip it through [`Ell::set_use_f32`], which
    /// guarantees `vals32` is materialized before the kernels index it.
    use_f32: bool,
    /// Overflow entries of rows wider than `width` (often empty).
    /// Spill values stay f64 on both paths — the remainder is tiny, so
    /// rounding it buys no bandwidth.
    pub spill: Csr,
    /// Real (unpadded) nonzeros, ELL body + spill.
    nnz: usize,
}

/// Value-array abstraction so the f64 and f32 kernels monomorphise to
/// the same tight loop instead of branching per entry.
trait EllVal: Copy + Send + Sync {
    fn promote(self) -> f64;
}

impl EllVal for f64 {
    #[inline(always)]
    fn promote(self) -> f64 {
        self
    }
}

impl EllVal for f32 {
    #[inline(always)]
    fn promote(self) -> f64 {
        self as f64
    }
}

impl Csr {
    /// Row-width distribution (drives the ELL auto-layout policy; also
    /// reported by the walk-engine feature-build diagnostics).
    pub fn row_width_stats(&self) -> RowWidthStats {
        let nnz = self.nnz();
        RowWidthStats {
            n_rows: self.n_rows,
            nnz,
            max: self.max_row_nnz(),
            mean: if self.n_rows == 0 {
                0.0
            } else {
                nnz as f64 / self.n_rows as f64
            },
        }
    }

    /// The auto-policy ELL width for this matrix:
    /// `min(max_row_nnz, ceil(ELL_WIDTH_FACTOR · mean_row_nnz))`.
    pub fn ell_auto_width(&self) -> usize {
        let st = self.row_width_stats();
        if st.nnz == 0 {
            return 0;
        }
        st.max.min(((ELL_WIDTH_FACTOR * st.mean).ceil() as usize).max(1))
    }

    /// Convert to native ELL with the given row width; entries beyond
    /// `width` per row go to the CSR spill remainder, so the conversion
    /// is total (never fails) and lossless. `use_f32` selects which of
    /// the two value arrays the kernels will read.
    pub fn to_ell(&self, width: usize, use_f32: bool) -> Ell {
        let n = self.n_rows;
        // An empty matrix gets width 0 regardless of the request: the
        // padding column index 0 would otherwise be out of bounds when
        // n_cols == 0.
        let width = if self.nnz() == 0 { 0 } else { width };
        let mut cols = vec![0u32; n * width];
        let mut vals = vec![0f64; n * width];
        // Spill CSR built directly (not via CooBuilder) so exact-zero
        // entries survive and the entry order is preserved verbatim.
        let mut sp_offsets = vec![0usize; n + 1];
        let mut sp_cols = Vec::new();
        let mut sp_vals = Vec::new();
        for r in 0..n {
            let (rc, rv) = self.row(r);
            let head = rc.len().min(width);
            let base = r * width;
            cols[base..base + head].copy_from_slice(&rc[..head]);
            vals[base..base + head].copy_from_slice(&rv[..head]);
            sp_cols.extend_from_slice(&rc[head..]);
            sp_vals.extend_from_slice(&rv[head..]);
            sp_offsets[r + 1] = sp_cols.len();
        }
        let vals32: Vec<f32> = if use_f32 {
            vals.iter().map(|&v| v as f32).collect()
        } else {
            Vec::new()
        };
        Ell {
            n_rows: n,
            n_cols: self.n_cols,
            width,
            cols,
            vals,
            vals32,
            use_f32,
            spill: Csr {
                n_rows: n,
                n_cols: self.n_cols,
                offsets: sp_offsets,
                cols: sp_cols,
                vals: sp_vals,
            },
            nnz: self.nnz(),
        }
    }

    /// Auto-layout policy: ELL at [`Csr::ell_auto_width`] if the
    /// pattern is regular enough (spill ≤ [`ELL_MAX_SPILL_FRAC`] of
    /// nnz, padding ≤ [`ELL_MAX_PAD_RATIO`]×), `None` to stay CSR.
    pub fn to_ell_auto(&self, use_f32: bool) -> Option<Ell> {
        let st = self.row_width_stats();
        if st.nnz == 0 {
            return None;
        }
        let width = self.ell_auto_width();
        if st.pad_ratio(width) > ELL_MAX_PAD_RATIO {
            return None;
        }
        let ell = self.to_ell(width, use_f32);
        if ell.spill.nnz() as f64 > ELL_MAX_SPILL_FRAC * st.nnz as f64 {
            return None;
        }
        Some(ell)
    }

    /// Apply `layout` to this matrix: `Some(ell)` when the policy picks
    /// (or forces) ELL, `None` when it stays CSR.
    pub fn select_ell(&self, layout: FeatureLayout) -> Option<Ell> {
        match layout {
            FeatureLayout::Csr => None,
            FeatureLayout::Auto => self.to_ell_auto(false),
            FeatureLayout::Ell | FeatureLayout::EllF32 => {
                Some(self.to_ell(self.ell_auto_width(), layout.uses_f32()))
            }
        }
    }
}

impl Ell {
    /// Real (unpadded) nonzeros, ELL body + spill.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Whether the kernels read the f32 value array.
    pub fn uses_f32(&self) -> bool {
        self.use_f32
    }

    /// Select which value array the kernels read, materializing the
    /// f32 copy from the f64 source on first use (the f64 array always
    /// stays, so the toggle is lossless in both directions).
    pub fn set_use_f32(&mut self, use_f32: bool) {
        if use_f32 && self.vals32.len() != self.vals.len() {
            self.vals32 = self.vals.iter().map(|&v| v as f32).collect();
        }
        self.use_f32 = use_f32;
    }

    /// Nonzeros held in the spill remainder.
    pub fn spill_nnz(&self) -> usize {
        self.spill.nnz()
    }

    /// Memory footprint in bytes (both value arrays + indices + spill).
    pub fn memory_bytes(&self) -> usize {
        self.cols.len() * 4
            + self.vals.len() * 8
            + self.vals32.len() * 4
            + self.spill.memory_bytes()
    }

    /// Rows [s, e) of y = A x into `out[0 .. e-s]`: fixed-width ELL
    /// gather, then the spill continuation in the same accumulator —
    /// the exact CSR per-row entry order.
    #[inline]
    fn rows_matvec<V: EllVal>(
        &self,
        vals: &[V],
        x: &[f64],
        s: usize,
        e: usize,
        out: &mut [f64],
    ) {
        let w = self.width;
        for i in s..e {
            let base = i * w;
            let mut acc = 0.0;
            for k in base..base + w {
                // SAFETY: k < n_rows*width == cols.len() == vals.len()
                // by construction; every stored col (incl. padding 0)
                // is < n_cols == x.len() (asserted by callers).
                unsafe {
                    acc += vals.get_unchecked(k).promote()
                        * x.get_unchecked(*self.cols.get_unchecked(k) as usize);
                }
            }
            let (sc, sv) = self.spill.row(i);
            for (c, v) in sc.iter().zip(sv) {
                // SAFETY: spill cols come from the source CSR, < n_cols.
                acc += v * unsafe { x.get_unchecked(*c as usize) };
            }
            out[i - s] = acc;
        }
    }

    /// Rows [s, e) of the SpMM Y = A X into `out` (row-major
    /// `(e-s) × ncols`); shared inner kernel of the serial and parallel
    /// block products, same accumulation order as [`Csr::matmat_into`].
    #[inline]
    fn rows_matmat<V: EllVal>(
        &self,
        vals: &[V],
        x: &[f64],
        ncols: usize,
        s: usize,
        e: usize,
        out: &mut [f64],
    ) {
        let w = self.width;
        for i in s..e {
            let yi = &mut out[(i - s) * ncols..(i - s + 1) * ncols];
            yi.fill(0.0);
            let base = i * w;
            for k in base..base + w {
                let c = unsafe { *self.cols.get_unchecked(k) } as usize;
                let v = unsafe { vals.get_unchecked(k) }.promote();
                // SAFETY: c < n_cols so c*ncols + ncols <= x.len() by
                // the callers' (hard-asserted) shape contract.
                let xr = unsafe { x.get_unchecked(c * ncols..c * ncols + ncols) };
                for (yj, xj) in yi.iter_mut().zip(xr) {
                    *yj += v * xj;
                }
            }
            let (sc, sv) = self.spill.row(i);
            for (c, v) in sc.iter().zip(sv) {
                let base = *c as usize * ncols;
                let xr = unsafe { x.get_unchecked(base..base + ncols) };
                for (yj, xj) in yi.iter_mut().zip(xr) {
                    *yj += v * xj;
                }
            }
        }
    }

    /// y = A x into a caller-provided buffer (serial).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        if self.use_f32 {
            self.rows_matvec(&self.vals32, x, 0, self.n_rows, y);
        } else {
            self.rows_matvec(&self.vals, x, 0, self.n_rows, y);
        }
    }

    /// Allocating wrapper over [`Ell::matvec_into`].
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Thread-parallel y = A x over disjoint row chunks,
    /// allocation-free.
    pub fn matvec_par_into(&self, x: &[f64], y: &mut [f64], threads: usize) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        parallel::par_rows_mut(y, 1, threads, |s, e, ys| {
            if self.use_f32 {
                self.rows_matvec(&self.vals32, x, s, e, ys);
            } else {
                self.rows_matvec(&self.vals, x, s, e, ys);
            }
        });
    }

    /// Allocating wrapper over [`Ell::matvec_par_into`].
    pub fn matvec_par(&self, x: &[f64], threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_par_into(x, &mut y, threads);
        y
    }

    /// SpMM Y = A X over a row-major `n_cols × ncols` block into the
    /// caller's row-major `n_rows × ncols` buffer (serial).
    pub fn matmat_into(&self, x: &[f64], ncols: usize, y: &mut [f64]) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        if self.use_f32 {
            self.rows_matmat(&self.vals32, x, ncols, 0, self.n_rows, y);
        } else {
            self.rows_matmat(&self.vals, x, ncols, 0, self.n_rows, y);
        }
    }

    /// Allocating wrapper over [`Ell::matmat_into`].
    pub fn matmat(&self, x: &[f64], ncols: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_into(x, ncols, &mut y);
        y
    }

    /// Thread-parallel SpMM over disjoint row chunks, allocation-free.
    pub fn matmat_par_into(&self, x: &[f64], ncols: usize, y: &mut [f64], threads: usize) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        parallel::par_rows_mut(y, ncols, threads, |s, e, rows| {
            if self.use_f32 {
                self.rows_matmat(&self.vals32, x, ncols, s, e, rows);
            } else {
                self.rows_matmat(&self.vals, x, ncols, s, e, rows);
            }
        });
    }

    /// Allocating wrapper over [`Ell::matmat_par_into`].
    pub fn matmat_par(&self, x: &[f64], ncols: usize, threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_par_into(x, ncols, &mut y, threads);
        y
    }
}

/// y = A x through the selected operand: the ELL when the layout
/// policy produced one, the CSR otherwise. `par` gates the threaded
/// kernels (callers keep their existing size thresholds).
#[inline]
pub fn spmv_dispatch(
    csr: &Csr,
    ell: Option<&Ell>,
    x: &[f64],
    y: &mut [f64],
    threads: usize,
    par: bool,
) {
    match ell {
        Some(e) if par => e.matvec_par_into(x, y, threads),
        Some(e) => e.matvec_into(x, y),
        None if par => csr.matvec_par_into(x, y, threads),
        None => csr.matvec_into(x, y),
    }
}

/// Blocked Y = A X through the selected operand (see
/// [`spmv_dispatch`]).
#[inline]
pub fn spmm_dispatch(
    csr: &Csr,
    ell: Option<&Ell>,
    x: &[f64],
    ncols: usize,
    y: &mut [f64],
    threads: usize,
    par: bool,
) {
    match ell {
        Some(e) if par => e.matmat_par_into(x, ncols, y, threads),
        Some(e) => e.matmat_into(x, ncols, y),
        None if par => csr.matmat_par_into(x, ncols, y, threads),
        None => csr.matmat_into(x, ncols, y),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sparse::CooBuilder;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    /// Random CSR with empty rows (rows are hit at random) and, at
    /// `nnz > width * n_rows`-ish densities, rows wide enough to spill.
    fn random_csr(rng: &mut Rng, n_rows: usize, n_cols: usize, nnz: usize) -> Csr {
        let mut b = CooBuilder::new(n_rows, n_cols);
        for _ in 0..nnz {
            b.push(
                rng.below(n_rows) as u32,
                rng.below(n_cols) as u32,
                rng.normal(),
            );
        }
        b.build()
    }

    /// Pack column vectors into the row-major block layout.
    fn pack(cols: &[Vec<f64>], n: usize) -> Vec<f64> {
        let b = cols.len();
        let mut block = vec![0.0; n * b];
        for (j, col) in cols.iter().enumerate() {
            for i in 0..n {
                block[i * b + j] = col[i];
            }
        }
        block
    }

    #[test]
    fn f64_ell_matvec_bit_identical_to_csr() {
        // Property: for random CSRs — including empty rows, non-square
        // shapes, and widths small enough that rows spill — the f64 ELL
        // matvec is BITWISE the CSR matvec, serial and parallel.
        proptest(48, |rng| {
            let n = 1 + rng.below(50);
            let m = 1 + rng.below(50);
            let a = random_csr(rng, n, m, 4 * n.max(m));
            let max_w = a.max_row_nnz();
            // Widths: 0 (all-spill), sub-max (some rows spill), exact,
            // and over-padded.
            for width in [0, max_w / 2, max_w, max_w + 3] {
                let ell = a.to_ell(width, false);
                let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
                let y_csr = a.matvec(&x);
                let y_ell = ell.matvec(&x);
                prop_assert!(
                    y_csr == y_ell,
                    "width {width}: f64 ELL matvec differs from CSR"
                );
                let y_par = ell.matvec_par(&x, 4);
                prop_assert!(y_ell == y_par, "width {width}: parallel differs");
            }
            Ok(())
        });
    }

    #[test]
    fn f64_ell_matmat_bit_identical_to_csr() {
        proptest(32, |rng| {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let b = 1 + rng.below(7);
            let a = random_csr(rng, n, m, 3 * n.max(m));
            let cols: Vec<Vec<f64>> = (0..b)
                .map(|_| (0..m).map(|_| rng.normal()).collect())
                .collect();
            let block = pack(&cols, m);
            let y_csr = a.matmat(&block, b);
            for width in [a.max_row_nnz() / 2, a.max_row_nnz() + 1] {
                let ell = a.to_ell(width, false);
                let y_ell = ell.matmat(&block, b);
                prop_assert!(
                    y_csr == y_ell,
                    "width {width}: f64 ELL SpMM differs from CSR"
                );
                let y_par = ell.matmat_par(&block, b, 4);
                prop_assert!(y_ell == y_par, "width {width}: parallel SpMM differs");
            }
            Ok(())
        });
    }

    #[test]
    fn f32_ell_within_relative_error_of_f64() {
        // Property: the f32 value path agrees with f64 to the f32
        // rounding bound, per row: the only error source is the one
        // rounding of each value (accumulators are f64), so
        // |y32 - y64| <= ~eps32 * sum_k |v_k x_k| with slack.
        proptest(32, |rng| {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(40);
            let a = random_csr(rng, n, m, 4 * n.max(m));
            let width = a.max_row_nnz() / 2;
            let ell64 = a.to_ell(width, false);
            let mut ell32 = a.to_ell(width, true);
            prop_assert!(ell32.uses_f32(), "to_ell must honor use_f32");
            let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let y64 = ell64.matvec(&x);
            let y32 = ell32.matvec(&x);
            let dense = a.to_dense();
            for i in 0..n {
                let row_mass: f64 =
                    dense[i].iter().zip(&x).map(|(v, xi)| (v * xi).abs()).sum();
                let bound = 1e-6 * row_mass + 1e-12;
                prop_assert!(
                    (y32[i] - y64[i]).abs() <= bound,
                    "row {i}: |{} - {}| > {bound}",
                    y32[i],
                    y64[i]
                );
            }
            // Same bound for the blocked kernel (single-column block).
            let yb32 = ell32.matmat(&x, 1);
            prop_assert!(yb32 == y32, "f32 SpMM column differs from f32 SpMV");
            // Toggling back to f64 recovers bitwise CSR parity.
            ell32.set_use_f32(false);
            prop_assert!(ell32.matvec(&x) == a.matvec(&x), "f64 toggle");
            Ok(())
        });
    }

    #[test]
    fn spill_split_is_lossless_and_ordered() {
        proptest(32, |rng| {
            let n = 1 + rng.below(30);
            let m = 1 + rng.below(30);
            let a = random_csr(rng, n, m, 5 * n);
            let width = a.max_row_nnz() / 3;
            let ell = a.to_ell(width, false);
            prop_assert!(
                ell.nnz() == a.nnz(),
                "nnz mismatch: {} vs {}",
                ell.nnz(),
                a.nnz()
            );
            // Every row: ELL head entries + spill tail == the CSR row.
            for r in 0..n {
                let (rc, rv) = a.row(r);
                let head = rc.len().min(ell.width);
                for k in 0..head {
                    prop_assert!(
                        ell.cols[r * ell.width + k] == rc[k]
                            && ell.vals[r * ell.width + k] == rv[k],
                        "row {r} slot {k} corrupted"
                    );
                }
                let (sc, sv) = ell.spill.row(r);
                prop_assert!(
                    sc == &rc[head..] && sv == &rv[head..],
                    "row {r} spill tail corrupted"
                );
            }
            // max-width conversion leaves the spill empty.
            prop_assert!(
                a.to_ell(a.max_row_nnz(), false).spill_nnz() == 0,
                "full-width conversion must not spill"
            );
            Ok(())
        });
    }

    #[test]
    fn auto_policy_accepts_regular_rejects_irregular() {
        // Near-uniform rows (the GRF feature shape): accepted.
        let mut rng = Rng::new(3);
        let mut b = CooBuilder::new(200, 200);
        for i in 0..200u32 {
            for k in 0..4 {
                b.push(i, (i + k) % 200, rng.normal());
            }
        }
        let regular = b.build();
        let ell = regular.to_ell_auto(false).expect("regular matrix -> ELL");
        assert!(ell.spill_nnz() as f64 <= ELL_MAX_SPILL_FRAC * regular.nnz() as f64);
        assert!(
            regular.row_width_stats().pad_ratio(ell.width) <= ELL_MAX_PAD_RATIO
        );

        // One dense row over an otherwise almost-empty matrix: the
        // width collapses to ~mean so nearly everything would spill.
        let mut b = CooBuilder::new(400, 400);
        for j in 0..400u32 {
            b.push(0, j, 1.0);
        }
        b.push(5, 5, 1.0);
        let skewed = b.build();
        assert!(
            skewed.to_ell_auto(false).is_none(),
            "spill-heavy pattern must stay CSR"
        );

        // Empty matrix: no ELL.
        assert!(Csr::zeros(10, 10).to_ell_auto(false).is_none());

        // select_ell honors forcing even where Auto rejects.
        assert!(skewed.select_ell(FeatureLayout::Auto).is_none());
        let forced = skewed.select_ell(FeatureLayout::EllF32).unwrap();
        assert!(forced.uses_f32());
        assert!(skewed.select_ell(FeatureLayout::Csr).is_none());
    }

    #[test]
    fn row_width_stats_match_pattern() {
        let mut b = CooBuilder::new(4, 8);
        b.push(0, 1, 1.0);
        b.push(0, 2, 1.0);
        b.push(0, 3, 1.0);
        b.push(2, 0, 1.0);
        let a = b.build();
        let st = a.row_width_stats();
        assert_eq!(st.n_rows, 4);
        assert_eq!(st.nnz, 4);
        assert_eq!(st.max, 3);
        assert!((st.mean - 1.0).abs() < 1e-12);
        assert!((st.pad_ratio(3) - 3.0).abs() < 1e-12);
        // Empty matrix edge.
        let st0 = Csr::zeros(0, 5).row_width_stats();
        assert_eq!(st0.max, 0);
        assert_eq!(st0.mean, 0.0);
        assert_eq!(st0.pad_ratio(7), 1.0);
    }

    #[test]
    fn empty_and_zero_width_edges() {
        // Empty matrix: width forced to 0, matvec is the zero map.
        let z = Csr::zeros(3, 4);
        let ell = z.to_ell(5, false);
        assert_eq!(ell.width, 0);
        assert_eq!(ell.matvec(&[1.0, 2.0, 3.0, 4.0]), vec![0.0; 3]);
        // Non-square with empty rows round-trips through matmat.
        let mut b = CooBuilder::new(3, 2);
        b.push(1, 0, 2.0);
        let a = b.build();
        let ell = a.to_ell(1, true);
        let y = ell.matmat(&[1.0, 10.0, 2.0, 20.0], 2);
        assert_eq!(y, vec![0.0, 0.0, 2.0, 20.0, 0.0, 0.0]);
    }
}
