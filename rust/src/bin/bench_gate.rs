//! CI perf-regression gate over the `BENCH_hotpath.json` trajectory.
//!
//! ```text
//! bench_gate <current.json> <baseline.json> [threshold]
//! ```
//!
//! Compares the fresh quick-profile bench run against the committed
//! baseline ([`grfgp::util::bench::gate_rows`]): rows are matched on
//! `(name, n, b)`, each row's current/baseline ratio is normalised by
//! the **median** ratio of the whole suite (so a uniformly
//! faster/slower CI runner shifts nothing), and any row whose
//! normalised slowdown exceeds the threshold (default 1.5×) fails the
//! process with exit code 1. `metric_*` rows, `*_iters` rows, rows
//! missing from the baseline, and sub-floor micro-timings are never
//! gated (see `gate_rows` docs).
//!
//! Environment overrides: `BENCH_GATE_THRESHOLD` (default 1.5),
//! `BENCH_GATE_MIN_NS` (noise floor, default 10000 = 10µs).
//!
//! ## How CI arms the gate
//!
//! The workflow keeps a **rolling baseline** in the Actions cache:
//! each green push to `main` caches its own quick-profile rows, and
//! later runs gate against the most recent cached entry (a failed
//! gate never advances it). The committed `BENCH_baseline.json` is
//! only the cold-cache fallback; while it is the empty seed `[]`,
//! `gate_rows` warns and passes, so the gate arms itself on the
//! second green CI run without any fabricated committed numbers.
//!
//! ## Refreshing the committed baseline
//!
//! The committed `BENCH_baseline.json` should track the quick profile
//! of a known-good commit measured on real hardware. After a
//! deliberate perf-affecting change (or to re-seed), run
//!
//! ```text
//! HOTPATH_PROFILE=quick cargo bench --bench hotpath
//! cp rust/BENCH_hotpath.json BENCH_baseline.json   # repo root
//! ```
//!
//! and commit the new baseline together with the change that moved it.

use grfgp::util::bench::{gate_rows, parse_rows_json};
use std::process::ExitCode;

fn read_rows(path: &str) -> Result<Vec<grfgp::util::bench::BenchRow>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_rows_json(&text).map_err(|e| format!("{path}: {e}"))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    if args.len() < 3 {
        eprintln!("usage: bench_gate <current.json> <baseline.json> [threshold]");
        return ExitCode::from(2);
    }
    let threshold: f64 = args
        .get(3)
        .cloned()
        .or_else(|| std::env::var("BENCH_GATE_THRESHOLD").ok())
        .map(|s| s.parse().expect("threshold must be a number"))
        .unwrap_or(1.5);
    let min_ns: f64 = std::env::var("BENCH_GATE_MIN_NS")
        .ok()
        .map(|s| s.parse().expect("BENCH_GATE_MIN_NS must be a number"))
        .unwrap_or(10_000.0);
    let (current, baseline) = match (read_rows(&args[1]), read_rows(&args[2])) {
        (Ok(c), Ok(b)) => (c, b),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench_gate: {e}");
            return ExitCode::from(2);
        }
    };
    let report = gate_rows(&current, &baseline, threshold, min_ns);
    println!(
        "bench_gate: {} rows matched, {} skipped, median ratio {:.3} \
         (machine-speed scale), threshold {threshold}x",
        report.matched.len(),
        report.skipped,
        report.median_ratio
    );
    for m in &report.matched {
        println!(
            "  {:<32} n={:<7} b={:<3} {:>12.0} -> {:>12.0} ns  ratio {:>6.2}  norm {:>6.2}{}",
            m.name,
            m.n,
            m.b,
            m.baseline_ns,
            m.current_ns,
            m.ratio,
            m.normalized,
            if m.normalized > threshold { "  << REGRESSION" } else { "" }
        );
    }
    if report.matched.is_empty() {
        println!(
            "bench_gate: WARNING — no gateable rows matched the baseline; \
             refresh BENCH_baseline.json from this run's BENCH_hotpath.json \
             (see the doc header of src/bin/bench_gate.rs)."
        );
        return ExitCode::SUCCESS;
    }
    if report.regressions.is_empty() {
        println!("bench_gate: OK — no row regressed past {threshold}x (normalised)");
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "bench_gate: FAIL — {} row(s) regressed past {threshold}x \
             (normalised); if intentional, refresh BENCH_baseline.json \
             (doc header of src/bin/bench_gate.rs)",
            report.regressions.len()
        );
        ExitCode::FAILURE
    }
}
