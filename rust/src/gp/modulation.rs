//! Modulation functions `f: N -> R` and GP hyperparameters.
//!
//! The GRF kernel is `K̂ = Φ(f) Φ(f)ᵀ` with `Φ(f) = Σ_l f_l C_l`; the
//! paper's two trainable variants are:
//!
//! * **diffusion-shape** — `f_l = σ_f · (-β/2)^l / l!` with learnable
//!   lengthscale β and scale σ_f (App. C.4): `Φ` estimates
//!   `σ_f exp(-(β/2) L̄)`-style series so `K̂ ≈ σ_f² K_diff`.
//! * **fully-learnable** — the `l_max+1` coefficients `f_l` are free
//!   parameters ("implicit kernel learning", §4.2).
//!
//! Positive quantities are parameterised on the log scale; every
//! variant exposes `coeffs()` and the Jacobian `d f_l / d param` so the
//! LML chain rule is exact.

/// Trainable modulation function.
///
/// Sign convention: the walk engine operates on the *normalised*
/// adjacency `Wn = D^{-1/2} W D^{-1/2}` (see `WalkConfig::normalize`),
/// so diffusion on the normalised Laplacian `exp(-βL̃) = e^{-β}
/// exp(+βWn)` is a **positive** power series in Wn — the `(−β)^l`
/// alternating series the paper writes for `exp(-βL)` corresponds to
/// expanding in L rather than W. We therefore take
/// `f_l = σ_f (β/2)^l / l!`, so `K̂ ≈ σ_f² exp(βWn) ∝ exp(-βL̃)` with
/// σ_f absorbing the `e^{-β}` constant.
#[derive(Clone, Debug)]
pub enum Modulation {
    /// f_l = exp(log_sigma_f) * (exp(log_beta)/2)^l / l!
    DiffusionShape {
        log_beta: f64,
        log_sigma_f: f64,
        l_max: usize,
    },
    /// Free coefficients.
    Learnable { f: Vec<f64> },
}

impl Modulation {
    pub fn diffusion(beta: f64, sigma_f: f64, l_max: usize) -> Modulation {
        Modulation::DiffusionShape {
            log_beta: beta.ln(),
            log_sigma_f: sigma_f.ln(),
            l_max,
        }
    }

    /// Random small init for the learnable variant (paper: "initialised
    /// randomly and learned via log marginal likelihood").
    pub fn learnable_init(l_max: usize, rng: &mut crate::util::rng::Rng) -> Modulation {
        let f = (0..=l_max)
            .map(|l| 0.5f64.powi(l as i32) * (1.0 + 0.2 * rng.normal()))
            .collect();
        Modulation::Learnable { f }
    }

    pub fn n_coeffs(&self) -> usize {
        match self {
            Modulation::DiffusionShape { l_max, .. } => l_max + 1,
            Modulation::Learnable { f } => f.len(),
        }
    }

    /// Number of trainable parameters.
    pub fn n_params(&self) -> usize {
        match self {
            Modulation::DiffusionShape { .. } => 2,
            Modulation::Learnable { f } => f.len(),
        }
    }

    /// Current parameter vector (unconstrained space).
    pub fn params(&self) -> Vec<f64> {
        match self {
            Modulation::DiffusionShape { log_beta, log_sigma_f, .. } => {
                vec![*log_beta, *log_sigma_f]
            }
            Modulation::Learnable { f } => f.clone(),
        }
    }

    pub fn set_params(&mut self, p: &[f64]) {
        match self {
            Modulation::DiffusionShape { log_beta, log_sigma_f, .. } => {
                *log_beta = p[0].clamp(-10.0, 5.0);
                *log_sigma_f = p[1].clamp(-10.0, 5.0);
            }
            Modulation::Learnable { f } => {
                f.copy_from_slice(p);
            }
        }
    }

    /// Modulation coefficients f_0..f_{l_max}.
    pub fn coeffs(&self) -> Vec<f64> {
        match self {
            Modulation::DiffusionShape { log_beta, log_sigma_f, l_max } => {
                let beta = log_beta.exp();
                let sf = log_sigma_f.exp();
                let mut out = Vec::with_capacity(l_max + 1);
                let mut term = sf; // l = 0
                out.push(term);
                for l in 1..=*l_max {
                    term *= beta / 2.0 / l as f64;
                    out.push(term);
                }
                out
            }
            Modulation::Learnable { f } => f.clone(),
        }
    }

    /// Jacobian J[p][l] = ∂ f_l / ∂ param_p.
    pub fn jacobian(&self) -> Vec<Vec<f64>> {
        match self {
            Modulation::DiffusionShape { l_max, .. } => {
                let f = self.coeffs();
                // ∂f_l/∂log_beta = l * f_l  (since f_l ∝ beta^l)
                // ∂f_l/∂log_sigma_f = f_l
                let d_beta: Vec<f64> =
                    f.iter().enumerate().map(|(l, v)| l as f64 * v).collect();
                let d_sf = f.clone();
                let _ = l_max;
                vec![d_beta, d_sf]
            }
            Modulation::Learnable { f } => {
                let n = f.len();
                let mut j = vec![vec![0.0; n]; n];
                for (p, row) in j.iter_mut().enumerate() {
                    row[p] = 1.0;
                }
                j
            }
        }
    }
}

/// Full GP hyperparameter set: modulation + observation noise.
#[derive(Clone, Debug)]
pub struct Hypers {
    pub modulation: Modulation,
    /// log σ_n² (unconstrained).
    pub log_noise: f64,
}

impl Hypers {
    pub fn new(modulation: Modulation, sigma_n2: f64) -> Hypers {
        Hypers { modulation, log_noise: sigma_n2.ln() }
    }

    pub fn sigma_n2(&self) -> f64 {
        self.log_noise.exp()
    }

    pub fn n_params(&self) -> usize {
        self.modulation.n_params() + 1
    }

    /// Packed parameter vector: [modulation..., log_noise].
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.modulation.params();
        p.push(self.log_noise);
        p
    }

    pub fn set_params(&mut self, p: &[f64]) {
        let nm = self.modulation.n_params();
        self.modulation.set_params(&p[..nm]);
        self.log_noise = p[nm].clamp(-12.0, 5.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diffusion_coeffs_match_series() {
        let m = Modulation::diffusion(2.0, 1.5, 4);
        let f = m.coeffs();
        // f_l = 1.5 * 1^l / l!  (positive series in the normalised
        // adjacency; see the sign-convention note on Modulation).
        let expect = [1.5, 1.5, 0.75, 0.25, 0.0625];
        for (a, b) in f.iter().zip(expect) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn jacobian_matches_finite_difference() {
        for m0 in [
            Modulation::diffusion(0.7, 1.2, 5),
            Modulation::Learnable { f: vec![1.0, -0.5, 0.25] },
        ] {
            let p0 = m0.params();
            let j = m0.jacobian();
            let f0 = m0.coeffs();
            let eps = 1e-6;
            for p in 0..m0.n_params() {
                let mut m1 = m0.clone();
                let mut p1 = p0.clone();
                p1[p] += eps;
                m1.set_params(&p1);
                let f1 = m1.coeffs();
                for l in 0..f0.len() {
                    let fd = (f1[l] - f0[l]) / eps;
                    assert!(
                        (j[p][l] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                        "param {p} coeff {l}: {} vs fd {fd}",
                        j[p][l]
                    );
                }
            }
        }
    }

    #[test]
    fn hypers_pack_roundtrip() {
        let mut h = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
        let p = h.params();
        assert_eq!(p.len(), 3);
        let mut p2 = p.clone();
        p2[2] = (0.5f64).ln();
        h.set_params(&p2);
        assert!((h.sigma_n2() - 0.5).abs() < 1e-12);
    }
}
