//! The sparse GRF-GP model: the paper's three-stage workflow
//! (*kernel initialisation → hyperparameter learning → posterior
//! inference*, §3.2) over the component-matrix representation.
//!
//! Everything runs through the masked gram operator
//! `A(v) = m Φ Φᵀ m v + σ² v` and CG (Lemma 1: `O(N^{3/2})`).
//!
//! Multi-RHS work — the `S+1` solves of a training step and the
//! pathwise sample batch of `predict` — goes through the **blocked**
//! path ([`GpModel::solve_system_block`]): one block-CG whose operator
//! application is two CSR SpMMs over the whole `n × B` block, instead
//! of `B` serial CG runs each streaming Φ per iteration for a single
//! vector. An optional Jacobi preconditioner (masked Φ row norms,
//! `O(nnz)`) cuts the iteration count on ill-conditioned kernels; it is
//! on by default via [`SolveConfig::precondition`].
//!
//! ## Two-level overlay: sub-linear graph deltas (O(touched nnz))
//!
//! A dynamic-graph delta flows through two delta row-stores that share
//! one compaction policy:
//!
//! 1. **Stream overlay** — [`crate::stream::StreamingFeatures`] (or a
//!    sharded [`crate::shard::ShardedFeatures`] — anything implementing
//!    [`DeltaEngine`]) resamples only the invalidated walks and stages
//!    the rebuilt feature rows over its compacted base CSRs (see
//!    `stream` module docs).
//! 2. **Model overlay** — this model mirrors that design for its own
//!    operands: Φ and Φᵀ live in [`Operand`]s (a
//!    [`crate::sparse::RowOverlay`], or its row-partitioned
//!    [`crate::shard::ShardedOverlay`] twin when
//!    [`GpModel::set_sharding`] is active — bitwise interchangeable,
//!    see the `shard` module docs), and
//!    [`CombinedFeatures`] keeps per-row pattern segments + relative
//!    scatter maps for the patched rows. A delta batch therefore costs
//!    O(touched nnz) model-side: no Φ clone, no full Φᵀ splice, no
//!    full scatter-map rebuild (each is counter-guarded —
//!    [`GpModel::phi_transposes`], [`GpModel::phi_overlay_stats`],
//!    `CombinedFeatures::full_map_builds`). Φᵀ is maintained by
//!    column-scatter ([`crate::sparse::RowOverlay::patch_transpose_rows`]),
//!    bitwise equal to a full transpose of the patched Φ.
//!
//! Both levels compact on the **same cadence**: when the stream's
//! overlay crosses its threshold and folds
//! ([`crate::stream::BatchSummary::compacted`]), the model folds its
//! Φ/Φᵀ/feature overlays too ([`GpModel::compact_model_overlays`]) and
//! the `to_ell_auto` layout policy re-runs on the fresh operands (the
//! packed ELL selection is pre-empted while an overlay is live, exactly
//! like the stream's `phi_ell`). Between compactions every product
//! dispatches overlay-then-base per row — bitwise identical to the
//! compacted matrix, so the correctness anchor (patched model ==
//! from-scratch rebuild, bit for bit) is untouched.

use crate::gp::adam::Adam;
use crate::gp::modulation::Hypers;
use crate::linalg::cg::{block_cg_solve, pcg_solve, CgStats};
use crate::linalg::{column_dots, dot};
use crate::shard::{Operand, Partition};
use crate::sparse::{Csr, Ell, FeatureLayout};
use crate::stream::{DeltaEngine, GraphDelta};
use crate::util::parallel::num_threads;
use crate::util::rng::Rng;
use crate::walks::{CombinedFeatures, WalkComponents};
use std::sync::{Arc, Mutex, PoisonError};

/// Solver settings shared by training and inference.
#[derive(Clone, Debug)]
pub struct SolveConfig {
    pub tol: f64,
    pub max_iters: usize,
    /// Hutchinson probes per gradient step (paper Eq. 10's S).
    pub probes: usize,
    pub threads: usize,
    /// Jacobi-precondition the CG solves with diag(H) = m‖φ_i‖² + σ².
    pub precondition: bool,
    /// Per-matrix SpMV/SpMM operand layout for the H-operator
    /// applications, re-selected whenever Φ changes
    /// (`refresh_features`). [`FeatureLayout::Auto`] (default) packs
    /// regular-width matrices into native ELL — bit-identical results,
    /// pure memory-layout win; [`FeatureLayout::EllF32`] additionally
    /// stores values in f32 (f64 accumulators), halving the value
    /// traffic of the bandwidth-bound solver at ~6e-8 relative
    /// rounding of Φ's Monte-Carlo-estimated entries.
    pub layout: FeatureLayout,
}

impl Default for SolveConfig {
    fn default() -> Self {
        SolveConfig {
            tol: 1e-6,
            max_iters: 256,
            probes: 8,
            threads: 0,
            precondition: true,
            layout: FeatureLayout::Auto,
        }
    }
}

impl SolveConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            num_threads()
        } else {
            self.threads
        }
    }
}

/// Per-training-step diagnostics.
#[derive(Clone, Debug)]
pub struct TrainStep {
    pub step: usize,
    pub grad_norm: f64,
    pub cg_iters: usize,
    pub sigma_n2: f64,
}

/// What [`GpModel::apply_graph_delta`] did: incremental-work counters
/// plus the refreshed posterior-mean solve for chaining warm starts.
#[derive(Clone, Debug)]
pub struct DeltaOutcome {
    /// Walks actually re-run (the delta endpoints' visit sets).
    pub resampled_walks: usize,
    /// Feature rows rebuilt and patched into the model.
    pub patched_rows: usize,
    pub added_node: Option<usize>,
    pub compacted: bool,
    /// Refreshed α = H⁻¹ (m y) on the mutated graph — feed it back as
    /// `warm` on the next delta.
    pub alpha: Vec<f64>,
    pub solve_stats: CgStats,
}

/// What [`GpModel::apply_graph_delta_batch`] did: one union feature
/// patch + one warm re-solve shared by the whole batch, plus per-delta
/// acks for the server protocol.
#[derive(Clone, Debug)]
pub struct BatchDeltaOutcome {
    /// One ack per input delta, in order.
    pub deltas: Vec<crate::stream::DeltaAck>,
    /// Union of walks re-run (each exactly once, on the final graph).
    pub resampled_walks: usize,
    /// Feature rows rebuilt and patched (once per batch).
    pub patched_rows: usize,
    pub compacted: bool,
    /// Refreshed α = H⁻¹ (m y) on the mutated graph — feed it back as
    /// `warm` on the next delta or batch.
    pub alpha: Vec<f64>,
    pub solve_stats: CgStats,
}

/// Sparse GRF Gaussian process.
pub struct GpModel {
    /// Cached walk components + union pattern for fast recombination.
    pub features: CombinedFeatures,
    pub hypers: Hypers,
    /// {0,1} training mask over all N nodes.
    pub mask: Vec<f64>,
    /// Observations embedded in R^N (zero off-train).
    pub y: Vec<f64>,
    pub solve: SolveConfig,
    /// Transposes of each C_l (for modulation gradients). None = stale
    /// (invalidated by a graph delta); lazily rebuilt on the next
    /// `lml_grad`, so serving-path deltas don't pay for operands only
    /// hyperparameter fitting reads.
    c_t: std::cell::RefCell<Option<Vec<Csr>>>,
    /// Current Φ and Φᵀ as compacted-base + delta-row overlays: a
    /// hyperparameter refresh rebuilds the bases; a graph delta stages
    /// O(touched) row patches and leaves the bases alone (module docs).
    /// Stored behind [`Operand`] so the same solve/predict code runs
    /// over a mono `RowOverlay` or the row-partitioned sharded twin.
    phi: Operand,
    phi_t: Operand,
    /// Node partition the operands are stored under (`None` = mono).
    /// Purely a storage-mode choice: every product, solve, and patch is
    /// bitwise identical either way ([`crate::shard`] module docs).
    partition: Option<Partition>,
    /// Scratch buffers for the masked gram operator — the CG hot path
    /// must not allocate per iteration (EXPERIMENTS.md §Perf).
    scratch: std::cell::RefCell<SolveScratch>,
    /// Cached Jacobi diagonal of H (None = stale). Invalidated when Φ,
    /// the mask, or σ² change (`refresh_features` / `set_data`), so the
    /// many solves between hyperparameter updates (posterior mean,
    /// every Thompson draw of a BO loop) don't re-pay the O(nnz) pass.
    jacobi_cache: std::cell::RefCell<Option<Vec<f64>>>,
    /// ELL operands for (Φ, Φᵀ) selected under `solve.layout`
    /// (None = use the CSR). Rebuilt lazily whenever Φ changes
    /// (`refresh_features`) or the layout policy flips, so a direct
    /// `model.solve.layout = …` assignment takes effect on the next
    /// operator application.
    ell_cache: std::cell::RefCell<Option<EllSelection>>,
    /// Count of full Φ transposes taken (`transpose_par`) —
    /// observability for the delta path, which patches Φᵀ by
    /// column-scatter instead and must leave this untouched.
    phi_transposes: std::cell::Cell<usize>,
    /// Modulation coefficients Φ/Φᵀ were last combined under. The
    /// delta path's partial recombination is only valid while this
    /// matches the live hypers; a mismatch (hypers mutated without
    /// `refresh_features`) falls back to a full refresh instead of
    /// silently mixing two modulations.
    phi_f: Vec<f64>,
}

/// (policy it was built under, Φ operand, Φᵀ operand). The operands
/// are `Arc`-shared so a published [`ModelReadView`] reuses them
/// without re-packing or copying.
type EllSelection = (FeatureLayout, Option<Arc<Ell>>, Option<Arc<Ell>>);

/// Reusable buffers for the masked gram operator — the CG hot path
/// must not allocate per iteration. One instance serves both the
/// single-vector ([`SolveCore::apply_h`]) and the blocked
/// ([`SolveCore::apply_h_block`]) operator.
pub struct SolveScratch {
    mx: Vec<f64>,
    mid: Vec<f64>,
    prod: Vec<f64>,
    blk_x: Vec<f64>,
    blk_mid: Vec<f64>,
}

impl SolveScratch {
    pub fn new(n: usize) -> SolveScratch {
        SolveScratch {
            mx: vec![0.0; n],
            mid: vec![0.0; n],
            prod: vec![0.0; n],
            blk_x: Vec::new(),
            blk_mid: Vec::new(),
        }
    }

    /// Grow the single-vector buffers after node insertion.
    fn grow(&mut self, n: usize) {
        self.mx.resize(n, 0.0);
        self.mid.resize(n, 0.0);
        self.prod.resize(n, 0.0);
    }
}

/// Borrowed bundle of everything the solve/predict math reads, plus
/// the math itself. This is the **single implementation** behind both
/// [`GpModel`] (live, mutable, `RefCell` caches) and
/// [`ModelReadView`] (owned, immutable, `Send + Sync` snapshot) — the
/// two entry points are bitwise-identical by construction because
/// they execute literally the same code over the same operand kinds.
pub struct SolveCore<'a> {
    pub phi: &'a Operand,
    pub phi_t: &'a Operand,
    pub phi_ell: Option<&'a Ell>,
    pub phi_t_ell: Option<&'a Ell>,
    pub mask: &'a [f64],
    pub y: &'a [f64],
    pub sigma2: f64,
    pub tol: f64,
    pub max_iters: usize,
    pub threads: usize,
    pub jacobi: Option<&'a [f64]>,
}

impl<'a> SolveCore<'a> {
    fn n(&self) -> usize {
        self.mask.len()
    }

    /// y = m Φ Φᵀ m x + σ² x (see [`GpModel`] module docs).
    fn apply_h(&self, scratch: &mut SolveScratch, x: &[f64], out: &mut [f64]) {
        let n = self.n();
        let k = self.phi.n_cols();
        let par = self.threads > 1 && n > 4096;
        scratch.mx.resize(n, 0.0);
        scratch.mid.resize(k, 0.0);
        scratch.prod.resize(n, 0.0);
        for i in 0..n {
            scratch.mx[i] = self.mask[i] * x[i];
        }
        self.phi_t
            .spmv(self.phi_t_ell, &scratch.mx, &mut scratch.mid, self.threads, par);
        self.phi
            .spmv(self.phi_ell, &scratch.mid, &mut scratch.prod, self.threads, par);
        for i in 0..n {
            out[i] = self.mask[i] * scratch.prod[i] + self.sigma2 * x[i];
        }
    }

    /// Blocked operator: `Y = m Φ Φᵀ m X + σ² X` over a row-major
    /// `n × ncols` block — two SpMMs serve all `ncols` vectors.
    fn apply_h_block(
        &self,
        scratch: &mut SolveScratch,
        x: &[f64],
        ncols: usize,
        out: &mut [f64],
    ) {
        let n = self.n();
        let k = self.phi.n_cols();
        let par = self.threads > 1 && n > 4096;
        debug_assert_eq!(x.len(), n * ncols);
        debug_assert_eq!(out.len(), n * ncols);
        scratch.blk_x.resize(n * ncols, 0.0);
        scratch.blk_mid.resize(k * ncols, 0.0);
        for i in 0..n {
            let m = self.mask[i];
            let base = i * ncols;
            for j in 0..ncols {
                scratch.blk_x[base + j] = m * x[base + j];
            }
        }
        self.phi_t.spmm(
            self.phi_t_ell,
            &scratch.blk_x,
            ncols,
            &mut scratch.blk_mid,
            self.threads,
            par,
        );
        self.phi
            .spmm(self.phi_ell, &scratch.blk_mid, ncols, out, self.threads, par);
        for i in 0..n {
            let m = self.mask[i];
            let base = i * ncols;
            for j in 0..ncols {
                out[base + j] = m * out[base + j] + self.sigma2 * x[base + j];
            }
        }
    }

    /// Solve (m K m + σ² I) v = b by (optionally preconditioned) CG.
    pub fn solve_system(
        &self,
        scratch: &mut SolveScratch,
        b: &[f64],
    ) -> (Vec<f64>, CgStats) {
        pcg_solve(
            |x, out| self.apply_h(scratch, x, out),
            b,
            None,
            self.jacobi,
            self.tol,
            self.max_iters,
        )
    }

    /// Block solve with optional warm start (row-major `n × ncols`).
    pub fn solve_system_block_warm(
        &self,
        scratch: &mut SolveScratch,
        b: &[f64],
        ncols: usize,
        x0: Option<&[f64]>,
    ) -> (Vec<f64>, Vec<CgStats>) {
        block_cg_solve(
            |x, out| self.apply_h_block(scratch, x, ncols, out),
            b,
            ncols,
            x0,
            self.jacobi,
            self.tol,
            self.max_iters,
        )
    }

    /// Kernel product y = Φ (Φᵀ x) (no mask/noise).
    pub fn apply_kernel(&self, x: &[f64]) -> Vec<f64> {
        if self.threads > 1 && self.n() > 4096 {
            let mid = self.phi_t.matvec_par(x, self.threads);
            self.phi.matvec_par(&mid, self.threads)
        } else {
            self.phi.matvec(&self.phi_t.matvec(x))
        }
    }

    /// Posterior mean at every node: K (m α) with α = H⁻¹ (m y).
    pub fn posterior_mean(&self, scratch: &mut SolveScratch) -> (Vec<f64>, CgStats) {
        let rhs: Vec<f64> = self
            .mask
            .iter()
            .zip(self.y.iter())
            .map(|(m, y)| m * y)
            .collect();
        let (alpha, st) = self.solve_system(scratch, &rhs);
        let malpha: Vec<f64> = self
            .mask
            .iter()
            .zip(&alpha)
            .map(|(m, a)| m * a)
            .collect();
        (self.apply_kernel(&malpha), st)
    }

    /// `n_samples` pathwise-conditioning draws through one blocked
    /// solve. Randomness is drawn per sample in the same order as the
    /// historic serial loop (`w_j`, then sample `j`'s per-node noise).
    pub fn posterior_samples(
        &self,
        scratch: &mut SolveScratch,
        n_samples: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<f64>> {
        if n_samples == 0 {
            return Vec::new();
        }
        let n = self.n();
        let b = n_samples;
        let k = self.phi.n_cols();
        let par = self.threads > 1 && n > 4096;
        let sigma = self.sigma2.sqrt();

        let mut w = vec![0.0; k * b];
        let mut eps = vec![0.0; n * b];
        for j in 0..b {
            for i in 0..k {
                w[i * b + j] = rng.normal();
            }
            for i in 0..n {
                eps[i * b + j] = rng.normal();
            }
        }
        // Prior draws g = Φ W over the whole block.
        let g = if par {
            self.phi.matmat_par(&w, b, self.threads)
        } else {
            self.phi.matmat(&w, b)
        };
        // Masked residual block m (y − g − σ ε).
        let mut rhs = vec![0.0; n * b];
        for i in 0..n {
            let m = self.mask[i];
            let base = i * b;
            for j in 0..b {
                rhs[base + j] = m * (self.y[i] - g[base + j] - sigma * eps[base + j]);
            }
        }
        let (alpha, _) = self.solve_system_block_warm(scratch, &rhs, b, None);
        // Kernel correction K (m α) for all samples: two more SpMMs.
        let mut malpha = alpha;
        for i in 0..n {
            let m = self.mask[i];
            let base = i * b;
            for j in 0..b {
                malpha[base + j] *= m;
            }
        }
        let mid = if par {
            self.phi_t.matmat_par(&malpha, b, self.threads)
        } else {
            self.phi_t.matmat(&malpha, b)
        };
        let corr = if par {
            self.phi.matmat_par(&mid, b, self.threads)
        } else {
            self.phi.matmat(&mid, b)
        };
        (0..b)
            .map(|j| (0..n).map(|i| g[i * b + j] + corr[i * b + j]).collect())
            .collect()
    }

    /// Predictive mean + variance given an already-computed posterior
    /// mean (the mean solve is rng-free, so callers may cache it).
    pub fn predict_with_mean(
        &self,
        scratch: &mut SolveScratch,
        mean: &[f64],
        n_samples: usize,
        rng: &mut Rng,
    ) -> (Vec<f64>, Vec<f64>) {
        let n = self.n();
        let mut m2 = vec![0.0; n];
        for s in self.posterior_samples(scratch, n_samples, rng) {
            for i in 0..n {
                let d = s[i] - mean[i];
                m2[i] += d * d;
            }
        }
        let var: Vec<f64> = m2
            .iter()
            .map(|v| v / n_samples.max(1) as f64 + self.sigma2)
            .collect();
        (mean.to_vec(), var)
    }
}

/// An immutable, owned snapshot of everything the inference path
/// reads: Φ/Φᵀ overlay views (`Arc`-shared compacted bases, so the
/// clone is O(overlay rows)), the packed ELL operands, mask, targets,
/// hyperparameters, solver settings, and the Jacobi diagonal. It is
/// `Send + Sync` (no interior mutability beyond a `Mutex`-guarded
/// lazy mean), so server read paths can run predictions concurrently
/// **without the model lock** — and because it drives the same
/// [`SolveCore`] the live model does, its answers are bitwise
/// identical to [`GpModel::predict`] on the same state and rng.
pub struct ModelReadView {
    phi: Operand,
    phi_t: Operand,
    phi_ell: Option<Arc<Ell>>,
    phi_t_ell: Option<Arc<Ell>>,
    mask: Vec<f64>,
    y: Vec<f64>,
    sigma2: f64,
    tol: f64,
    max_iters: usize,
    threads: usize,
    jacobi: Option<Vec<f64>>,
    /// Lazily computed posterior mean, shared across requests: the
    /// cold mean solve is deterministic and rng-free, so caching it
    /// cannot perturb any bitwise contract.
    mean_cache: Mutex<Option<Arc<Vec<f64>>>>,
}

impl ModelReadView {
    pub fn n(&self) -> usize {
        self.mask.len()
    }

    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }

    fn core(&self) -> SolveCore<'_> {
        SolveCore {
            phi: &self.phi,
            phi_t: &self.phi_t,
            phi_ell: self.phi_ell.as_deref(),
            phi_t_ell: self.phi_t_ell.as_deref(),
            mask: &self.mask,
            y: &self.y,
            sigma2: self.sigma2,
            tol: self.tol,
            max_iters: self.max_iters,
            threads: self.threads,
            jacobi: self.jacobi.as_deref(),
        }
    }

    /// Posterior mean over all nodes, computed once per view and
    /// shared by every subsequent prediction off this snapshot.
    pub fn posterior_mean(&self) -> Arc<Vec<f64>> {
        let mut cache = self
            .mean_cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if cache.is_none() {
            let mut scratch = SolveScratch::new(self.n());
            let (mean, _) = self.core().posterior_mean(&mut scratch);
            *cache = Some(Arc::new(mean));
        }
        cache.as_ref().expect("filled above").clone()
    }

    /// Predictive mean + variance at every node — bitwise what
    /// [`GpModel::predict`] returns on the same state and rng stream.
    pub fn predict(&self, n_samples: usize, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
        let mean = self.posterior_mean();
        let mut scratch = SolveScratch::new(self.n());
        self.core()
            .predict_with_mean(&mut scratch, &mean, n_samples, rng)
    }

    /// `n_samples` pathwise posterior draws off the snapshot.
    pub fn posterior_samples(&self, n_samples: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
        let mut scratch = SolveScratch::new(self.n());
        self.core().posterior_samples(&mut scratch, n_samples, rng)
    }
}

impl GpModel {
    /// Build from walk components. `train_nodes` and `train_y` define
    /// the observed data; all other nodes are latent.
    pub fn new(
        components: WalkComponents,
        hypers: Hypers,
        train_nodes: &[usize],
        train_y: &[f64],
    ) -> GpModel {
        assert_eq!(train_nodes.len(), train_y.len());
        assert_eq!(
            hypers.modulation.n_coeffs(),
            components.n_coeffs(),
            "modulation length must equal l_max+1 of the walk components"
        );
        let n = components.n();
        let mut mask = vec![0.0; n];
        let mut y = vec![0.0; n];
        for (&i, &v) in train_nodes.iter().zip(train_y) {
            mask[i] = 1.0;
            y[i] = v;
        }
        let threads = num_threads();
        let c_t = components
            .c
            .iter()
            .map(|c| c.transpose_par(threads))
            .collect();
        let mut features = components.prepare();
        let phi_f = hypers.modulation.coeffs();
        let phi = features.combine_into(&phi_f).clone();
        let phi_t = Operand::from_csr(phi.transpose_par(threads), None);
        let phi = Operand::from_csr(phi, None);
        GpModel {
            features,
            hypers,
            mask,
            y,
            solve: SolveConfig::default(),
            c_t: std::cell::RefCell::new(Some(c_t)),
            phi,
            phi_t,
            partition: None,
            scratch: std::cell::RefCell::new(SolveScratch::new(n)),
            jacobi_cache: std::cell::RefCell::new(None),
            ell_cache: std::cell::RefCell::new(None),
            phi_transposes: std::cell::Cell::new(1),
            phi_f,
        }
    }

    /// Switch the Φ/Φᵀ storage mode: `Some(p)` re-wraps both operands
    /// as row-partitioned [`crate::shard::ShardedOverlay`]s under `p`,
    /// `None` folds them back to mono. The fold-and-rewrap is one
    /// O(nnz) pass per operand and preserves every stored value bit, so
    /// all downstream products and solves are unchanged; the packed ELL
    /// selection is invalidated because the sharded mode never offers
    /// one ([`Operand::select_ell`]).
    pub fn set_sharding(&mut self, partition: Option<Partition>) {
        if self.partition == partition {
            return;
        }
        let phi = self.phi.to_csr();
        let phi_t = self.phi_t.to_csr();
        self.phi = Operand::from_csr(phi, partition);
        self.phi_t = Operand::from_csr(phi_t, partition);
        self.partition = partition;
        *self.ell_cache.borrow_mut() = None;
    }

    /// The node partition the operands are stored under (`None` = mono).
    pub fn partition(&self) -> Option<Partition> {
        self.partition
    }

    /// Φ folded to a plain CSR — test/diagnostic oracle for the
    /// sharded-vs-mono bit-identity suites.
    pub fn phi_csr(&self) -> Csr {
        self.phi.to_csr()
    }

    /// Φᵀ folded to a plain CSR (see [`GpModel::phi_csr`]).
    pub fn phi_t_csr(&self) -> Csr {
        self.phi_t.to_csr()
    }

    /// How many full Φ transposes (`transpose_par`) this model has run
    /// (1 from the constructor, +1 per `refresh_features`). The graph
    /// delta path patches Φᵀ incrementally and must not move this.
    pub fn phi_transposes(&self) -> usize {
        self.phi_transposes.get()
    }

    /// Overlay observability for the sub-linear delta path:
    /// `(phi_overlay_rows, phi_t_overlay_rows, phi_compactions,
    /// phi_t_compactions)`. Delta batches grow the first two and leave
    /// the compaction counts alone until the stream's compaction
    /// cadence fires (counter-guarded in the tests).
    pub fn phi_overlay_stats(&self) -> (usize, usize, usize, usize) {
        (
            self.phi.overlay_rows(),
            self.phi_t.overlay_rows(),
            self.phi.compactions(),
            self.phi_t.compactions(),
        )
    }

    /// Fold the model-side overlays (Φ, Φᵀ, and the feature
    /// recombiner's row store) back into compacted bases — one O(nnz)
    /// splice each. Runs automatically on the stream's compaction
    /// cadence ([`GpModel::apply_graph_delta_batch`]); callers that
    /// want the per-batch memcpy cost profile back (memory-tight
    /// deployments, the `model_delta_batch_memcpy` bench contrast) can
    /// invoke it after every batch. The packed ELL operands re-select
    /// lazily from the fresh bases at the next application.
    pub fn compact_model_overlays(&mut self) {
        self.features.compact();
        self.phi.compact();
        self.phi_t.compact();
        *self.ell_cache.borrow_mut() = None;
    }

    pub fn n(&self) -> usize {
        self.mask.len()
    }

    pub fn n_train(&self) -> usize {
        self.mask.iter().filter(|&&m| m == 1.0).count()
    }

    /// Refresh Φ after a hyperparameter update. Runs on every Adam
    /// step, so the transpose goes through the parallel path. The ELL
    /// operand selection is invalidated here and re-derived (lazily,
    /// under `solve.layout`) at the next operator application.
    fn refresh_features(&mut self) {
        let f = self.hypers.modulation.coeffs();
        // `combine_into` folds any pending feature overlay first, so
        // the rebuilt Φ/Φᵀ start a fresh compacted generation.
        let phi = self.features.combine_into(&f).clone();
        let phi_t = phi.transpose_par(self.solve.effective_threads());
        self.phi = Operand::from_csr(phi, self.partition);
        self.phi_t = Operand::from_csr(phi_t, self.partition);
        self.phi_transposes.set(self.phi_transposes.get() + 1);
        self.phi_f = f;
        *self.jacobi_cache.borrow_mut() = None;
        *self.ell_cache.borrow_mut() = None;
    }

    /// The (lazily selected) ELL operands for the current Φ under
    /// `solve.layout`; rebuilt when Φ or the policy changed.
    fn ell_ops(&self) -> std::cell::Ref<'_, EllSelection> {
        {
            let mut cache = self.ell_cache.borrow_mut();
            let stale = match &*cache {
                Some((l, _, _)) => *l != self.solve.layout,
                None => true,
            };
            if stale {
                let layout = self.solve.layout;
                *cache = Some((
                    layout,
                    self.phi.select_ell(layout).map(Arc::new),
                    self.phi_t.select_ell(layout).map(Arc::new),
                ));
            }
        }
        std::cell::Ref::map(self.ell_cache.borrow(), |c| {
            c.as_ref().expect("filled above")
        })
    }

    /// Replace observations (BO adds one point per step).
    pub fn set_data(&mut self, train_nodes: &[usize], train_y: &[f64]) {
        self.mask.iter_mut().for_each(|m| *m = 0.0);
        self.y.iter_mut().for_each(|v| *v = 0.0);
        for (&i, &v) in train_nodes.iter().zip(train_y) {
            self.mask[i] = 1.0;
            self.y[i] = v;
        }
        *self.jacobi_cache.borrow_mut() = None;
    }

    /// Apply a graph mutation to a live model: the stream resamples
    /// only the invalidated walks, then exactly the affected feature
    /// rows are patched through ([`CombinedFeatures::patch_rows`]), the
    /// gram operator refreshed (Φ/Φᵀ recombined, modulation-gradient
    /// operands rebuilt, layout/Jacobi caches invalidated), and the
    /// posterior-mean system re-solved via
    /// [`GpModel::solve_system_block_warm`] seeded from the pre-delta
    /// solution `warm` (zero-padded if the graph grew).
    ///
    /// After this returns, the model is **bit-identical** to one built
    /// from scratch on the mutated graph with the same per-walk seeds
    /// (same components, same union pattern, same solves) — the
    /// streaming subsystem's correctness anchor.
    pub fn apply_graph_delta(
        &mut self,
        stream: &mut impl DeltaEngine,
        delta: &GraphDelta,
        warm: Option<&[f64]>,
    ) -> Result<DeltaOutcome, String> {
        let out = self.apply_graph_delta_batch(
            stream,
            std::slice::from_ref(delta),
            warm,
        )?;
        Ok(DeltaOutcome {
            resampled_walks: out.resampled_walks,
            patched_rows: out.patched_rows,
            added_node: out.deltas[0].added_node,
            compacted: out.compacted,
            alpha: out.alpha,
            solve_stats: out.solve_stats,
        })
    }

    /// Batched [`GpModel::apply_graph_delta`]: the delta engine applies
    /// the whole batch with one union invalidation + parallel resample
    /// ([`crate::stream::StreamingFeatures::apply_delta_batch`], or the
    /// per-shard fan-out of [`crate::shard::ShardedFeatures`]), then
    /// the model pays **one** union row patch, one incremental operator
    /// refresh, and one warm re-solve for the entire batch. The
    /// post-batch model is bit-identical to one built from scratch on
    /// the mutated graph under the same per-walk seeds — whichever
    /// engine maintained the features.
    pub fn apply_graph_delta_batch(
        &mut self,
        stream: &mut impl DeltaEngine,
        deltas: &[GraphDelta],
        warm: Option<&[f64]>,
    ) -> Result<BatchDeltaOutcome, String> {
        if stream.n() != self.n() {
            return Err(format!(
                "stream tracks {} nodes, model {} — not the same graph",
                stream.n(),
                self.n()
            ));
        }
        let n_len = self.features.components.n_coeffs();
        if stream.walk_config().max_len + 1 != n_len {
            return Err(format!(
                "stream l_max+1 = {} != model modulation length {n_len}",
                stream.walk_config().max_len + 1
            ));
        }
        let summary = stream.apply_delta_batch(deltas)?;
        let n = stream.n();
        // Old Φ row supports of the affected rows: the Φᵀ rows that
        // must *drop* entries (gains are read off the patched Φ below).
        let old_supports: Vec<(u32, Vec<u32>)> = summary
            .affected_rows
            .iter()
            .filter(|&&r| (r as usize) < self.phi.n_rows())
            .map(|&r| (r, self.phi.row(r as usize).0.to_vec()))
            .collect();
        let mut patches: std::collections::BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>> =
            Default::default();
        for &r in &summary.affected_rows {
            patches.insert(
                r,
                (0..n_len)
                    .map(|l| stream.component_row(l, r as usize))
                    .collect(),
            );
        }
        // O(touched nnz): the affected rows' pattern segments +
        // relative scatter maps land in the feature overlay — no
        // component splice, no full map rebuild.
        self.features.patch_rows(n, &patches);
        if self.mask.len() < n {
            // Node insertion: grow the observation embedding and the
            // operator scratch (new nodes start unobserved).
            self.mask.resize(n, 0.0);
            self.y.resize(n, 0.0);
            self.scratch.borrow_mut().grow(n);
        }
        // The modulation-gradient operands C_lᵀ are only read by
        // `lml_grad`; invalidate them here and rebuild lazily so the
        // serving-path delta cost stays independent of fitting.
        *self.c_t.borrow_mut() = None;
        // Incremental operator refresh: recombine only the patched Φ
        // rows (the modulation is unchanged on the delta path, so every
        // other slot already holds the current combination), stage them
        // in the Φ overlay, and column-scatter into the Φᵀ overlay — no
        // Φ clone, no Φᵀ splice, no `transpose_par` here. If the hypers
        // were mutated without `refresh_features` the partial invariant
        // is void: fall back to the full refresh rather than silently
        // mixing two modulations.
        let f = self.hypers.modulation.coeffs();
        if f == self.phi_f {
            self.features.recombine_rows(&f, &summary.affected_rows);
            self.phi.grow(n, n);
            for &r in &summary.affected_rows {
                let (cols, vals) = self.features.pattern_row(r as usize);
                self.phi.patch_row(r, cols.to_vec(), vals.to_vec());
            }
            self.phi_t.patch_transpose_rows(
                &self.phi,
                &summary.affected_rows,
                &old_supports,
            );
            // Patch the Jacobi diagonal in place rather than dropping
            // it: only the touched rows' ‖φ_i‖² moved (mask and σ² are
            // delta-invariant on this branch), so the cached
            // preconditioner stays O(touched) too. Appended nodes are
            // unobserved, d = σ². Entry-for-entry what a fresh
            // `jacobi_diag` would compute (same accumulation order).
            {
                let mut cache = self.jacobi_cache.borrow_mut();
                if let Some(d) = cache.as_mut() {
                    let sigma2 = self.hypers.sigma_n2();
                    d.resize(n, sigma2);
                    for &r in &summary.affected_rows {
                        let i = r as usize;
                        d[i] = sigma2;
                        if self.mask[i] != 0.0 {
                            let (_, vals) = self.phi.row(i);
                            let mut acc = 0.0;
                            for v in vals {
                                acc += v * v;
                            }
                            d[i] += acc;
                        }
                    }
                }
            }
            *self.ell_cache.borrow_mut() = None;
            // Shared compaction cadence: when the stream folded its
            // overlay this batch, fold the model-side overlays too and
            // let the layout policy re-select on the fresh bases.
            if summary.compacted {
                self.compact_model_overlays();
            }
        } else {
            self.refresh_features();
        }
        let rhs: Vec<f64> =
            self.mask.iter().zip(&self.y).map(|(m, y)| m * y).collect();
        let x0: Option<Vec<f64>> = warm.map(|w| {
            let mut v = vec![0.0; n];
            let k = w.len().min(n);
            v[..k].copy_from_slice(&w[..k]);
            v
        });
        let (alpha, stats) = self.solve_system_block_warm(&rhs, 1, x0.as_deref());
        let solve_stats = stats.into_iter().next().expect("one column");
        Ok(BatchDeltaOutcome {
            deltas: summary.deltas,
            resampled_walks: summary.resampled.len(),
            patched_rows: summary.affected_rows.len(),
            compacted: summary.compacted,
            alpha,
            solve_stats,
        })
    }

    // ------------------------------------------------------------------
    // Masked gram operator (the math lives in [`SolveCore`]; the model
    // assembles a borrowed core over its caches and delegates)
    // ------------------------------------------------------------------

    /// Assemble a borrowed [`SolveCore`] over the model's live state
    /// (lazily filling the ELL/Jacobi caches) plus the reusable
    /// scratch, and run `f` on it. Every solve and inference entry
    /// point funnels through here, so the live model and a published
    /// [`ModelReadView`] execute the exact same code.
    fn with_core<R>(&self, f: impl FnOnce(&SolveCore<'_>, &mut SolveScratch) -> R) -> R {
        let ops = self.ell_ops();
        let (_, phi_ell, phi_t_ell) = &*ops;
        let jacobi = self.jacobi_cached();
        let core = SolveCore {
            phi: &self.phi,
            phi_t: &self.phi_t,
            phi_ell: phi_ell.as_deref(),
            phi_t_ell: phi_t_ell.as_deref(),
            mask: &self.mask,
            y: &self.y,
            sigma2: self.hypers.sigma_n2(),
            tol: self.solve.tol,
            max_iters: self.solve.max_iters,
            threads: self.solve.effective_threads(),
            jacobi: jacobi.as_deref().map(|v| v.as_slice()),
        };
        let mut scratch = self.scratch.borrow_mut();
        f(&core, &mut scratch)
    }

    /// An owned, immutable snapshot of the inference inputs — see
    /// [`ModelReadView`]. O(overlay rows + n) to build: the compacted
    /// Φ/Φᵀ bases and packed ELL operands are `Arc`-shared, only the
    /// overlay maps, mask/y, and the Jacobi diagonal are copied.
    pub fn read_view(&self) -> ModelReadView {
        let ops = self.ell_ops();
        let (_, phi_ell, phi_t_ell) = &*ops;
        let jacobi = self.jacobi_cached().map(|d| (*d).clone());
        ModelReadView {
            phi: self.phi.clone(),
            phi_t: self.phi_t.clone(),
            phi_ell: phi_ell.clone(),
            phi_t_ell: phi_t_ell.clone(),
            mask: self.mask.clone(),
            y: self.y.clone(),
            sigma2: self.hypers.sigma_n2(),
            tol: self.solve.tol,
            max_iters: self.solve.max_iters,
            threads: self.solve.effective_threads(),
            jacobi,
            mean_cache: Mutex::new(None),
        }
    }

    /// Jacobi preconditioner diagonal of H, `diag(H)_i = m_i ‖φ_i‖² + σ²`
    /// (see [`crate::sparse::ops::jacobi_diag`], the shared definition).
    pub fn jacobi_diag(&self) -> Vec<f64> {
        self.phi
            .jacobi_diag(Some(&self.mask), self.hypers.sigma_n2())
    }

    /// Kernel product y = Φ (Φᵀ x) (no mask/noise).
    pub fn apply_kernel(&self, x: &[f64]) -> Vec<f64> {
        self.with_core(|core, _| core.apply_kernel(x))
    }

    /// Cached C_lᵀ operands for the modulation gradients: rebuilt on
    /// first use after a graph delta invalidated them. Materialises
    /// each component through the feature overlay
    /// ([`CombinedFeatures::component_csr`]) so a training step between
    /// compactions sees the patched rows.
    fn c_t_cached(&self) -> std::cell::Ref<'_, Vec<Csr>> {
        {
            let mut cache = self.c_t.borrow_mut();
            if cache.is_none() {
                let threads = self.solve.effective_threads();
                let n = self.features.n();
                *cache = Some(
                    (0..self.features.components.c.len())
                        .map(|l| {
                            let base = &self.features.components.c[l];
                            if self.features.overlay_rows() == 0
                                && base.n_rows == n
                            {
                                // Compacted: transpose the borrowed
                                // base directly, no materialise clone.
                                base.transpose_par(threads)
                            } else {
                                self.features
                                    .component_csr(l)
                                    .transpose_par(threads)
                            }
                        })
                        .collect(),
                );
            }
        }
        std::cell::Ref::map(self.c_t.borrow(), |c| {
            c.as_ref().expect("filled above")
        })
    }

    /// Cached Jacobi diagonal for the solvers: computed on first use
    /// after Φ/mask/σ² change, then shared by every subsequent solve.
    fn jacobi_cached(&self) -> Option<std::cell::Ref<'_, Vec<f64>>> {
        if !self.solve.precondition {
            return None;
        }
        {
            let mut cache = self.jacobi_cache.borrow_mut();
            if cache.is_none() {
                *cache = Some(self.jacobi_diag());
            }
        }
        Some(std::cell::Ref::map(self.jacobi_cache.borrow(), |c| {
            c.as_ref().expect("filled above")
        }))
    }

    /// Solve (m K m + σ² I) v = b by (optionally Jacobi-preconditioned)
    /// CG.
    pub fn solve_system(&self, b: &[f64]) -> (Vec<f64>, CgStats) {
        self.with_core(|core, scratch| core.solve_system(scratch, b))
    }

    /// Solve (m K m + σ² I) V = B for a row-major `n × ncols` block of
    /// right-hand sides with one block-CG (shared SpMM operator
    /// application, per-column convergence). Column `j` of the result
    /// is bitwise the solve of column `j` through [`GpModel::solve_system`].
    pub fn solve_system_block(&self, b: &[f64], ncols: usize) -> (Vec<f64>, Vec<CgStats>) {
        self.solve_system_block_warm(b, ncols, None)
    }

    /// [`GpModel::solve_system_block`] with an optional warm-start
    /// block `x0` (row-major `n × ncols`, like `b`): the block-CG
    /// starts from `R = B − A·X0` instead of `R = B`. Thompson
    /// re-solves across BO steps change one observation at a time, so
    /// the previous step's solves are excellent starting points — see
    /// the iteration-count test in [`crate::bo`].
    pub fn solve_system_block_warm(
        &self,
        b: &[f64],
        ncols: usize,
        x0: Option<&[f64]>,
    ) -> (Vec<f64>, Vec<CgStats>) {
        self.with_core(|core, scratch| {
            core.solve_system_block_warm(scratch, b, ncols, x0)
        })
    }

    // ------------------------------------------------------------------
    // Hyperparameter learning (paper Eq. 8-11)
    // ------------------------------------------------------------------

    /// Stochastic LML gradient w.r.t. the packed parameter vector
    /// [modulation params..., log σ²].
    ///
    /// ∇L = ½ αᵀ (∂H/∂θ) α − ½ tr(H⁻¹ ∂H/∂θ),   α = H⁻¹ y,
    /// with the trace estimated by `probes` Rademacher probes z_s
    /// (restricted to the training mask) and solves v_s = H⁻¹ z_s.
    ///
    /// ∂H/∂f_l = m (C_l Φᵀ + Φ C_lᵀ) m, so each term reduces to dot
    /// products of Φᵀu and C_lᵀu — no kernel materialisation.
    pub fn lml_grad(&self, rng: &mut Rng) -> (Vec<f64>, TrainStep) {
        let n = self.n();
        let s = self.solve.probes;
        let ncols = s + 1;
        let sigma2 = self.hypers.sigma_n2();
        let n_coeff = self.features.components.n_coeffs();
        let threads = self.solve.effective_threads();
        let par = threads > 1 && n > 4096;

        // --- one blocked solve: [y, z_1..z_S] -----------------------------
        // Column 0 is y; columns 1..=S are Rademacher probes restricted
        // to the training mask (drawn probe-major, matching the historic
        // stream).
        let mut rhs = vec![0.0; n * ncols];
        for i in 0..n {
            rhs[i * ncols] = self.y[i];
        }
        for si in 1..ncols {
            for i in 0..n {
                if self.mask[i] == 1.0 {
                    rhs[i * ncols + si] =
                        if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                }
            }
        }
        let (solves, stats) = self.solve_system_block(&rhs, ncols);
        let total_cg: usize = stats.iter().map(|st| st.iterations).sum();

        // --- blocked projections: Φᵀ and C_lᵀ applied to whole blocks -----
        // Each projection is a single SpMM pass over the matrix instead
        // of S+1 SpMVs. All vectors are mask-supported (CG preserves the
        // support since the rhs are masked).
        let proj = |mat: &Csr, x: &[f64]| -> Vec<f64> {
            if par {
                mat.matmat_par(x, ncols, threads)
            } else {
                mat.matmat(x, ncols)
            }
        };
        // Φᵀ is an overlay operand: its own (overlay-aware) SpMM.
        let proj_t = |x: &[f64]| -> Vec<f64> {
            if par {
                self.phi_t.matmat_par(x, ncols, threads)
            } else {
                self.phi_t.matmat(x, ncols)
            }
        };
        let phi_v = proj_t(&solves); // Φᵀ V
        let phi_z = proj_t(&rhs); // Φᵀ Z

        // --- gradient w.r.t. modulation coefficients ----------------------
        // quad_l  = αᵀ ∂H α     = 2 (C_lᵀα)·(Φᵀα)
        // trace_l ≈ (1/S) Σ_s [ (C_lᵀ v_s)·(Φᵀ z_s) + (Φᵀ v_s)·(C_lᵀ z_s) ]
        // All S+1 dot products of a pair of blocks come out of one
        // streaming column_dots pass.
        // Quad terms only ever read column 0 (the α column), so they
        // use a strided single-column dot instead of a full
        // column_dots pass — 1/ncols of the memory traffic.
        let col0_dot = |a: &[f64], b: &[f64]| -> f64 {
            debug_assert_eq!(a.len(), b.len());
            let mut acc = 0.0;
            let mut i = 0;
            while i < a.len() {
                acc += a[i] * b[i];
                i += ncols;
            }
            acc
        };
        let mut grad_f = vec![0.0; n_coeff];
        let c_t = self.c_t_cached();
        for (l, ct) in c_t.iter().enumerate() {
            let c_v = proj(ct, &solves); // C_lᵀ V
            let c_z = proj(ct, &rhs); // C_lᵀ Z
            let d_cv_pz = column_dots(&c_v, &phi_z, ncols);
            let d_pv_cz = column_dots(&phi_v, &c_z, ncols);
            let quad = 2.0 * col0_dot(&c_v, &phi_v);
            let mut tr = 0.0;
            for si in 1..ncols {
                tr += d_cv_pz[si] + d_pv_cz[si];
            }
            let tr = if s > 0 { tr / s as f64 } else { 0.0 };
            grad_f[l] = 0.5 * quad - 0.5 * tr;
        }

        // --- gradient w.r.t. log σ² ---------------------------------------
        // ∂H/∂logσ² = σ² I (on the train block):
        // quad = σ² αᵀα;  trace ≈ σ²/S Σ v_s·z_s.
        let quad_n = sigma2 * col0_dot(&solves, &solves);
        let d_vz = column_dots(&solves, &rhs, ncols);
        let mut tr_n = 0.0;
        for si in 1..ncols {
            tr_n += d_vz[si];
        }
        let tr_n = if s > 0 { sigma2 * tr_n / s as f64 } else { 0.0 };
        let grad_log_noise = 0.5 * quad_n - 0.5 * tr_n;

        // --- chain rule to packed params ----------------------------------
        let jac = self.hypers.modulation.jacobian();
        let mut grad = Vec::with_capacity(self.hypers.n_params());
        for row in &jac {
            grad.push(dot(row, &grad_f));
        }
        grad.push(grad_log_noise);

        let gnorm = grad.iter().map(|g| g * g).sum::<f64>().sqrt();
        let _ = n;
        (
            grad,
            TrainStep {
                step: 0,
                grad_norm: gnorm,
                cg_iters: total_cg,
                sigma_n2: sigma2,
            },
        )
    }

    /// Maximise the LML with Adam for `steps` iterations.
    pub fn fit(&mut self, steps: usize, lr: f64, rng: &mut Rng) -> Vec<TrainStep> {
        let mut opt = Adam::new(self.hypers.n_params(), lr);
        let mut log = Vec::with_capacity(steps);
        for step in 0..steps {
            let (grad, mut info) = self.lml_grad(rng);
            let mut p = self.hypers.params();
            opt.step_ascent(&mut p, &grad);
            self.hypers.set_params(&p);
            self.refresh_features();
            info.step = step;
            log.push(info);
        }
        log
    }

    // ------------------------------------------------------------------
    // Posterior inference (paper Eq. 12, pathwise conditioning)
    // ------------------------------------------------------------------

    /// Posterior mean at every node: K (m α) with α = H⁻¹ (m y).
    pub fn posterior_mean(&self) -> (Vec<f64>, CgStats) {
        self.with_core(|core, scratch| core.posterior_mean(scratch))
    }

    /// One pathwise-conditioning sample from the posterior over all
    /// nodes: g + K m H⁻¹ m (y − g(x) − ε),  g = Φ w.
    pub fn posterior_sample(&self, rng: &mut Rng) -> Vec<f64> {
        self.posterior_samples(1, rng)
            .pop()
            .expect("posterior_samples(1) returns one sample")
    }

    /// `n_samples` pathwise-conditioning draws through **one** blocked
    /// solve: all prior functions `g_j = Φ w_j`, the conditioning
    /// solves, and the kernel corrections run as `n × n_samples` SpMM
    /// blocks, so the feature matrix is streamed once per block-CG
    /// iteration instead of once per sample per iteration.
    ///
    /// Randomness is drawn per sample in the same order as the historic
    /// serial loop (`w_j`, then the per-node noise of sample `j`), so a
    /// given `Rng` produces the same draws either way.
    pub fn posterior_samples(&self, n_samples: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
        self.with_core(|core, scratch| {
            core.posterior_samples(scratch, n_samples, rng)
        })
    }

    /// One pathwise Thompson draw with a warm-startable conditioning
    /// solve. Consumes the **same rng stream** as
    /// [`GpModel::posterior_sample`] (w, then per-node noise), but
    /// splits the conditioning solve `H α = m (y − g − σ ε)` by
    /// linearity into a 2-column block `[m y, m (g + σ ε)]` with
    /// `α = α_y − α_f`: the `α_y` (data) column changes slowly across
    /// BO steps, so the *previous* step's `α_y` is an excellent warm
    /// start, while the fluctuation column is freshly random and
    /// starts cold. Both columns share the operator SpMMs, so the
    /// split costs no extra matrix traffic.
    ///
    /// Returns `(sample, α_y, per-column CG stats)`; feed `α_y` back as
    /// `warm` on the next draw ([`crate::bo::ThompsonPolicy`] does).
    pub fn thompson_sample_warm(
        &self,
        rng: &mut Rng,
        warm: Option<&[f64]>,
    ) -> (Vec<f64>, Vec<f64>, Vec<CgStats>) {
        self.with_core(|core, scratch| {
            let n = core.mask.len();
            let k = core.phi.n_cols();
            let par = core.threads > 1 && n > 4096;
            let sigma = core.sigma2.sqrt();
            let w = rng.normal_vec(k);
            let eps = rng.normal_vec(n);
            let g = if par {
                core.phi.matvec_par(&w, core.threads)
            } else {
                core.phi.matvec(&w)
            };
            let mut rhs = vec![0.0; n * 2];
            for i in 0..n {
                let m = core.mask[i];
                rhs[i * 2] = m * core.y[i];
                rhs[i * 2 + 1] = m * (g[i] + sigma * eps[i]);
            }
            let x0: Option<Vec<f64>> = warm.filter(|wv| wv.len() == n).map(|wv| {
                let mut v = vec![0.0; n * 2];
                for i in 0..n {
                    v[i * 2] = wv[i];
                }
                v
            });
            let (sol, stats) =
                core.solve_system_block_warm(scratch, &rhs, 2, x0.as_deref());
            let mut alpha_y = vec![0.0; n];
            let mut malpha = vec![0.0; n];
            for i in 0..n {
                alpha_y[i] = sol[i * 2];
                malpha[i] = core.mask[i] * (sol[i * 2] - sol[i * 2 + 1]);
            }
            let corr = core.apply_kernel(&malpha);
            let sample: Vec<f64> = (0..n).map(|i| g[i] + corr[i]).collect();
            (sample, alpha_y, stats)
        })
    }

    /// Predictive mean + variance at every node, variance estimated
    /// from `n_samples` pathwise draws (includes observation noise).
    /// The draws come from one blocked solve ([`GpModel::posterior_samples`]).
    pub fn predict(&self, n_samples: usize, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
        self.with_core(|core, scratch| {
            let (mean, _) = core.posterior_mean(scratch);
            core.predict_with_mean(scratch, &mean, n_samples, rng)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::modulation::Modulation;
    use crate::graph::generators;
    use crate::linalg::chol::Cholesky;
    use crate::linalg::Mat;
    use crate::walks::{WalkConfig, WalkSampler};

    /// Exact train-block LML (paper Eq. 8) via dense algebra — oracle.
    fn dense_lml_of(m: &GpModel) -> f64 {
        let n = m.n();
        let phi = Mat::from_rows(&m.phi.to_dense());
        let k = phi.matmul(&phi.transpose());
        let train: Vec<usize> = (0..n).filter(|&i| m.mask[i] == 1.0).collect();
        let t = train.len();
        let mut h = Mat::zeros(t, t);
        for (a, &i) in train.iter().enumerate() {
            for (b, &j) in train.iter().enumerate() {
                h[(a, b)] = k[(i, j)];
            }
            h[(a, a)] += m.hypers.sigma_n2();
        }
        let yv: Vec<f64> = train.iter().map(|&i| m.y[i]).collect();
        let ch = Cholesky::new(&h).unwrap();
        let alpha = ch.solve(&yv);
        -0.5 * dot(&yv, &alpha) - 0.5 * ch.logdet()
            - 0.5 * t as f64 * (2.0 * std::f64::consts::PI).ln()
    }

    fn small_model(seed: u64) -> (GpModel, Mat) {
        let g = generators::grid2d(5, 5);
        let cfg = WalkConfig { n_walks: 300, max_len: 4, threads: 1, ..Default::default() };
        let comps = WalkSampler::new(&g, &cfg, seed).components();
        let mut rng = Rng::new(seed);
        let train: Vec<usize> = rng.sample_without_replacement(25, 12);
        let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.3).sin()).collect();
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let model = GpModel::new(comps, hypers, &train, &y);
        // Dense K̂ for oracles.
        let phi = Mat::from_rows(&model.phi.to_dense());
        let k = phi.matmul(&phi.transpose());
        (model, k)
    }

    #[test]
    fn posterior_mean_matches_dense_solve() {
        let (model, k) = small_model(7);
        let n = model.n();
        // Dense oracle: mu = K m (m K m + s I)^{-1} m y.
        let sigma2 = model.hypers.sigma_n2();
        let mut h = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                h[(i, j)] = model.mask[i] * k[(i, j)] * model.mask[j];
            }
            h[(i, i)] += sigma2;
        }
        let rhs: Vec<f64> =
            (0..n).map(|i| model.mask[i] * model.y[i]).collect();
        let alpha = Cholesky::new(&h).unwrap().solve(&rhs);
        let malpha: Vec<f64> =
            (0..n).map(|i| model.mask[i] * alpha[i]).collect();
        let expect = k.matvec(&malpha);
        let (mean, st) = model.posterior_mean();
        assert!(st.converged, "{st:?}");
        for i in 0..n {
            assert!(
                (mean[i] - expect[i]).abs() < 1e-4,
                "node {i}: {} vs {}",
                mean[i],
                expect[i]
            );
        }
    }

    #[test]
    fn read_view_predictions_bitwise_match_live_model() {
        let (model, _) = small_model(7);
        let view = model.read_view();
        assert_eq!(view.n(), model.n());
        // Same rng stream into both entry points — bitwise equality is
        // the contract that lets the server predict off published
        // snapshots without re-deriving anything from the live model.
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let (m1, v1) = model.predict(4, &mut r1);
        let (m2, v2) = view.predict(4, &mut r2);
        assert_eq!(m1, m2, "means diverge");
        assert_eq!(v1, v2, "variances diverge");
        // The cached mean is reused — a second predict off the view
        // still matches a fresh model predict on the same stream.
        let (m3, v3) = model.predict(4, &mut r1);
        let (m4, v4) = view.predict(4, &mut r2);
        assert_eq!(m3, m4);
        assert_eq!(v3, v4);
        // Raw pathwise samples agree too.
        let s1 = model.posterior_samples(3, &mut r1);
        let s2 = view.posterior_samples(3, &mut r2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn lml_grad_matches_finite_difference() {
        // Exact-LML finite difference via dense Cholesky vs our
        // stochastic gradient with MANY probes.
        let (mut model, _) = small_model(3);
        model.solve.probes = 400;
        model.solve.tol = 1e-10;
        model.solve.max_iters = 2000;
        let mut rng = Rng::new(11);
        let (grad, _) = model.lml_grad(&mut rng);
        let dense_lml = dense_lml_of;
        let p0 = model.hypers.params();
        let eps = 1e-5;
        for pi in 0..p0.len() {
            let mut mp = model.hypers.clone();
            let mut pv = p0.clone();
            pv[pi] += eps;
            mp.set_params(&pv);
            let mut m_plus = GpModel::new(
                model.features.components.clone(),
                mp,
                &(0..model.n()).filter(|&i| model.mask[i] == 1.0).collect::<Vec<_>>(),
                &(0..model.n())
                    .filter(|&i| model.mask[i] == 1.0)
                    .map(|i| model.y[i])
                    .collect::<Vec<_>>(),
            );
            m_plus.refresh_features();
            let f_plus = dense_lml(&m_plus);
            let f_zero = dense_lml(&model);
            let fd = (f_plus - f_zero) / eps;
            assert!(
                (grad[pi] - fd).abs() < 0.05 * (1.0 + fd.abs()),
                "param {pi}: stochastic {} vs fd {fd}",
                grad[pi]
            );
        }
    }

    #[test]
    fn fit_increases_exact_lml() {
        let (mut model, _) = small_model(5);
        let mut rng = Rng::new(0);
        let before = dense_lml_of(&model);
        model.fit(60, 0.05, &mut rng);
        let after = dense_lml_of(&model);
        assert!(
            after > before,
            "Adam on the stochastic LML gradient should increase the \
             exact LML: {before} -> {after}"
        );
    }

    #[test]
    fn solve_system_block_matches_serial_solves() {
        // Each column of the blocked solve must reproduce the
        // stand-alone single-RHS solve (same preconditioner, lockstep
        // per-column recurrences), on both solver configurations.
        let (mut model, _) = small_model(21);
        let n = model.n();
        let mut rng = Rng::new(2);
        for &precondition in &[true, false] {
            model.solve.precondition = precondition;
            let ncols = 4;
            let mut block = vec![0.0; n * ncols];
            let mut cols: Vec<Vec<f64>> = Vec::new();
            for j in 0..ncols {
                let c: Vec<f64> = (0..n).map(|i| model.mask[i] * rng.normal()).collect();
                for i in 0..n {
                    block[i * ncols + j] = c[i];
                }
                cols.push(c);
            }
            let (xb, stats) = model.solve_system_block(&block, ncols);
            for (j, c) in cols.iter().enumerate() {
                let (xs, st) = model.solve_system(c);
                assert_eq!(
                    stats[j].iterations, st.iterations,
                    "precond={precondition} col {j} iteration count"
                );
                for i in 0..n {
                    assert!(
                        (xb[i * ncols + j] - xs[i]).abs()
                            < 1e-12 * (1.0 + xs[i].abs()),
                        "precond={precondition} col {j} row {i}: {} vs {}",
                        xb[i * ncols + j],
                        xs[i]
                    );
                }
            }
        }
    }

    #[test]
    fn layout_selection_keeps_solves_bitwise_in_f64() {
        // Flipping the operand layout between CSR, forced ELL, and Auto
        // must not change a single bit of the solve (f64 ELL replays the
        // CSR accumulation order), and the lazy re-selection must pick
        // up direct `solve.layout` assignments.
        let (mut model, _) = small_model(17);
        let n = model.n();
        let mut rng = Rng::new(6);
        let rhs: Vec<f64> =
            (0..n).map(|i| model.mask[i] * rng.normal()).collect();
        let block: Vec<f64> = (0..n * 3).map(|_| rng.normal()).collect();
        model.solve.layout = FeatureLayout::Csr;
        let (x_csr, st_csr) = model.solve_system(&rhs);
        let (xb_csr, _) = model.solve_system_block(&block, 3);
        for layout in [FeatureLayout::Ell, FeatureLayout::Auto] {
            model.solve.layout = layout;
            let (x, st) = model.solve_system(&rhs);
            assert_eq!(st.iterations, st_csr.iterations, "{layout:?}");
            assert!(x == x_csr, "{layout:?} solve differs from CSR");
            let (xb, _) = model.solve_system_block(&block, 3);
            assert!(xb == xb_csr, "{layout:?} block solve differs from CSR");
        }
    }

    #[test]
    fn ell_f32_layout_posterior_close_to_f64() {
        // The f32-valued operator only perturbs Φ at the f32 rounding
        // level (~6e-8 relative, against ~1e-2 MC estimation error), so
        // the posterior mean must track the f64 path tightly.
        let (mut model, _) = small_model(7);
        let (mean64, st64) = model.posterior_mean();
        model.solve.layout = FeatureLayout::EllF32;
        let (mean32, st32) = model.posterior_mean();
        assert!(st64.converged && st32.converged);
        let scale = mean64.iter().fold(0.0f64, |a, v| a.max(v.abs()));
        for i in 0..model.n() {
            assert!(
                (mean32[i] - mean64[i]).abs() <= 1e-3 * (scale + 1.0),
                "node {i}: {} vs {}",
                mean32[i],
                mean64[i]
            );
        }
    }

    #[test]
    fn warm_started_block_solve_matches_and_saves_iterations() {
        // Re-solving the same system warm-started at (a perturbation
        // of) the previous solution must converge to the same block in
        // fewer total iterations than a cold start.
        let (model, _) = small_model(21);
        let n = model.n();
        let ncols = 3;
        let mut rng = Rng::new(13);
        let mut block = vec![0.0; n * ncols];
        for i in 0..n {
            for j in 0..ncols {
                block[i * ncols + j] = model.mask[i] * rng.normal();
            }
        }
        let (x_cold, st_cold) = model.solve_system_block(&block, ncols);
        let x0: Vec<f64> = x_cold
            .iter()
            .map(|v| v * (1.0 + 1e-4) + 1e-6)
            .collect();
        let (x_warm, st_warm) =
            model.solve_system_block_warm(&block, ncols, Some(&x0));
        let cold: usize = st_cold.iter().map(|s| s.iterations).sum();
        let warm: usize = st_warm.iter().map(|s| s.iterations).sum();
        assert!(warm < cold, "warm {warm} !< cold {cold}");
        for j in 0..ncols {
            assert!(st_warm[j].converged, "col {j}: {:?}", st_warm[j]);
        }
        for i in 0..n * ncols {
            assert!(
                (x_warm[i] - x_cold[i]).abs() < 1e-3 * (1.0 + x_cold[i].abs()),
                "entry {i}: warm {} vs cold {}",
                x_warm[i],
                x_cold[i]
            );
        }
    }

    #[test]
    fn posterior_samples_match_serial_formula() {
        // The blocked sampler must reproduce the serial pathwise
        // formula draw-for-draw: same rng stream, same solves.
        let (model, _) = small_model(31);
        let n = model.n();
        let n_samples = 3;
        let mut rng_block = Rng::new(99);
        let mut rng_serial = rng_block.clone();
        let samples = model.posterior_samples(n_samples, &mut rng_block);
        assert_eq!(samples.len(), n_samples);
        let sigma = model.hypers.sigma_n2().sqrt();
        for (j, sample) in samples.iter().enumerate() {
            let w = rng_serial.normal_vec(model.phi.n_cols());
            let g = model.phi.matvec(&w);
            let rhs: Vec<f64> = (0..n)
                .map(|i| {
                    model.mask[i] * (model.y[i] - g[i] - sigma * rng_serial.normal())
                })
                .collect();
            let (alpha, _) = model.solve_system(&rhs);
            let malpha: Vec<f64> =
                (0..n).map(|i| model.mask[i] * alpha[i]).collect();
            let corr = model.apply_kernel(&malpha);
            for i in 0..n {
                let expect = g[i] + corr[i];
                assert!(
                    (sample[i] - expect).abs() < 1e-10 * (1.0 + expect.abs()),
                    "sample {j} node {i}: {} vs {expect}",
                    sample[i]
                );
            }
        }
        // The blocked path consumed exactly the serial stream.
        assert_eq!(rng_block.next_u64(), rng_serial.next_u64());
    }

    #[test]
    fn apply_graph_delta_matches_rebuilt_model_bitwise() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::grid2d(5, 5);
        let cfg = WalkConfig { n_walks: 40, max_len: 4, threads: 1, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let mut stream = StreamingFeatures::new(
            g.clone(),
            cfg.clone(),
            hypers.modulation.coeffs(),
            9,
        );
        let train: Vec<usize> = (0..25).step_by(3).collect();
        let y: Vec<f64> =
            train.iter().map(|&i| (i as f64 * 0.3).sin()).collect();
        let mut model = GpModel::new(stream.components(), hypers.clone(), &train, &y);
        let rhs0: Vec<f64> =
            model.mask.iter().zip(&model.y).map(|(m, y)| m * y).collect();
        let (alpha0, _) = model.solve_system(&rhs0);
        let delta = GraphDelta::AddEdge { u: 0, v: 12, w: 0.8 };
        let out = model
            .apply_graph_delta(&mut stream, &delta, Some(&alpha0))
            .unwrap();
        assert!(out.solve_stats.converged, "{:?}", out.solve_stats);
        assert!(out.resampled_walks > 0 && out.patched_rows > 0);
        // Only part of the graph may be touched: the incremental
        // update must not have resampled every walk.
        assert!(
            out.resampled_walks < 25 * cfg.n_walks,
            "delta resampled all walks"
        );
        // Reference: a model built from scratch on the mutated graph
        // under the same per-walk seeds.
        let full = StreamingFeatures::new(
            stream.graph().clone(),
            cfg.clone(),
            hypers.modulation.coeffs(),
            9,
        );
        let model2 = GpModel::new(full.components(), hypers.clone(), &train, &y);
        let (m1, s1) = model.posterior_mean();
        let (m2, s2) = model2.posterior_mean();
        assert_eq!(s1.iterations, s2.iterations);
        assert!(m1 == m2, "patched model must match rebuilt model bitwise");
        // Node insertion grows the embedding and keeps the model usable.
        let out2 = model
            .apply_graph_delta(&mut stream, &GraphDelta::AddNode, Some(&out.alpha))
            .unwrap();
        assert_eq!(out2.added_node, Some(25));
        assert_eq!(model.n(), 26);
        let (mean, st) = model.posterior_mean();
        assert!(st.converged);
        assert_eq!(mean.len(), 26);
        // Mismatched stream/model is rejected, state intact.
        let mut other = StreamingFeatures::new(
            generators::ring(10),
            cfg.clone(),
            hypers.modulation.coeffs(),
            1,
        );
        assert!(model
            .apply_graph_delta(&mut other, &GraphDelta::AddNode, None)
            .is_err());
        assert_eq!(model.n(), 26);
    }

    #[test]
    fn apply_graph_delta_patches_phi_t_without_transpose() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::grid2d(5, 5);
        let cfg = WalkConfig { n_walks: 30, max_len: 4, threads: 1, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let mut stream = StreamingFeatures::new(
            g,
            cfg.clone(),
            hypers.modulation.coeffs(),
            5,
        );
        let train: Vec<usize> = (0..25).step_by(4).collect();
        let y: Vec<f64> =
            train.iter().map(|&i| (i as f64 * 0.2).cos()).collect();
        let mut model =
            GpModel::new(stream.components(), hypers, &train, &y);
        let transposes_before = model.phi_transposes();
        for delta in [
            GraphDelta::AddEdge { u: 1, v: 14, w: 0.6 },
            GraphDelta::AddNode,
            GraphDelta::AddEdge { u: 25, v: 2, w: 0.3 },
            GraphDelta::RemoveEdge { u: 1, v: 14 },
            GraphDelta::AddEdge { u: 7, v: 7, w: 0.8 }, // self-loop
        ] {
            let out = model
                .apply_graph_delta(&mut stream, &delta, None)
                .unwrap();
            assert!(out.solve_stats.converged, "{delta:?}: {:?}", out.solve_stats);
            // The incrementally patched Φᵀ must be bitwise the full
            // transpose of the patched Φ...
            assert!(
                model.phi_t == model.phi.transpose(),
                "{delta:?}: patched Φᵀ != transpose(Φ)"
            );
        }
        // ...without ever running a full transpose on the delta path.
        assert_eq!(
            model.phi_transposes(),
            transposes_before,
            "delta path ran transpose_par"
        );
    }

    #[test]
    fn delta_after_unrefreshed_hypers_change_falls_back_to_full_refresh() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::grid2d(4, 4);
        let cfg = WalkConfig { n_walks: 20, max_len: 4, threads: 1, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let mut stream = StreamingFeatures::new(
            g,
            cfg,
            hypers.modulation.coeffs(),
            3,
        );
        let train = vec![0usize, 5, 10];
        let y = vec![0.3, -0.2, 0.8];
        let mut model = GpModel::new(stream.components(), hypers, &train, &y);
        // Mutate the public hypers WITHOUT refresh_features: the delta
        // path must detect the stale combination and do a full refresh
        // (one transpose) instead of mixing two modulations.
        let mut p = model.hypers.params();
        p[0] += 0.25;
        model.hypers.set_params(&p);
        let before = model.phi_transposes();
        model
            .apply_graph_delta(
                &mut stream,
                &GraphDelta::AddEdge { u: 1, v: 10, w: 0.5 },
                None,
            )
            .unwrap();
        assert_eq!(model.phi_transposes(), before + 1, "fallback must refresh");
        // Φ/Φᵀ are coherent under the NEW modulation.
        let expect = model
            .features
            .combine_into(&model.hypers.modulation.coeffs())
            .clone();
        assert!(model.phi == expect, "Φ must be the new-modulation combination");
        assert!(model.phi_t == model.phi.transpose());
        // Subsequent deltas take the incremental path again.
        let before = model.phi_transposes();
        model
            .apply_graph_delta(
                &mut stream,
                &GraphDelta::AddEdge { u: 2, v: 9, w: 0.4 },
                None,
            )
            .unwrap();
        assert_eq!(model.phi_transposes(), before, "incremental path restored");
        assert!(model.phi_t == model.phi.transpose());
    }

    #[test]
    fn apply_graph_delta_batch_matches_rebuilt_model_bitwise() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::grid2d(5, 5);
        let cfg = WalkConfig { n_walks: 40, max_len: 4, threads: 2, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let mut stream = StreamingFeatures::new(
            g,
            cfg.clone(),
            hypers.modulation.coeffs(),
            9,
        );
        let train: Vec<usize> = (0..25).step_by(3).collect();
        let y: Vec<f64> =
            train.iter().map(|&i| (i as f64 * 0.3).sin()).collect();
        let mut model =
            GpModel::new(stream.components(), hypers.clone(), &train, &y);
        let rhs0: Vec<f64> =
            model.mask.iter().zip(&model.y).map(|(m, y)| m * y).collect();
        let (alpha0, _) = model.solve_system(&rhs0);
        let deltas = vec![
            GraphDelta::AddEdge { u: 0, v: 12, w: 0.8 },
            GraphDelta::AddEdge { u: 3, v: 19, w: 0.5 },
            GraphDelta::AddNode,
            GraphDelta::AddEdge { u: 25, v: 6, w: 0.4 },
            GraphDelta::RemoveEdge { u: 0, v: 12 },
            GraphDelta::AddEdge { u: 11, v: 11, w: 0.7 }, // self-loop
        ];
        let out = model
            .apply_graph_delta_batch(&mut stream, &deltas, Some(&alpha0))
            .unwrap();
        assert!(out.solve_stats.converged, "{:?}", out.solve_stats);
        assert_eq!(out.deltas.len(), deltas.len(), "one ack per delta");
        assert_eq!(out.deltas[2].added_node, Some(25));
        assert!(out.patched_rows > 0);
        assert_eq!(model.n(), 26);
        // Reference: a model built from scratch on the mutated graph
        // under the same per-walk seeds — posterior bitwise equal.
        let full = StreamingFeatures::new(
            stream.graph().clone(),
            cfg,
            hypers.modulation.coeffs(),
            9,
        );
        let model2 = GpModel::new(full.components(), hypers, &train, &y);
        let (m1, s1) = model.posterior_mean();
        let (m2, s2) = model2.posterior_mean();
        assert_eq!(s1.iterations, s2.iterations);
        assert!(m1 == m2, "batched model must match rebuilt model bitwise");
        assert!(model.phi_t == model.phi.transpose());
        // A failing batch (validation) leaves model and stream intact.
        let n_before = model.n();
        assert!(model
            .apply_graph_delta_batch(
                &mut stream,
                &[
                    GraphDelta::AddEdge { u: 0, v: 1, w: 0.5 },
                    GraphDelta::AddEdge { u: 0, v: 9999, w: 0.5 },
                ],
                None,
            )
            .is_err());
        assert_eq!(model.n(), n_before);
        let (m3, _) = model.posterior_mean();
        assert!(m3 == m1, "failed batch must not move the model");
    }

    /// Acceptance guard of the sub-linear delta path: a run of delta
    /// batches must not clone Φ, splice Φᵀ, transpose, or rebuild the
    /// scatter maps — every counter stays put while the overlays grow —
    /// and the overlay-backed model stays bitwise a rebuilt one.
    #[test]
    fn delta_batches_stay_on_overlays_without_memcpy() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::grid2d(6, 6);
        let cfg = WalkConfig { n_walks: 30, max_len: 4, threads: 2, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let mut stream = StreamingFeatures::new(
            g,
            cfg.clone(),
            hypers.modulation.coeffs(),
            21,
        );
        // Keep the stream (and therefore the model) from compacting so
        // the steady overlay state is what gets asserted.
        stream.set_compact_threshold(usize::MAX);
        let train: Vec<usize> = (0..36).step_by(4).collect();
        let y: Vec<f64> =
            train.iter().map(|&i| (i as f64 * 0.2).cos()).collect();
        let mut model =
            GpModel::new(stream.components(), hypers.clone(), &train, &y);
        let transposes0 = model.phi_transposes();
        assert_eq!(model.features.full_map_builds(), 1);
        let batches: Vec<Vec<GraphDelta>> = vec![
            vec![
                GraphDelta::AddEdge { u: 0, v: 20, w: 0.7 },
                GraphDelta::AddEdge { u: 3, v: 33, w: 0.4 },
            ],
            vec![GraphDelta::AddNode, GraphDelta::AddEdge { u: 36, v: 5, w: 0.5 }],
            vec![
                GraphDelta::RemoveEdge { u: 0, v: 20 },
                GraphDelta::AddEdge { u: 7, v: 7, w: 0.9 },
            ],
        ];
        for batch in &batches {
            let out = model
                .apply_graph_delta_batch(&mut stream, batch, None)
                .unwrap();
            assert!(out.patched_rows > 0);
        }
        // Counters: no transpose, no full map rebuild, no compaction —
        // and the overlays actually hold the patched rows.
        assert_eq!(model.phi_transposes(), transposes0, "delta path transposed");
        assert_eq!(
            model.features.full_map_builds(),
            1,
            "delta path rebuilt all scatter maps"
        );
        let (phi_rows, phi_t_rows, phi_comp, phi_t_comp) =
            model.phi_overlay_stats();
        assert!(phi_rows > 0 && phi_t_rows > 0, "overlays unused");
        assert_eq!((phi_comp, phi_t_comp), (0, 0), "delta path compacted");
        // Overlay-backed operands are bitwise the rebuilt model's.
        let full = StreamingFeatures::new(
            stream.graph().clone(),
            cfg,
            hypers.modulation.coeffs(),
            21,
        );
        let model2 = GpModel::new(full.components(), hypers, &train, &y);
        let (m1, s1) = model.posterior_mean();
        let (m2, s2) = model2.posterior_mean();
        assert_eq!(s1.iterations, s2.iterations);
        assert!(m1 == m2, "overlay model != rebuilt model");
        assert!(model.phi_t == model.phi.transpose());
        // Training still works off the overlays (C_lᵀ rebuilt through
        // the overlay-aware materialisation).
        let mut rng = Rng::new(2);
        let (grad, step) = model.lml_grad(&mut rng);
        let mut rng = Rng::new(2);
        let (grad2, step2) = model2.lml_grad(&mut rng);
        assert_eq!(step.cg_iters, step2.cg_iters);
        assert!(grad == grad2, "overlay lml_grad != rebuilt lml_grad");
        // Explicit fold: bitwise no-op on the operands.
        model.compact_model_overlays();
        let (r0, r1, c0, c1) = model.phi_overlay_stats();
        assert_eq!((r0, r1), (0, 0));
        assert!(c0 >= 1 && c1 >= 1);
        let (m3, _) = model.posterior_mean();
        assert!(m3 == m1, "compaction moved the posterior");
    }

    /// Shared compaction cadence: when the stream folds its overlay
    /// mid-batch, the model folds Φ/Φᵀ/features too — and nothing
    /// observable moves.
    #[test]
    fn model_overlays_compact_on_stream_cadence() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::grid2d(5, 5);
        let cfg = WalkConfig { n_walks: 25, max_len: 4, threads: 1, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let mut stream = StreamingFeatures::new(
            g,
            cfg.clone(),
            hypers.modulation.coeffs(),
            4,
        );
        stream.set_compact_threshold(1); // every batch compacts
        let train: Vec<usize> = (0..25).step_by(5).collect();
        let y: Vec<f64> = train.iter().map(|&i| (i as f64).sin()).collect();
        let mut model =
            GpModel::new(stream.components(), hypers.clone(), &train, &y);
        let out = model
            .apply_graph_delta_batch(
                &mut stream,
                &[GraphDelta::AddEdge { u: 1, v: 13, w: 0.6 }],
                None,
            )
            .unwrap();
        assert!(out.compacted, "threshold 1 must compact");
        let (phi_rows, phi_t_rows, phi_comp, phi_t_comp) =
            model.phi_overlay_stats();
        assert_eq!((phi_rows, phi_t_rows), (0, 0), "overlays must be folded");
        assert!(phi_comp >= 1 && phi_t_comp >= 1, "compaction counters");
        let full = StreamingFeatures::new(
            stream.graph().clone(),
            cfg,
            hypers.modulation.coeffs(),
            4,
        );
        let model2 = GpModel::new(full.components(), hypers, &train, &y);
        let (m1, _) = model.posterior_mean();
        let (m2, _) = model2.posterior_mean();
        assert!(m1 == m2, "compacted model != rebuilt model");
        assert!(model.phi_t == model.phi.transpose());
    }

    /// Regression (add_node growth path): a batch that appends a node
    /// and immediately wires it up must scatter the fresh column into a
    /// correctly grown Φᵀ — bitwise the full transpose — rather than a
    /// stale-width one, including when the very next batch touches the
    /// new node again.
    #[test]
    fn add_node_then_delta_scatters_into_grown_phi_t() {
        use crate::stream::{GraphDelta, StreamingFeatures};
        let g = generators::ring(18);
        let cfg = WalkConfig { n_walks: 24, max_len: 3, threads: 1, ..Default::default() };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
        let mut stream = StreamingFeatures::new(
            g,
            cfg.clone(),
            hypers.modulation.coeffs(),
            6,
        );
        stream.set_compact_threshold(usize::MAX);
        let train: Vec<usize> = (0..18).step_by(3).collect();
        let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.4).sin()).collect();
        let mut model =
            GpModel::new(stream.components(), hypers.clone(), &train, &y);
        let transposes0 = model.phi_transposes();
        // Batch 1: append the node (pre-compaction: its rows live only
        // in the overlays).
        let out = model
            .apply_graph_delta_batch(&mut stream, &[GraphDelta::AddNode], None)
            .unwrap();
        assert_eq!(out.deltas[0].added_node, Some(18));
        assert!(model.phi_t == model.phi.transpose(), "after AddNode");
        // Batch 2: a delta touching the freshly added node — its Φ row
        // gains off-diagonal entries that must land in Φᵀ rows/columns
        // that only exist in the grown shape.
        model
            .apply_graph_delta_batch(
                &mut stream,
                &[
                    GraphDelta::AddEdge { u: 18, v: 2, w: 0.8 },
                    GraphDelta::AddEdge { u: 18, v: 11, w: 0.3 },
                ],
                None,
            )
            .unwrap();
        assert_eq!(model.phi_transposes(), transposes0, "no transpose allowed");
        assert!(
            model.phi_t == model.phi.transpose(),
            "fresh column scattered into a stale-width Φᵀ"
        );
        // And the whole model matches a rebuild.
        let full = StreamingFeatures::new(
            stream.graph().clone(),
            cfg,
            hypers.modulation.coeffs(),
            6,
        );
        let model2 = GpModel::new(full.components(), hypers, &train, &y);
        let (m1, _) = model.posterior_mean();
        let (m2, _) = model2.posterior_mean();
        assert!(m1 == m2, "post-growth model != rebuilt model");
    }

    #[test]
    fn thompson_sample_warm_matches_posterior_sample() {
        // Same rng stream, same draw up to CG tolerance; the returned
        // α_y warm-starts the next draw into strictly fewer iterations.
        let (model, _) = small_model(19);
        let n = model.n();
        let mut rng_a = Rng::new(77);
        let mut rng_b = rng_a.clone();
        let (sample, alpha_y, stats) = model.thompson_sample_warm(&mut rng_a, None);
        let reference = model.posterior_sample(&mut rng_b);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "rng streams diverged");
        let scale = reference
            .iter()
            .fold(0.0f64, |a, v| a.max(v.abs()))
            .max(1.0);
        for i in 0..n {
            assert!(
                (sample[i] - reference[i]).abs() < 1e-4 * scale,
                "node {i}: split draw {} vs serial {}",
                sample[i],
                reference[i]
            );
        }
        assert!(stats.iter().all(|s| s.converged));
        // Re-draw warm-started at α_y: the data column must converge in
        // strictly fewer iterations than its cold counterpart.
        let mut rng_c = Rng::new(78);
        let mut rng_d = rng_c.clone();
        let (_, _, st_cold) = model.thompson_sample_warm(&mut rng_c, None);
        let (_, _, st_warm) =
            model.thompson_sample_warm(&mut rng_d, Some(&alpha_y));
        assert!(
            st_warm[0].iterations < st_cold[0].iterations,
            "warm α_y column: {} !< {}",
            st_warm[0].iterations,
            st_cold[0].iterations
        );
    }

    #[test]
    fn posterior_sample_mean_converges_to_posterior_mean() {
        let (model, _) = small_model(9);
        let mut rng = Rng::new(4);
        let n = model.n();
        let (mean, _) = model.posterior_mean();
        let reps = 250;
        let mut acc = vec![0.0; n];
        for _ in 0..reps {
            let s = model.posterior_sample(&mut rng);
            for i in 0..n {
                acc[i] += s[i];
            }
        }
        let mut max_err: f64 = 0.0;
        for i in 0..n {
            max_err = max_err.max((acc[i] / reps as f64 - mean[i]).abs());
        }
        assert!(max_err < 0.3, "max_err={max_err}");
    }

    #[test]
    fn predict_variance_shrinks_at_train_nodes() {
        let (model, _) = small_model(13);
        let mut rng = Rng::new(8);
        let (_, var) = model.predict(64, &mut rng);
        let train_var: f64 = (0..model.n())
            .filter(|&i| model.mask[i] == 1.0)
            .map(|i| var[i])
            .sum::<f64>()
            / model.n_train() as f64;
        let test_var: f64 = (0..model.n())
            .filter(|&i| model.mask[i] == 0.0)
            .map(|i| var[i])
            .sum::<f64>()
            / (model.n() - model.n_train()) as f64;
        assert!(
            train_var < test_var,
            "variance should shrink at observed nodes: {train_var} vs {test_var}"
        );
    }
}
