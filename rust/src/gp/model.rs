//! The sparse GRF-GP model: the paper's three-stage workflow
//! (*kernel initialisation → hyperparameter learning → posterior
//! inference*, §3.2) over the component-matrix representation.
//!
//! Everything runs through the masked gram operator
//! `A(v) = m Φ Φᵀ m v + σ² v` and CG (Lemma 1: `O(N^{3/2})`).

use crate::gp::adam::Adam;
use crate::gp::modulation::Hypers;
use crate::linalg::cg::{cg_solve, CgStats};
use crate::linalg::dot;
use crate::sparse::Csr;
use crate::util::parallel::num_threads;
use crate::util::rng::Rng;
use crate::walks::{CombinedFeatures, WalkComponents};

/// Solver settings shared by training and inference.
#[derive(Clone, Debug)]
pub struct SolveConfig {
    pub tol: f64,
    pub max_iters: usize,
    /// Hutchinson probes per gradient step (paper Eq. 10's S).
    pub probes: usize,
    pub threads: usize,
}

impl Default for SolveConfig {
    fn default() -> Self {
        SolveConfig { tol: 1e-6, max_iters: 256, probes: 8, threads: 0 }
    }
}

impl SolveConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            num_threads()
        } else {
            self.threads
        }
    }
}

/// Per-training-step diagnostics.
#[derive(Clone, Debug)]
pub struct TrainStep {
    pub step: usize,
    pub grad_norm: f64,
    pub cg_iters: usize,
    pub sigma_n2: f64,
}

/// Sparse GRF Gaussian process.
pub struct GpModel {
    /// Cached walk components + union pattern for fast recombination.
    pub features: CombinedFeatures,
    pub hypers: Hypers,
    /// {0,1} training mask over all N nodes.
    pub mask: Vec<f64>,
    /// Observations embedded in R^N (zero off-train).
    pub y: Vec<f64>,
    pub solve: SolveConfig,
    /// Transposes of each C_l (for modulation gradients).
    c_t: Vec<Csr>,
    /// Current Φ and Φᵀ (refreshed after each hyperparameter change).
    phi: Csr,
    phi_t: Csr,
    /// Scratch buffers for the masked gram operator — the CG hot path
    /// must not allocate per iteration (EXPERIMENTS.md §Perf).
    scratch: std::cell::RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)>,
}

impl GpModel {
    /// Build from walk components. `train_nodes` and `train_y` define
    /// the observed data; all other nodes are latent.
    pub fn new(
        components: WalkComponents,
        hypers: Hypers,
        train_nodes: &[usize],
        train_y: &[f64],
    ) -> GpModel {
        assert_eq!(train_nodes.len(), train_y.len());
        assert_eq!(
            hypers.modulation.n_coeffs(),
            components.n_coeffs(),
            "modulation length must equal l_max+1 of the walk components"
        );
        let n = components.n();
        let mut mask = vec![0.0; n];
        let mut y = vec![0.0; n];
        for (&i, &v) in train_nodes.iter().zip(train_y) {
            mask[i] = 1.0;
            y[i] = v;
        }
        let c_t = components.c.iter().map(|c| c.transpose()).collect();
        let mut features = components.prepare();
        let phi = features.combine_into(&hypers.modulation.coeffs()).clone();
        let phi_t = phi.transpose();
        GpModel {
            features,
            hypers,
            mask,
            y,
            solve: SolveConfig::default(),
            c_t,
            phi,
            phi_t,
            scratch: std::cell::RefCell::new((
                vec![0.0; n],
                vec![0.0; n],
                vec![0.0; n],
            )),
        }
    }

    pub fn n(&self) -> usize {
        self.mask.len()
    }

    pub fn n_train(&self) -> usize {
        self.mask.iter().filter(|&&m| m == 1.0).count()
    }

    /// Refresh Φ after a hyperparameter update.
    fn refresh_features(&mut self) {
        let f = self.hypers.modulation.coeffs();
        self.phi = self.features.combine_into(&f).clone();
        self.phi_t = self.phi.transpose();
    }

    /// Replace observations (BO adds one point per step).
    pub fn set_data(&mut self, train_nodes: &[usize], train_y: &[f64]) {
        self.mask.iter_mut().for_each(|m| *m = 0.0);
        self.y.iter_mut().for_each(|v| *v = 0.0);
        for (&i, &v) in train_nodes.iter().zip(train_y) {
            self.mask[i] = 1.0;
            self.y[i] = v;
        }
    }

    // ------------------------------------------------------------------
    // Masked gram operator
    // ------------------------------------------------------------------

    /// y = m Φ Φᵀ m x + σ² x.
    fn apply_h(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n();
        let threads = self.solve.effective_threads();
        let sigma2 = self.hypers.sigma_n2();
        if threads > 1 && n > 4096 {
            let mx: Vec<f64> =
                self.mask.iter().zip(x).map(|(m, v)| m * v).collect();
            let mid = self.phi_t.matvec_par(&mx, threads);
            let prod = self.phi.matvec_par(&mid, threads);
            for i in 0..n {
                out[i] = self.mask[i] * prod[i] + sigma2 * x[i];
            }
        } else {
            // Allocation-free path through reusable scratch buffers.
            let mut guard = self.scratch.borrow_mut();
            let (mx, mid, prod) = &mut *guard;
            for i in 0..n {
                mx[i] = self.mask[i] * x[i];
            }
            self.phi_t.matvec_into(mx, mid);
            self.phi.matvec_into(mid, prod);
            for i in 0..n {
                out[i] = self.mask[i] * prod[i] + sigma2 * x[i];
            }
        }
    }

    /// Kernel product y = Φ (Φᵀ x) (no mask/noise).
    pub fn apply_kernel(&self, x: &[f64]) -> Vec<f64> {
        let threads = self.solve.effective_threads();
        if threads > 1 && self.n() > 4096 {
            let mid = self.phi_t.matvec_par(x, threads);
            self.phi.matvec_par(&mid, threads)
        } else {
            self.phi.matvec(&self.phi_t.matvec(x))
        }
    }

    /// Solve (m K m + σ² I) v = b by CG.
    pub fn solve_system(&self, b: &[f64]) -> (Vec<f64>, CgStats) {
        cg_solve(
            |x, out| self.apply_h(x, out),
            b,
            None,
            self.solve.tol,
            self.solve.max_iters,
        )
    }

    // ------------------------------------------------------------------
    // Hyperparameter learning (paper Eq. 8-11)
    // ------------------------------------------------------------------

    /// Stochastic LML gradient w.r.t. the packed parameter vector
    /// [modulation params..., log σ²].
    ///
    /// ∇L = ½ αᵀ (∂H/∂θ) α − ½ tr(H⁻¹ ∂H/∂θ),   α = H⁻¹ y,
    /// with the trace estimated by `probes` Rademacher probes z_s
    /// (restricted to the training mask) and solves v_s = H⁻¹ z_s.
    ///
    /// ∂H/∂f_l = m (C_l Φᵀ + Φ C_lᵀ) m, so each term reduces to dot
    /// products of Φᵀu and C_lᵀu — no kernel materialisation.
    pub fn lml_grad(&self, rng: &mut Rng) -> (Vec<f64>, TrainStep) {
        let n = self.n();
        let s = self.solve.probes;
        let sigma2 = self.hypers.sigma_n2();
        let n_coeff = self.features.components.n_coeffs();

        // --- batch of solves: [y, z_1..z_S] -------------------------------
        let mut rhs: Vec<Vec<f64>> = Vec::with_capacity(s + 1);
        rhs.push(self.y.clone());
        for _ in 0..s {
            let z: Vec<f64> = self
                .mask
                .iter()
                .map(|&m| if m == 1.0 { if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 } } else { 0.0 })
                .collect();
            rhs.push(z);
        }
        let mut solves = Vec::with_capacity(s + 1);
        let mut total_cg = 0;
        for b in &rhs {
            let (v, st) = self.solve_system(b);
            total_cg += st.iterations;
            solves.push(v);
        }
        let alpha = &solves[0];

        // --- per-vector projections: Φᵀ u and C_lᵀ u ----------------------
        // All vectors are already mask-supported (CG preserves the mask
        // support since rhs are masked).
        let proj_phi: Vec<Vec<f64>> =
            solves.iter().map(|v| self.phi_t.matvec(v)).collect();
        let proj_phi_rhs: Vec<Vec<f64>> =
            rhs.iter().map(|v| self.phi_t.matvec(v)).collect();
        let proj_c: Vec<Vec<Vec<f64>>> = self
            .c_t
            .iter()
            .map(|ct| solves.iter().map(|v| ct.matvec(v)).collect())
            .collect();
        let proj_c_rhs: Vec<Vec<Vec<f64>>> = self
            .c_t
            .iter()
            .map(|ct| rhs.iter().map(|v| ct.matvec(v)).collect())
            .collect();

        // --- gradient w.r.t. modulation coefficients ----------------------
        // quad_l  = αᵀ ∂H α     = 2 (C_lᵀα)·(Φᵀα)
        // trace_l ≈ (1/S) Σ_s [ (C_lᵀ v_s)·(Φᵀ z_s) + (Φᵀ v_s)·(C_lᵀ z_s) ]
        let mut grad_f = vec![0.0; n_coeff];
        for l in 0..n_coeff {
            let quad = 2.0 * dot(&proj_c[l][0], &proj_phi[0]);
            let mut tr = 0.0;
            for si in 1..=s {
                tr += dot(&proj_c[l][si], &proj_phi_rhs[si])
                    + dot(&proj_phi[si], &proj_c_rhs[l][si]);
            }
            let tr = if s > 0 { tr / s as f64 } else { 0.0 };
            grad_f[l] = 0.5 * quad - 0.5 * tr;
        }

        // --- gradient w.r.t. log σ² ---------------------------------------
        // ∂H/∂logσ² = σ² I (on the train block):
        // quad = σ² αᵀα;  trace ≈ σ²/S Σ v_s·z_s.
        let quad_n = sigma2 * dot(alpha, alpha);
        let mut tr_n = 0.0;
        for si in 1..=s {
            tr_n += dot(&solves[si], &rhs[si]);
        }
        let tr_n = if s > 0 { sigma2 * tr_n / s as f64 } else { 0.0 };
        let grad_log_noise = 0.5 * quad_n - 0.5 * tr_n;

        // --- chain rule to packed params ----------------------------------
        let jac = self.hypers.modulation.jacobian();
        let mut grad = Vec::with_capacity(self.hypers.n_params());
        for row in &jac {
            grad.push(dot(row, &grad_f));
        }
        grad.push(grad_log_noise);

        let gnorm = grad.iter().map(|g| g * g).sum::<f64>().sqrt();
        let _ = n;
        (
            grad,
            TrainStep {
                step: 0,
                grad_norm: gnorm,
                cg_iters: total_cg,
                sigma_n2: sigma2,
            },
        )
    }

    /// Maximise the LML with Adam for `steps` iterations.
    pub fn fit(&mut self, steps: usize, lr: f64, rng: &mut Rng) -> Vec<TrainStep> {
        let mut opt = Adam::new(self.hypers.n_params(), lr);
        let mut log = Vec::with_capacity(steps);
        for step in 0..steps {
            let (grad, mut info) = self.lml_grad(rng);
            let mut p = self.hypers.params();
            opt.step_ascent(&mut p, &grad);
            self.hypers.set_params(&p);
            self.refresh_features();
            info.step = step;
            log.push(info);
        }
        log
    }

    // ------------------------------------------------------------------
    // Posterior inference (paper Eq. 12, pathwise conditioning)
    // ------------------------------------------------------------------

    /// Posterior mean at every node: K (m α) with α = H⁻¹ (m y).
    pub fn posterior_mean(&self) -> (Vec<f64>, CgStats) {
        let rhs: Vec<f64> =
            self.mask.iter().zip(&self.y).map(|(m, y)| m * y).collect();
        let (alpha, st) = self.solve_system(&rhs);
        let malpha: Vec<f64> =
            self.mask.iter().zip(&alpha).map(|(m, a)| m * a).collect();
        (self.apply_kernel(&malpha), st)
    }

    /// One pathwise-conditioning sample from the posterior over all
    /// nodes: g + K m H⁻¹ m (y − g(x) − ε),  g = Φ w.
    pub fn posterior_sample(&self, rng: &mut Rng) -> Vec<f64> {
        let n = self.n();
        let w = rng.normal_vec(self.phi.n_cols);
        let threads = self.solve.effective_threads();
        let g = if threads > 1 && n > 4096 {
            self.phi.matvec_par(&w, threads)
        } else {
            self.phi.matvec(&w)
        };
        let sigma = self.hypers.sigma_n2().sqrt();
        let rhs: Vec<f64> = (0..n)
            .map(|i| self.mask[i] * (self.y[i] - g[i] - sigma * rng.normal()))
            .collect();
        let (alpha, _) = self.solve_system(&rhs);
        let malpha: Vec<f64> =
            self.mask.iter().zip(&alpha).map(|(m, a)| m * a).collect();
        let corr = self.apply_kernel(&malpha);
        (0..n).map(|i| g[i] + corr[i]).collect()
    }

    /// Predictive mean + variance at every node, variance estimated
    /// from `n_samples` pathwise draws (includes observation noise).
    pub fn predict(&self, n_samples: usize, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
        let n = self.n();
        let (mean, _) = self.posterior_mean();
        let mut m2 = vec![0.0; n];
        for _ in 0..n_samples {
            let s = self.posterior_sample(rng);
            for i in 0..n {
                let d = s[i] - mean[i];
                m2[i] += d * d;
            }
        }
        let sigma2 = self.hypers.sigma_n2();
        let var: Vec<f64> = m2
            .iter()
            .map(|v| v / n_samples.max(1) as f64 + sigma2)
            .collect();
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::modulation::Modulation;
    use crate::graph::generators;
    use crate::linalg::chol::Cholesky;
    use crate::linalg::Mat;
    use crate::walks::{sample_components, WalkConfig};

    /// Exact train-block LML (paper Eq. 8) via dense algebra — oracle.
    fn dense_lml_of(m: &GpModel) -> f64 {
        let n = m.n();
        let phi = Mat::from_rows(&m.phi.to_dense());
        let k = phi.matmul(&phi.transpose());
        let train: Vec<usize> = (0..n).filter(|&i| m.mask[i] == 1.0).collect();
        let t = train.len();
        let mut h = Mat::zeros(t, t);
        for (a, &i) in train.iter().enumerate() {
            for (b, &j) in train.iter().enumerate() {
                h[(a, b)] = k[(i, j)];
            }
            h[(a, a)] += m.hypers.sigma_n2();
        }
        let yv: Vec<f64> = train.iter().map(|&i| m.y[i]).collect();
        let ch = Cholesky::new(&h).unwrap();
        let alpha = ch.solve(&yv);
        -0.5 * dot(&yv, &alpha) - 0.5 * ch.logdet()
            - 0.5 * t as f64 * (2.0 * std::f64::consts::PI).ln()
    }

    fn small_model(seed: u64) -> (GpModel, Mat) {
        let g = generators::grid2d(5, 5);
        let cfg = WalkConfig { n_walks: 300, max_len: 4, threads: 1, ..Default::default() };
        let comps = sample_components(&g, &cfg, seed);
        let mut rng = Rng::new(seed);
        let train: Vec<usize> = rng.sample_without_replacement(25, 12);
        let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.3).sin()).collect();
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 4), 0.1);
        let model = GpModel::new(comps, hypers, &train, &y);
        // Dense K̂ for oracles.
        let phi = Mat::from_rows(&model.phi.to_dense());
        let k = phi.matmul(&phi.transpose());
        (model, k)
    }

    #[test]
    fn posterior_mean_matches_dense_solve() {
        let (model, k) = small_model(7);
        let n = model.n();
        // Dense oracle: mu = K m (m K m + s I)^{-1} m y.
        let sigma2 = model.hypers.sigma_n2();
        let mut h = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                h[(i, j)] = model.mask[i] * k[(i, j)] * model.mask[j];
            }
            h[(i, i)] += sigma2;
        }
        let rhs: Vec<f64> =
            (0..n).map(|i| model.mask[i] * model.y[i]).collect();
        let alpha = Cholesky::new(&h).unwrap().solve(&rhs);
        let malpha: Vec<f64> =
            (0..n).map(|i| model.mask[i] * alpha[i]).collect();
        let expect = k.matvec(&malpha);
        let (mean, st) = model.posterior_mean();
        assert!(st.converged, "{st:?}");
        for i in 0..n {
            assert!(
                (mean[i] - expect[i]).abs() < 1e-4,
                "node {i}: {} vs {}",
                mean[i],
                expect[i]
            );
        }
    }

    #[test]
    fn lml_grad_matches_finite_difference() {
        // Exact-LML finite difference via dense Cholesky vs our
        // stochastic gradient with MANY probes.
        let (mut model, _) = small_model(3);
        model.solve.probes = 400;
        model.solve.tol = 1e-10;
        model.solve.max_iters = 2000;
        let mut rng = Rng::new(11);
        let (grad, _) = model.lml_grad(&mut rng);
        let dense_lml = dense_lml_of;
        let p0 = model.hypers.params();
        let eps = 1e-5;
        for pi in 0..p0.len() {
            let mut mp = model.hypers.clone();
            let mut pv = p0.clone();
            pv[pi] += eps;
            mp.set_params(&pv);
            let mut m_plus = GpModel::new(
                model.features.components.clone(),
                mp,
                &(0..model.n()).filter(|&i| model.mask[i] == 1.0).collect::<Vec<_>>(),
                &(0..model.n())
                    .filter(|&i| model.mask[i] == 1.0)
                    .map(|i| model.y[i])
                    .collect::<Vec<_>>(),
            );
            m_plus.refresh_features();
            let f_plus = dense_lml(&m_plus);
            let f_zero = dense_lml(&model);
            let fd = (f_plus - f_zero) / eps;
            assert!(
                (grad[pi] - fd).abs() < 0.05 * (1.0 + fd.abs()),
                "param {pi}: stochastic {} vs fd {fd}",
                grad[pi]
            );
        }
    }

    #[test]
    fn fit_increases_exact_lml() {
        let (mut model, _) = small_model(5);
        let mut rng = Rng::new(0);
        let before = dense_lml_of(&model);
        model.fit(60, 0.05, &mut rng);
        let after = dense_lml_of(&model);
        assert!(
            after > before,
            "Adam on the stochastic LML gradient should increase the \
             exact LML: {before} -> {after}"
        );
    }

    #[test]
    fn posterior_sample_mean_converges_to_posterior_mean() {
        let (model, _) = small_model(9);
        let mut rng = Rng::new(4);
        let n = model.n();
        let (mean, _) = model.posterior_mean();
        let reps = 250;
        let mut acc = vec![0.0; n];
        for _ in 0..reps {
            let s = model.posterior_sample(&mut rng);
            for i in 0..n {
                acc[i] += s[i];
            }
        }
        let mut max_err: f64 = 0.0;
        for i in 0..n {
            max_err = max_err.max((acc[i] / reps as f64 - mean[i]).abs());
        }
        assert!(max_err < 0.3, "max_err={max_err}");
    }

    #[test]
    fn predict_variance_shrinks_at_train_nodes() {
        let (model, _) = small_model(13);
        let mut rng = Rng::new(8);
        let (_, var) = model.predict(64, &mut rng);
        let train_var: f64 = (0..model.n())
            .filter(|&i| model.mask[i] == 1.0)
            .map(|i| var[i])
            .sum::<f64>()
            / model.n_train() as f64;
        let test_var: f64 = (0..model.n())
            .filter(|&i| model.mask[i] == 0.0)
            .map(|i| var[i])
            .sum::<f64>()
            / (model.n() - model.n_train()) as f64;
        assert!(
            train_var < test_var,
            "variance should shrink at observed nodes: {train_var} vs {test_var}"
        );
    }
}
