//! JLT + Woodbury alternative solver (paper App. B).
//!
//! Project the GRF features through a Gaussian Johnson–Lindenstrauss
//! map G ∈ R^{N×m}: K₁ = ΦG/√m, then solve
//! (K̂ + σ²I)⁻¹ b ≈ (1/σ²)[I − U (I_m + UᵀU)⁻¹ Uᵀ] b,  U = K₁/σ.
//! Trades sparsity for an m×m dense solve: O(nnz(Φ)·m + N m² + m³).

use crate::linalg::chol::Cholesky;
use crate::linalg::Mat;
use crate::sparse::Csr;
use crate::util::rng::Rng;
use anyhow::Result;

/// Precomputed JLT/Woodbury solver for one (Φ, σ²).
pub struct WoodburySolver {
    /// U = Φ G / (√m σ), dense N×m.
    u: Mat,
    /// Cholesky of (I_m + UᵀU).
    small: Cholesky,
    sigma2: f64,
}

impl WoodburySolver {
    /// Build with sketch dimension `m` (paper: logarithmic in N suffices
    /// for JL-type accuracy).
    pub fn new(phi: &Csr, sigma2: f64, m: usize, rng: &mut Rng) -> Result<WoodburySolver> {
        let n = phi.n_rows;
        // U[i, :] = (1/(sqrt(m) sigma)) * sum_c phi[i,c] * G[c, :]
        // computed row-by-row from the sparse phi. G is materialised
        // column-block free: G[c, :] regenerated via a per-row RNG would
        // break iid-ness across rows of phi, so we materialise G (N×m).
        let scale = 1.0 / ((m as f64).sqrt() * sigma2.sqrt());
        let mut g = Mat::zeros(phi.n_cols, m);
        for v in &mut g.data {
            *v = rng.normal();
        }
        let mut u = Mat::zeros(n, m);
        for i in 0..n {
            let (cols, vals) = phi.row(i);
            let ui = u.row_mut(i);
            for (c, v) in cols.iter().zip(vals) {
                let grow = g.row(*c as usize);
                for (uij, gj) in ui.iter_mut().zip(grow) {
                    *uij += v * gj;
                }
            }
            for uij in ui.iter_mut() {
                *uij *= scale;
            }
        }
        // I_m + UᵀU
        let utu = u.transpose().matmul(&u);
        let mut small = utu;
        small.add_diag(1.0);
        let small = Cholesky::new(&small)?;
        Ok(WoodburySolver { u, small, sigma2 })
    }

    /// Approximate solve of (K̂ + σ²I) v = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.u.rows;
        assert_eq!(b.len(), n);
        // v = (1/σ²)[b − U (I + UᵀU)⁻¹ (Uᵀ b)]
        let utb = self.u.transpose().matvec(b);
        let w = self.small.solve(&utb);
        let uw = self.u.matvec(&w);
        (0..n).map(|i| (b[i] - uw[i]) / self.sigma2).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn random_phi(rng: &mut Rng, n: usize) -> Csr {
        let mut b = CooBuilder::new(n, n);
        for i in 0..n {
            for _ in 0..3 {
                b.push(i as u32, rng.below(n) as u32, 0.3 * rng.normal());
            }
        }
        b.build()
    }

    #[test]
    fn woodbury_approximates_direct_solve() {
        let mut rng = Rng::new(0);
        let n = 60;
        let phi = random_phi(&mut rng, n);
        let sigma2 = 0.5;
        // Large sketch -> high accuracy.
        let solver = WoodburySolver::new(&phi, sigma2, 256, &mut rng).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let got = solver.solve(&b);
        // Direct dense solve.
        let d = phi.to_dense();
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = (0..n).map(|c| d[i][c] * d[j][c]).sum();
            }
            a[(i, i)] += sigma2;
        }
        let expect = Cholesky::new(&a).unwrap().solve(&b);
        // JL error scales ~1/sqrt(m); check relative L2 error.
        let num: f64 = got
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 =
            expect.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        assert!(num / den < 0.35, "relative error {}", num / den);
    }

    #[test]
    fn exact_when_sketch_huge_and_phi_zero() {
        // Phi = 0 -> system is sigma^2 I -> solve is b / sigma^2.
        let mut rng = Rng::new(1);
        let phi = Csr::zeros(10, 10);
        let solver = WoodburySolver::new(&phi, 0.25, 8, &mut rng).unwrap();
        let b: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let v = solver.solve(&b);
        for i in 0..10 {
            assert!((v[i] - b[i] / 0.25).abs() < 1e-10);
        }
    }
}
