//! Adam optimiser (Kingma & Ba) — the paper trains all hyperparameters
//! with Adam (App. C.3/C.4: lr 0.01, up to 1000 iterations).

pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// Ascent step: params += step(grad) maximises the objective
    /// (our LML is maximised, so we pass the gradient directly).
    pub fn step_ascent(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] += self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// Descent step (minimisation).
    pub fn step_descent(&mut self, params: &mut [f64], grad: &[f64]) {
        let neg: Vec<f64> = grad.iter().map(|g| -g).collect();
        self.step_ascent(params, &neg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimises_quadratic() {
        // f(x) = (x0-3)^2 + 2(x1+1)^2
        let mut x = vec![0.0, 0.0];
        let mut opt = Adam::new(2, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0), 4.0 * (x[1] + 1.0)];
            opt.step_descent(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-2, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-2, "{x:?}");
    }

    #[test]
    fn ascent_maximises() {
        // f(x) = -(x-2)^2, grad = -2(x-2)
        let mut x = vec![0.0];
        let mut opt = Adam::new(1, 0.05);
        for _ in 0..800 {
            let g = vec![-2.0 * (x[0] - 2.0)];
            opt.step_ascent(&mut x, &g);
        }
        assert!((x[0] - 2.0).abs() < 1e-2, "{x:?}");
    }
}
