//! Evaluation metrics: RMSE and negative log predictive density (NLPD),
//! exactly as defined in App. C.4.

/// Root mean squared error between predictions and targets.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    assert!(!pred.is_empty());
    let mse: f64 = pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t).powi(2))
        .sum::<f64>()
        / pred.len() as f64;
    mse.sqrt()
}

/// Gaussian NLPD: -(1/N) Σ log N(y_i | mu_i, var_i).
pub fn nlpd(mu: &[f64], var: &[f64], y: &[f64]) -> f64 {
    assert_eq!(mu.len(), y.len());
    assert_eq!(var.len(), y.len());
    assert!(!mu.is_empty());
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    let total: f64 = mu
        .iter()
        .zip(var)
        .zip(y)
        .map(|((m, v), t)| {
            let v = v.max(1e-12);
            0.5 * (ln2pi + v.ln() + (t - m).powi(2) / v)
        })
        .sum();
    total / mu.len() as f64
}

/// Simple regret: best-so-far gap to the optimum (BO metric, §4.3).
pub fn simple_regret_curve(observed: &[f64], optimum: f64) -> Vec<f64> {
    let mut best = f64::NEG_INFINITY;
    observed
        .iter()
        .map(|&v| {
            best = best.max(v);
            optimum - best
        })
        .collect()
}

/// Classification accuracy.
pub fn accuracy(pred: &[usize], target: &[usize]) -> f64 {
    assert_eq!(pred.len(), target.len());
    let hits = pred.iter().zip(target).filter(|(a, b)| a == b).count();
    hits as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn nlpd_is_minimised_by_truth() {
        // For fixed var, NLPD at mu=y is lower than mu != y.
        let y = [1.0, -2.0];
        let var = [0.5, 0.5];
        assert!(nlpd(&[1.0, -2.0], &var, &y) < nlpd(&[0.0, 0.0], &var, &y));
        // Calibration: for standard normal residuals, NLPD ~ 0.5*(ln 2pi + 1).
        let v = nlpd(&[0.0], &[1.0], &[1.0]);
        assert!((v - 0.5 * ((2.0 * std::f64::consts::PI).ln() + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn regret_monotone_nonincreasing() {
        let r = simple_regret_curve(&[0.1, 0.5, 0.3, 0.9], 1.0);
        let expect = [0.9, 0.5, 0.5, 0.1];
        for (a, b) in r.iter().zip(expect) {
            assert!((a - b).abs() < 1e-12, "{r:?}");
        }
        for w in r.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
    }
}
