//! Gaussian-process layer: sparse GRF-GP (the paper's contribution) and
//! exact dense baselines, with the full three-stage workflow of §3.2.

pub mod adam;
pub mod exact;
pub mod metrics;
pub mod model;
pub mod modulation;
pub mod woodbury;

pub use exact::{ExactGp, ExactKernel};
pub use model::{
    DeltaOutcome, GpModel, ModelReadView, SolveConfig, SolveScratch, TrainStep,
};
pub use modulation::{Hypers, Modulation};
