//! Exact dense GP baselines — the `O(N^3)` comparators.
//!
//! Implements the paper's exact kernels (diffusion `exp(-βL)`, Matérn
//! `(2ν/κ² + L̃)^{-ν}`) via a full symmetric eigendecomposition of the
//! Laplacian, computed **once**; hyperparameter training then rescales
//! the spectrum (`K(β) = σ_f² V exp(-βλ) Vᵀ`), which is how GPflow
//! implements these kernels too.

use crate::gp::metrics;
use crate::graph::Graph;
use crate::linalg::chol::Cholesky;
use crate::linalg::eigen::sym_eigen;
use crate::linalg::Mat;
use crate::util::rng::Rng;
use anyhow::Result;

/// Exact kernel family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ExactKernel {
    /// K = σ_f² exp(-β L)
    Diffusion,
    /// K = σ_f² (2ν/κ² + L̃)^{-ν}, L̃ the normalised Laplacian.
    Matern { nu: f64 },
}

/// Dense exact GP on a graph.
pub struct ExactGp {
    pub kernel: ExactKernel,
    /// Laplacian spectrum (ascending) and eigenvectors.
    lam: Vec<f64>,
    v: Mat,
    /// Hyperparameters.
    pub beta: f64,
    pub sigma_f2: f64,
    pub sigma_n2: f64,
    /// Training data.
    train: Vec<usize>,
    y: Vec<f64>,
}

impl ExactGp {
    /// Eigendecompose the (normalised) Laplacian once — O(N^3).
    pub fn new(g: &Graph, kernel: ExactKernel) -> ExactGp {
        let n = g.num_nodes();
        let lap = match kernel {
            ExactKernel::Diffusion => Mat::from_rows(&g.dense_laplacian()),
            ExactKernel::Matern { .. } => {
                // Normalised Laplacian D^{-1/2} L D^{-1/2}.
                let l = g.dense_laplacian();
                let d: Vec<f64> = (0..n)
                    .map(|i| g.weighted_degree(i).max(1e-12).sqrt())
                    .collect();
                let mut nl = Mat::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        nl[(i, j)] = l[i][j] / (d[i] * d[j]);
                    }
                }
                nl
            }
        };
        let (lam, v) = sym_eigen(&lap);
        ExactGp {
            kernel,
            lam,
            v,
            beta: 1.0,
            sigma_f2: 1.0,
            sigma_n2: 0.1,
            train: Vec::new(),
            y: Vec::new(),
        }
    }

    pub fn set_data(&mut self, train: &[usize], y: &[f64]) {
        assert_eq!(train.len(), y.len());
        self.train = train.to_vec();
        self.y = y.to_vec();
    }

    /// Spectral kernel weights g(λ) for the current hyperparameters.
    fn spectral(&self) -> Vec<f64> {
        self.lam
            .iter()
            .map(|&l| match self.kernel {
                ExactKernel::Diffusion => {
                    self.sigma_f2 * (-self.beta * l.max(0.0)).exp()
                }
                ExactKernel::Matern { nu } => {
                    // beta plays the role of 2ν/κ².
                    self.sigma_f2 * (self.beta + l.max(0.0)).powf(-nu)
                }
            })
            .collect()
    }

    /// Materialise the full kernel matrix K = V g(Λ) Vᵀ — O(N^3).
    pub fn kernel_matrix(&self) -> Mat {
        let n = self.lam.len();
        let gl = self.spectral();
        // K = (V * g) Vᵀ
        let mut vg = Mat::zeros(n, n);
        for i in 0..n {
            for k in 0..n {
                vg[(i, k)] = self.v[(i, k)] * gl[k];
            }
        }
        vg.matmul_par(&self.v.transpose(), 0)
    }

    /// Train-block kernel + noise, Cholesky-factorised.
    fn train_system(&self, k: &Mat) -> Result<(Cholesky, Vec<f64>)> {
        let t = self.train.len();
        let mut h = Mat::zeros(t, t);
        for (a, &i) in self.train.iter().enumerate() {
            for (b, &j) in self.train.iter().enumerate() {
                h[(a, b)] = k[(i, j)];
            }
            h[(a, a)] += self.sigma_n2;
        }
        let ch = Cholesky::new(&h)?;
        let alpha = ch.solve(&self.y);
        Ok((ch, alpha))
    }

    /// Exact log marginal likelihood (paper Eq. 8).
    pub fn lml(&self) -> Result<f64> {
        let k = self.kernel_matrix();
        let (ch, alpha) = self.train_system(&k)?;
        let t = self.train.len() as f64;
        Ok(-0.5 * crate::linalg::dot(&self.y, &alpha)
            - 0.5 * ch.logdet()
            - 0.5 * t * (2.0 * std::f64::consts::PI).ln())
    }

    /// Fit (β, σ_f², σ_n²) by coordinate-wise golden-section-ish log
    /// grid ascent on the exact LML (robust; the exact baseline has
    /// only 3 hyperparameters).
    pub fn fit(&mut self, rounds: usize) -> Result<f64> {
        let mut best = self.lml()?;
        for _ in 0..rounds {
            for param in 0..3 {
                let current = match param {
                    0 => self.beta,
                    1 => self.sigma_f2,
                    _ => self.sigma_n2,
                };
                let mut best_v = current;
                for &mult in &[0.1, 0.25, 0.5, 0.8, 1.25, 2.0, 4.0, 10.0] {
                    let cand = (current * mult).clamp(1e-5, 1e4);
                    match param {
                        0 => self.beta = cand,
                        1 => self.sigma_f2 = cand,
                        _ => self.sigma_n2 = cand,
                    }
                    if let Ok(l) = self.lml() {
                        if l > best {
                            best = l;
                            best_v = cand;
                        }
                    }
                }
                match param {
                    0 => self.beta = best_v,
                    1 => self.sigma_f2 = best_v,
                    _ => self.sigma_n2 = best_v,
                }
            }
        }
        Ok(best)
    }

    /// Exact posterior mean and variance at every node — O(N^3).
    pub fn predict(&self) -> Result<(Vec<f64>, Vec<f64>)> {
        let n = self.lam.len();
        let k = self.kernel_matrix();
        let (ch, alpha) = self.train_system(&k)?;
        let mut mean = vec![0.0; n];
        let mut var = vec![0.0; n];
        for i in 0..n {
            let k_ix: Vec<f64> =
                self.train.iter().map(|&j| k[(i, j)]).collect();
            mean[i] = crate::linalg::dot(&k_ix, &alpha);
            let w = ch.solve(&k_ix);
            var[i] = (k[(i, i)] - crate::linalg::dot(&k_ix, &w)).max(1e-12)
                + self.sigma_n2;
        }
        Ok((mean, var))
    }

    /// Exact posterior sample over all nodes (dense Cholesky of the
    /// full posterior covariance) — for BO baselines on small graphs.
    pub fn posterior_sample(&self, rng: &mut Rng) -> Result<Vec<f64>> {
        let n = self.lam.len();
        let k = self.kernel_matrix();
        let (ch, alpha) = self.train_system(&k)?;
        let mut mean = vec![0.0; n];
        for i in 0..n {
            let k_ix: Vec<f64> =
                self.train.iter().map(|&j| k[(i, j)]).collect();
            mean[i] = crate::linalg::dot(&k_ix, &alpha);
        }
        // Posterior covariance: K - K_x' H^{-1} K_x.
        let t = self.train.len();
        let mut kx = Mat::zeros(n, t);
        for i in 0..n {
            for (b, &j) in self.train.iter().enumerate() {
                kx[(i, b)] = k[(i, j)];
            }
        }
        let hinv_kxt = ch.solve_mat(&kx.transpose());
        let reduction = kx.matmul(&hinv_kxt);
        let mut cov = k;
        for i in 0..n {
            for j in 0..n {
                cov[(i, j)] -= reduction[(i, j)];
            }
            cov[(i, i)] += 1e-8; // jitter
        }
        let chp = Cholesky::new(&cov)?;
        let u = rng.normal_vec(n);
        let z = chp.sample(&u);
        Ok((0..n).map(|i| mean[i] + z[i]).collect())
    }

    /// Test metrics (RMSE / NLPD) on held-out nodes.
    pub fn evaluate(&self, test: &[usize], y_test: &[f64]) -> Result<(f64, f64)> {
        let (mean, var) = self.predict()?;
        let mu: Vec<f64> = test.iter().map(|&i| mean[i]).collect();
        let vv: Vec<f64> = test.iter().map(|&i| var[i]).collect();
        Ok((metrics::rmse(&mu, y_test), metrics::nlpd(&mu, &vv, y_test)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn diffusion_kernel_matches_expm() {
        let g = generators::ring(10);
        let gp = ExactGp::new(&g, ExactKernel::Diffusion);
        let k = gp.kernel_matrix();
        let l = Mat::from_rows(&g.dense_laplacian());
        let expect = crate::linalg::expm::diffusion_kernel(&l, 1.0, 1.0);
        for i in 0..10 {
            for j in 0..10 {
                assert!(
                    (k[(i, j)] - expect[(i, j)]).abs() < 1e-8,
                    "({i},{j}): {} vs {}",
                    k[(i, j)],
                    expect[(i, j)]
                );
            }
        }
    }

    #[test]
    fn exact_gp_interpolates_smooth_signal() {
        let g = generators::ring(24);
        let truth: Vec<f64> = (0..24)
            .map(|i| (i as f64 / 24.0 * std::f64::consts::TAU).sin())
            .collect();
        let train: Vec<usize> = (0..24).step_by(2).collect();
        let y: Vec<f64> = train.iter().map(|&i| truth[i]).collect();
        let mut gp = ExactGp::new(&g, ExactKernel::Diffusion);
        gp.sigma_n2 = 1e-4;
        gp.beta = 1.0;
        gp.set_data(&train, &y);
        gp.fit(2).unwrap();
        let test: Vec<usize> = (1..24).step_by(2).collect();
        let yt: Vec<f64> = test.iter().map(|&i| truth[i]).collect();
        let (rmse, nlpd) = gp.evaluate(&test, &yt).unwrap();
        assert!(rmse < 0.2, "rmse={rmse}");
        assert!(nlpd < 1.0, "nlpd={nlpd}");
    }

    #[test]
    fn matern_kernel_is_psd() {
        let g = generators::grid2d(4, 4);
        let gp = ExactGp::new(&g, ExactKernel::Matern { nu: 2.0 });
        let k = gp.kernel_matrix();
        let (lam, _) = crate::linalg::eigen::jacobi_eigen(&k, 100);
        assert!(lam[0] > -1e-9, "min eig {}", lam[0]);
    }

    #[test]
    fn fit_improves_lml() {
        let g = generators::ring(16);
        let truth: Vec<f64> =
            (0..16).map(|i| (i as f64 * 0.8).cos()).collect();
        let train: Vec<usize> = (0..16).step_by(2).collect();
        let y: Vec<f64> = train.iter().map(|&i| truth[i]).collect();
        let mut gp = ExactGp::new(&g, ExactKernel::Diffusion);
        gp.set_data(&train, &y);
        let before = gp.lml().unwrap();
        let after = gp.fit(3).unwrap();
        assert!(after >= before);
    }
}
