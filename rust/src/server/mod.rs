//! GP inference server — the L3 "coordinator" surface.
//!
//! A std-net TCP server speaking newline-delimited JSON, in the style
//! of a model-inference router: a listener thread accepts connections,
//! requests are routed into a shared queue, and a worker pool owns the
//! GP model behind a mutex, micro-batching compatible requests (e.g.
//! several `predict` requests are merged into one posterior evaluation
//! under a single lock acquisition / feature borrow, and graph
//! mutations coalesce with observations into one ordered write batch).
//!
//! Protocol (one JSON object per line):
//!   {"op":"observe","node":17,"y":0.42}
//!   {"op":"predict","nodes":[1,2,3],"samples":16}
//!   {"op":"add_edge","u":3,"v":7,"w":0.5}     → incremental GRF patch
//!   {"op":"remove_edge","u":3,"v":7}          → incremental GRF patch
//!   {"op":"add_node"}                         → appends isolated node
//!   {"op":"sample"}                           → full posterior draw argmax
//!   {"op":"thompson"}                         → next query node
//!   {"op":"stats"}
//!   {"op":"metrics"}                          → telemetry registry (JSON)
//!   {"op":"metrics","format":"prometheus"}    → Prometheus text rendering
//!   {"op":"shutdown"}
//! Responses: {"ok":true, ...} or
//! {"ok":false,"error":"...","error_kind":"parse|protocol|overload|internal"}.
//! Every response to a decoded frame additionally carries a
//! `trace_id` (see "Observability" below).
//!
//! ## Starting a server
//!
//! [`ServeOptions`] is the single entry point: a builder over the
//! listen address (or an already-bound listener), the serving-edge
//! [`ServerConfig`] (with dedicated setters for the common knobs —
//! shards, metrics listener, alert rules, slow-request threshold), the
//! model seed, and the walk-[`Termination`] scheme
//! (`--termination iid|antithetic|qmc`; see the
//! [`crate::walks`] docs, "Termination schemes"). The pre-builder
//! functions `serve` / `serve_with` / `serve_on` / `serve_on_with` are
//! deprecated shims over it.
//!
//! ## Observability
//!
//! The server is instrumented through [`crate::obs`] — a global
//! lock-free registry of atomic counters, gauges, and log₂-bucket
//! latency histograms, exported wholesale by the `{"op":"metrics"}`
//! op. The metrics handler reads only atomics (the registry + the
//! server counters below); unlike `stats` it **never takes the model
//! lock**, so scraping cannot perturb serving.
//!
//! **Metric catalogue** (full list: `obs::registry::all`; names are
//! stable wire API):
//!
//! * `req_<op>` / `request_ns_<op>` — per-op request count and wall
//!   time (recorded at the wire dispatch point, so batching-window
//!   waits are included: this is client-visible latency). Ops:
//!   observe, predict, add_edge, remove_edge, add_node, sample,
//!   thompson, stats, metrics, shutdown, fault.
//! * `errors_{parse,protocol,overload,internal}` — error replies by
//!   `error_kind`, wire-decoder errors included.
//! * `cg_solves` / `cg_block_solves` / `cg_noconverged`, `cg_iters` /
//!   `cg_block_iters` (iterations-to-converge per solve),
//!   `cg_residual_decades` (residual trajectory, in digits),
//!   `cg_last_residual` — the solver layer.
//! * `spmv_{ell,csr}` + `spmv_{ell,csr}_ns`, `spmm_{ell,csr}` +
//!   `spmm_{ell,csr}_ns` — kernel dispatches by selected layout.
//! * `stream_delta_batches`, `resample_walks` (union fan-out),
//!   `resample_rows`, `resample_ns`, `compact_ns`,
//!   `stream_compactions` — the streaming delta engine.
//! * `snapshot_publishes`, `snapshot_publish_ns` (build + swap),
//!   `predict_snapshot_lag_ns` (age of the snapshot each predict
//!   computed off — the staleness the RCU read path delivers).
//! * `slow_requests`, `grf_variance_{iid,antithetic,qmc}` — kernel
//!   estimator variance per termination scheme (see
//!   `benches/hotpath.rs` and [`crate::walks::kernel_variance`]).
//!
//! **Histogram buckets** are fixed log₂ scale: bucket `i ≥ 1` holds
//! values in `[2^(i-1), 2^i)` ns (bucket 0 holds exact zeros), 44
//! buckets total; p50/p95/p99 in the JSON export are bucket upper
//! bounds (≤ 2× upward bias). See `obs::registry` docs.
//!
//! **trace_id semantics**: every response to a decoded frame carries
//! `trace_id = "<graph_version-hex>-<dispatch-seq-hex>"`, where the
//! dispatch sequence is a server-global monotone counter. For
//! predicts, `trace_id` correlates a log line with the
//! (`graph_version`, `rng_seq`) pair already echoed in the response —
//! the pair that reproduces the prediction bit-for-bit. Requests
//! slower than `--slow-request-ms` additionally log one structured
//! JSON line to stderr (`slow_request` record, keyed by the same
//! `trace_id`) and bump `slow_requests`.
//!
//! **Prometheus scrape example** — the text rendering is standard
//! exposition format, prefixed `grfgp_`:
//!
//! ```text
//! $ echo '{"op":"metrics","format":"prometheus"}' | nc 127.0.0.1 7701
//! {"ok":true,"text":"# TYPE grfgp_req_predict counter\n..."}
//! ```
//!
//! ## Limits & failure modes
//!
//! The wire layer is attacker-facing and every limit below is a
//! [`ServerConfig`] knob; the listed defaults are what
//! [`ServeOptions::new`] uses.
//!
//! * **Frame cap** (`wire.max_frame_bytes`, 256 KiB): one
//!   newline-delimited frame may not exceed this. The decoder's
//!   reassembly buffer is bounded by the same number — an oversized
//!   frame is *discarded as it streams in* (never stored) and answered
//!   with exactly one `protocol` error at its terminating newline; the
//!   connection then resynchronises on the next frame.
//! * **Depth cap** (`wire.max_parse_depth`, 64): JSON nesting beyond
//!   this is a `parse` error — `[[[[…` bombs cannot exhaust the stack.
//!   Lone `\uXXXX` surrogates and invalid UTF-8 are `parse` errors by
//!   default (`wire.unicode`, see [`wire::UnicodeMode`] for the
//!   documented lossy `Replace` mode).
//! * **Connection cap** (`max_connections`, 256): excess connections
//!   are answered with a single `overload` ("busy") line and closed
//!   gracefully; the slot frees as soon as an accepted connection
//!   ends.
//! * **Timeouts**: reads poll at `read_timeout` (250 ms) so every
//!   client thread observes shutdown promptly even when its peer is
//!   idle — this is what makes shutdown complete with idle connections
//!   attached. A connection with no complete frame for `idle_timeout`
//!   (10 min) is told so (`protocol` error) and closed; slow-loris
//!   byte-trickling does not count as progress. Writes block at most
//!   `write_timeout` (30 s).
//! * **Error taxonomy**: every error reply carries `error_kind` —
//!   `parse` (bad JSON), `protocol` (valid JSON, unusable request or
//!   oversized frame), `overload` (connection cap), `internal`
//!   (handler panic, batch timeout). Malformed input costs one error
//!   line, never the connection.
//! * **Panic isolation**: each request dispatch runs under
//!   `catch_unwind`; a panicking handler yields an `internal` error on
//!   that connection and poisons nothing — all locks are acquired with
//!   poison recovery, so other clients keep being served and shutdown
//!   still completes. (`fault_injection` enables a test-only
//!   `{"op":"fault"}` that panics on demand to prove this end to end;
//!   it is off by default and rejected as `protocol` when off.)
//! * **Shutdown semantics**: `{"op":"shutdown"}` is acknowledged
//!   (`{"ok":true,"bye":true}`), then the accept loop stops and every
//!   client thread exits within one `read_timeout` tick; `serve`
//!   returns once all connections have drained.
//!
//! ## Dynamic-graph lifecycle
//!
//! The server owns a [`FeatureEngine`] next to the model — the mono
//! [`StreamingFeatures`] by default, or the partitioned
//! [`crate::shard::ShardedFeatures`] behind `--shards`. A graph
//! mutation does **not** rebuild the features: only the walks whose
//! trajectories visited the delta endpoints are resampled, the affected
//! feature rows are patched through the model
//! ([`GpModel::apply_graph_delta`]), and the posterior-mean system is
//! re-solved warm-started from the pre-delta solution (carried in
//! [`ModelState::alpha`]). Patched rows accumulate in a delta row-store
//! overlay that compacts periodically, re-running the `to_ell_auto`
//! layout policy on the fresh Φ. Runs of **consecutive graph deltas in
//! a write batch coalesce into one engine call**
//! ([`GpModel::apply_graph_delta_batch`]): one union invalidation,
//! one parallel walk resample, one row patch, and one warm re-solve
//! serve the whole run, while every delta is still acknowledged under
//! its own monotone `graph_version`.
//!
//! Each successful mutation bumps `graph_version` (monotone, reported
//! by `stats`); every `add_edge`/`remove_edge`/`add_node` response
//! carries the post-delta version and every `predict` response carries
//! the version its numbers were computed under, so a client that saw a
//! delta acknowledged at version `k` can reject any prediction stamped
//! `< k` as stale.
//!
//! ## Concurrency & snapshot semantics
//!
//! Reads and writes are split RCU-style (see [`snapshot`]):
//!
//! * **Publication point.** Writers mutate the private [`ModelState`]
//!   under the model mutex; at the end of every coalesced write batch
//!   ([`ModelState::apply_writes`]) they build an immutable
//!   [`snapshot::ReadSnapshot`] (Φ/Φᵀ overlay views with `Arc`-shared
//!   bases, cached α, hyperparameters, `graph_version`) and swap it
//!   into the [`snapshot::SnapshotCell`] **before the writes are
//!   acknowledged** — an acked `graph_version` is therefore always
//!   servable, and a predict response can never carry a version newer
//!   than its numbers.
//! * **Wait-free reads.** `predict` never acquires the model mutex
//!   (counter-asserted in the tests): it loads the latest published
//!   `Arc<ReadSnapshot>` (one brief reader-lock clone) and computes
//!   entirely off it. Node ids are validated against the *snapshot's*
//!   node count, so a read racing a node insertion yields a typed
//!   out-of-range error, never a torn result.
//! * **Staleness bound.** A predict admitted at time *t* reflects at
//!   least the last write batch whose ack completed before *t* —
//!   i.e. staleness is bounded by one in-flight write batch. Readers
//!   pinned to an old snapshot (long solves) keep it alive via `Arc`
//!   refcounts and never block writers from publishing newer ones.
//! * **RNG determinism.** Each predict draws its rng as
//!   `rng_base.split(0xBA7C).split(rng_seq)` where `rng_base` is the
//!   server rng frozen at publish time and `rng_seq` (echoed in the
//!   response) is a global monotone counter. Identical traffic is
//!   reproducible from `(graph_version, rng_seq)` pairs, read volume
//!   no longer perturbs the write-side rng stream, and the direct
//!   handler path and the batcher compute predictions through the
//!   **same** implementation ([`predict_off_snapshot`]).
//!
//! ## Sharding topology (`--shards S`)
//!
//! With `S > 1` the graph's nodes are partitioned across `S` shard
//! workers by the pure round-robin rule `owner(i) = i mod S`
//! ([`crate::shard::Partition`]) — balanced under `add_node` growth and
//! derivable from the id alone, so routing needs no lookup table.
//!
//! * **Partitioned maintenance.** Each shard owns the feature rows of
//!   its nodes: its own walk store, visit index, and delta overlay
//!   over row-partitioned component bases. A validated write batch
//!   fans out to all shards; each resamples only the *owned* walks the
//!   batch invalidated and patches only its own Φ/Φᵀ rows, in
//!   parallel ([`crate::shard::ShardedFeatures::apply_delta_batch`]).
//! * **Cross-shard edge invalidation.** An edge delta `{u, v}` is
//!   routed by *walk-source* ownership, not endpoint ownership: a walk
//!   started at shard A's node that visited `u` lives in shard A's
//!   visit index, so each shard discovers its own invalidations from
//!   its replica of the graph — no shard asks another what to resample
//!   (walk seeds are a pure function of `(seed, node, walk)`).
//! * **Snapshot composition invariant.** The write path joins every
//!   shard worker *before* the model rows are patched and the
//!   [`snapshot::ReadSnapshot`] is published, so a snapshot can never
//!   mix two generations of per-shard state: one `graph_version`
//!   stamps all rows, and ack-implies-published holds exactly as in
//!   the mono path. Predicts stay wait-free and never acquire the
//!   model lock, sharded or not.
//! * **Bitwise contract.** Φ, Φᵀ, predictions, and `graph_version`
//!   stamps are bit-identical to the unsharded engine for every shard
//!   count (enforced by `tests/shard.rs` across S ∈ {2,4,7}, hub-cap
//!   saturation, and forced compactions). Per-shard compaction
//!   cadences and overlay occupancy legitimately differ — those are
//!   observability-only.

pub mod batcher;
pub mod snapshot;
pub mod wire;

use crate::gp::model::GpModel;
use crate::gp::Hypers;
use crate::obs;
use crate::shard::{FeatureEngine, ShardedFeatures};
use crate::stream::{GraphDelta, StreamingFeatures};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::walks::Termination;
use anyhow::{Context, Result};
use batcher::{Batcher, Request, Response};
use snapshot::{ReadSnapshot, SnapshotCell};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, TryLockError};
use std::time::{Duration, Instant};
use wire::{ErrorKind, WireConfig, WireDecoder, WireError};

/// Serving-edge limits and policies (see the module-level "Limits &
/// failure modes" section for how each behaves when hit).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Per-connection frame/parse limits.
    pub wire: WireConfig,
    /// Cap on concurrently served connections; excess connects receive
    /// one `overload` line and are closed.
    pub max_connections: usize,
    /// Socket read timeout — the poll granularity at which idle client
    /// threads notice shutdown and the idle deadline. Smaller = faster
    /// shutdown, more wakeups.
    pub read_timeout: Duration,
    /// Close a connection that completes no frame for this long.
    pub idle_timeout: Duration,
    /// Cap on blocking writes to a slow-reading client.
    pub write_timeout: Duration,
    /// Enable the test-only `{"op":"fault"}` panic op (off by default;
    /// the fault-injection suite turns it on to prove panic isolation).
    pub fault_injection: bool,
    /// Micro-batching width: how many compatible requests the batcher
    /// merges into one engine call (`--max-batch` on `grfgp serve`).
    pub max_batch: usize,
    /// Log a structured one-line JSON record to stderr for any request
    /// slower than this many milliseconds (`--slow-request-ms`;
    /// 0 disables the log, which is the default).
    pub slow_request_ms: u64,
    /// Feature-maintenance shard count (`--shards`; 1 = the mono
    /// engine). See the module-level "Sharding topology" section.
    pub shards: usize,
    /// Optional plaintext-HTTP metrics listener address
    /// (`--metrics-addr`): answers `GET /metrics` with the Prometheus
    /// text rendering so a stock scraper needs no JSON shim. `None`
    /// (default) binds nothing.
    pub metrics_addr: Option<String>,
    /// p99 latency alert rules (`--alert-p99-ms op=ms,...`), evaluated
    /// at every metrics scrape — wire op and HTTP listener alike (see
    /// [`crate::obs::alerts`]).
    pub alerts: Vec<obs::alerts::AlertRule>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            wire: WireConfig::default(),
            max_connections: 256,
            read_timeout: Duration::from_millis(250),
            idle_timeout: Duration::from_secs(600),
            write_timeout: Duration::from_secs(30),
            fault_injection: false,
            max_batch: 8,
            slow_request_ms: 0,
            shards: 1,
            metrics_addr: None,
            alerts: Vec::new(),
        }
    }
}

/// Server shared state.
pub struct ServerState {
    pub model: Mutex<ModelState>,
    pub requests_served: AtomicU64,
    /// Bumped once per applied graph delta; predictions are stamped
    /// with the version they were computed under.
    pub graph_version: AtomicU64,
    /// Monotone node count mirror (updated under the model lock) — lets
    /// request validation run without contending on the model mutex.
    pub n_nodes: AtomicUsize,
    pub shutdown: AtomicBool,
    /// Live connection count, against `config.max_connections`.
    pub active_connections: AtomicUsize,
    /// The published read snapshot `predict` computes off — see the
    /// module-level "Concurrency & snapshot semantics" section.
    pub snapshots: SnapshotCell,
    /// Global predict sequence counter: each predict engine call takes
    /// one value, derives its rng from it, and echoes it (`rng_seq`).
    pub predict_seq: AtomicU64,
    /// Lifetime count of model-mutex acquisitions — observability for
    /// the wait-free-read contract (predicts must not move it).
    pub model_lock_acquisitions: AtomicU64,
    /// Monotone dispatch counter feeding `trace_id` (one value per
    /// decoded frame; see the module-level "Observability" section).
    pub trace_seq: AtomicU64,
    pub config: ServerConfig,
}

impl ServerState {
    /// Build the shared state and publish the initial read snapshot
    /// (publication 0), so a predict arriving before the first write
    /// already finds one.
    pub fn new(ms: ModelState, config: ServerConfig) -> ServerState {
        let n0 = ms.model.n();
        let first = ms.snapshot(0);
        ServerState {
            model: Mutex::new(ms),
            requests_served: AtomicU64::new(0),
            graph_version: AtomicU64::new(0),
            n_nodes: AtomicUsize::new(n0),
            shutdown: AtomicBool::new(false),
            active_connections: AtomicUsize::new(0),
            snapshots: SnapshotCell::new(first),
            predict_seq: AtomicU64::new(0),
            model_lock_acquisitions: AtomicU64::new(0),
            trace_seq: AtomicU64::new(0),
            config,
        }
    }

    /// Model lock with poison recovery. A panicking handler must not
    /// turn every subsequent request into a poison panic: the panic
    /// already surfaced as an `internal` error on its own connection,
    /// and the model invariants the handlers rely on (vector lengths,
    /// version mirrors) are re-established at the start of each write,
    /// so serving continues on whatever state the handler left.
    pub fn model_guard(&self) -> MutexGuard<'_, ModelState> {
        self.model_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.model.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Non-blocking variant of [`ServerState::model_guard`]; `None`
    /// only when the lock is genuinely contended.
    pub fn try_model_guard(&self) -> Option<MutexGuard<'_, ModelState>> {
        match self.model.try_lock() {
            Ok(g) => {
                self.model_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
                Some(g)
            }
            Err(TryLockError::Poisoned(p)) => {
                self.model_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
                Some(p.into_inner())
            }
            Err(TryLockError::WouldBlock) => None,
        }
    }
}

/// The mutable model + data the workers operate on.
pub struct ModelState {
    pub model: GpModel,
    /// Incrementally maintained walk/feature state of the served graph
    /// — the mono engine, or the partitioned fan-out behind `--shards`
    /// (bitwise interchangeable; see [`crate::shard`]).
    pub stream: FeatureEngine,
    pub observations: Vec<(usize, f64)>,
    pub rng: Rng,
    /// Posterior-mean solve carried across graph deltas — the warm
    /// start for the next delta's re-solve.
    pub alpha: Option<Vec<f64>>,
}

impl ModelState {
    /// Build the served model from the streaming state (the model's
    /// components are the stream's, so deltas patch consistently).
    pub fn new(stream: StreamingFeatures, hypers: Hypers, seed: u64) -> ModelState {
        ModelState::with_engine(FeatureEngine::Mono(stream), hypers, seed)
    }

    /// [`ModelState::new`] over a partitioned engine: the graph's nodes
    /// are round-robin-owned by `n_shards` workers that each maintain
    /// their own rows of the feature state; the model's Φ/Φᵀ operands
    /// adopt the same partition ([`GpModel::set_sharding`]). With
    /// `n_shards <= 1` this is exactly [`ModelState::new`].
    pub fn new_sharded(
        stream: StreamingFeatures,
        hypers: Hypers,
        seed: u64,
        n_shards: usize,
    ) -> ModelState {
        if n_shards <= 1 {
            return ModelState::new(stream, hypers, seed);
        }
        let sharded = ShardedFeatures::new(
            stream.graph().clone(),
            stream.config().clone(),
            stream.modulation().to_vec(),
            stream.seed(),
            n_shards,
        );
        ModelState::with_engine(FeatureEngine::Sharded(sharded), hypers, seed)
    }

    /// Build the served model over an explicit maintenance engine. The
    /// model's components are the engine's — and its operand storage
    /// follows the engine's node partition — so deltas patch both
    /// consistently in either mode.
    pub fn with_engine(engine: FeatureEngine, hypers: Hypers, seed: u64) -> ModelState {
        let mut model = GpModel::new(engine.components(), hypers, &[], &[]);
        model.set_sharding(engine.partition());
        ModelState {
            model,
            stream: engine,
            observations: Vec::new(),
            rng: Rng::new(seed),
            alpha: None,
        }
    }

    fn refresh(&mut self) {
        let nodes: Vec<usize> =
            self.observations.iter().map(|(i, _)| *i).collect();
        let ys: Vec<f64> = self.observations.iter().map(|(_, v)| *v).collect();
        self.model.set_data(&nodes, &ys);
    }

    /// Freeze the current state into an immutable [`ReadSnapshot`]
    /// stamped with `graph_version`. O(overlay rows + n): the Φ/Φᵀ
    /// compacted bases and packed ELL operands are `Arc`-shared with
    /// the live model ([`GpModel::read_view`]).
    pub fn snapshot(&self, graph_version: u64) -> ReadSnapshot {
        ReadSnapshot {
            view: self.model.read_view(),
            graph_version,
            n_nodes: self.model.n(),
            n_obs: self.observations.len(),
            compactions: self.stream.compactions(),
            shards: self.stream.n_shards(),
            publish_seq: 0,
            rng_base: self.rng.clone(),
            published_at: Instant::now(),
        }
    }

    /// Apply one coalesced write batch (observes + graph deltas) in
    /// arrival order under the already-held model lock. Runs of
    /// observations flush with a single `set_data` (before the next
    /// delta run, so its warm re-solve sees them; at the end
    /// otherwise); **runs of consecutive graph deltas coalesce into one
    /// engine call** ([`GpModel::apply_graph_delta_batch`]: one union
    /// feature patch + one warm re-solve), with every delta still
    /// acked under its own monotone `graph_version`.
    pub fn apply_writes(
        &mut self,
        reqs: &[Request],
        state: &ServerState,
    ) -> Vec<Response> {
        fn as_delta(req: &Request) -> Option<GraphDelta> {
            match req {
                Request::AddEdge { u, v, w } => {
                    Some(GraphDelta::AddEdge { u: *u, v: *v, w: *w })
                }
                Request::RemoveEdge { u, v } => {
                    Some(GraphDelta::RemoveEdge { u: *u, v: *v })
                }
                Request::AddNode => Some(GraphDelta::AddNode),
                _ => None,
            }
        }
        let mut out = Vec::with_capacity(reqs.len());
        let mut dirty_obs = false;
        let mut i = 0;
        while i < reqs.len() {
            if as_delta(&reqs[i]).is_some() {
                // Coalesce the run of consecutive graph deltas.
                let mut run = Vec::new();
                while i < reqs.len() {
                    match as_delta(&reqs[i]) {
                        Some(d) => {
                            run.push(d);
                            i += 1;
                        }
                        None => break,
                    }
                }
                if dirty_obs {
                    // Flush pending observations first so the batch's
                    // warm re-solve sees them.
                    self.refresh();
                    dirty_obs = false;
                }
                out.extend(self.apply_delta_run(&run, state));
                continue;
            }
            match &reqs[i] {
                Request::Observe { node, y } => {
                    if *node >= self.model.n() {
                        out.push(Response::error(format!(
                            "node {node} out of range"
                        )));
                    } else {
                        self.observations.push((*node, *y));
                        dirty_obs = true;
                        out.push(Response::ok(vec![(
                            "n_obs",
                            Json::from_uint(self.observations.len() as u64),
                        )]));
                    }
                }
                other => out.push(Response::error(format!(
                    "non-write request {other:?} in write batch"
                ))),
            }
            i += 1;
        }
        if dirty_obs {
            self.refresh();
        }
        // Publication point: swap in a snapshot reflecting everything
        // this batch applied, *before* the acks above are delivered —
        // so a client that saw `graph_version = k` acknowledged can
        // immediately read a prediction stamped `>= k`. The span covers
        // build + swap: the full publish latency writers pay.
        let publish_span =
            obs::span::Span::new(&obs::registry::SNAPSHOT_PUBLISH_NS);
        state.snapshots.publish(
            self.snapshot(state.graph_version.load(Ordering::SeqCst)),
        );
        publish_span.stop();
        out
    }

    /// Apply a coalesced run of graph deltas: one batched engine call,
    /// one monotone `graph_version` per delta on the acks. A batch that
    /// fails up-front validation mutated nothing, so it falls back to
    /// per-delta application for per-request error granularity (the
    /// valid deltas still apply, the invalid one gets its own error).
    fn apply_delta_run(
        &mut self,
        deltas: &[GraphDelta],
        state: &ServerState,
    ) -> Vec<Response> {
        if deltas.len() == 1 {
            return vec![self.apply_delta(&deltas[0], state)];
        }
        let warm = self.alpha.take();
        match self.model.apply_graph_delta_batch(
            &mut self.stream,
            deltas,
            warm.as_deref(),
        ) {
            Ok(out) => {
                let k = deltas.len() as u64;
                let base = state.graph_version.fetch_add(k, Ordering::SeqCst);
                state.n_nodes.store(self.model.n(), Ordering::SeqCst);
                self.alpha = Some(out.alpha);
                out.deltas
                    .iter()
                    .enumerate()
                    .map(|(idx, ack)| {
                        delta_ack(
                            base + 1 + idx as u64,
                            out.resampled_walks,
                            ack.invalidated,
                            out.patched_rows,
                            out.solve_stats.iterations,
                            deltas.len(),
                            out.compacted,
                            ack.added_node,
                        )
                    })
                    .collect()
            }
            Err(_) => {
                // Validation failed before any mutation: state is
                // untouched, re-apply one-by-one so each request gets
                // its own result.
                self.alpha = warm;
                deltas
                    .iter()
                    .map(|d| self.apply_delta(d, state))
                    .collect()
            }
        }
    }

    fn apply_delta(&mut self, delta: &GraphDelta, state: &ServerState) -> Response {
        let warm = self.alpha.take();
        match self.model.apply_graph_delta(
            &mut self.stream,
            delta,
            warm.as_deref(),
        ) {
            Ok(outcome) => {
                let version =
                    state.graph_version.fetch_add(1, Ordering::SeqCst) + 1;
                state.n_nodes.store(self.model.n(), Ordering::SeqCst);
                let resp = delta_ack(
                    version,
                    outcome.resampled_walks,
                    outcome.resampled_walks,
                    outcome.patched_rows,
                    outcome.solve_stats.iterations,
                    1,
                    outcome.compacted,
                    outcome.added_node,
                );
                self.alpha = Some(outcome.alpha);
                resp
            }
            Err(e) => {
                // A failed delta did not change the graph; the taken
                // warm start is still valid for the next one.
                self.alpha = warm;
                Response::error(e)
            }
        }
    }
}

/// Shared ack shape for graph deltas, single or coalesced — both paths
/// build through here so the fields cannot drift:
/// * `resampled_walks` keeps its per-delta identity from the original
///   protocol: the size of **this** delta's invalidation set (what a
///   sequential application would have re-run), so clients summing it
///   across their acks keep getting per-delta costs;
/// * `batch_resampled_walks` — walks actually re-run by the engine
///   call this delta coalesced into (the union; equals
///   `resampled_walks` when `batched` is 1);
/// * `patched_rows` / `cg_iters` / `compacted` are engine-call level
///   and shared by the `batched` acks of one call — they cannot be
///   attributed per delta.
#[allow(clippy::too_many_arguments)]
fn delta_ack(
    version: u64,
    batch_resampled: usize,
    invalidated: usize,
    patched_rows: usize,
    cg_iters: usize,
    batched: usize,
    compacted: bool,
    node: Option<usize>,
) -> Response {
    let mut fields = vec![
        ("graph_version", Json::from_uint(version)),
        ("resampled_walks", Json::from_uint(invalidated as u64)),
        (
            "batch_resampled_walks",
            Json::from_uint(batch_resampled as u64),
        ),
        ("patched_rows", Json::from_uint(patched_rows as u64)),
        ("cg_iters", Json::from_uint(cg_iters as u64)),
        ("batched", Json::from_uint(batched as u64)),
        ("compacted", Json::Bool(compacted)),
    ];
    if let Some(id) = node {
        fields.push(("node", Json::from_uint(id as u64)));
    }
    Response::ok(fields)
}

/// One wait-free prediction engine call: load the latest published
/// snapshot, take a fresh `rng_seq`, and compute full mean/variance
/// vectors off the snapshot. **Never touches the model mutex.** Both
/// the direct handler path ([`handle`]) and the batcher's leader
/// ([`batcher::Batcher`]) come through here, so the two entry points
/// are one implementation.
///
/// Returns `(snapshot, mean, var, rng_seq)`; callers validate node ids
/// against `snapshot.n_nodes` (not the live mirror — the mirror may
/// already exceed a not-yet-published insertion) and gather their
/// requested nodes out of the full vectors.
pub fn predict_off_snapshot(
    state: &ServerState,
    samples: usize,
) -> (Arc<ReadSnapshot>, Vec<f64>, Vec<f64>, u64) {
    let snap = state.snapshots.load();
    // Predict-vs-publish lag: how stale the snapshot this predict
    // computes off is. Atomics only — the path stays wait-free (and
    // skips even the clock read when telemetry is off).
    if obs::enabled() {
        obs::registry::PREDICT_SNAPSHOT_LAG_NS
            .record_duration(snap.published_at.elapsed());
    }
    let seq = state.predict_seq.fetch_add(1, Ordering::SeqCst);
    let mut rng = snap.predict_rng(seq);
    let (mean, var) = snap.view.predict(samples, &mut rng);
    (snap, mean, var, seq)
}

/// Reject a posterior sample containing NaN (a numerically failed
/// solve) with a typed `internal` error instead of letting a NaN
/// comparison panic the handler.
fn nan_guard(sample: &[f64], op: &str) -> Option<Response> {
    if sample.iter().any(|v| v.is_nan()) {
        Some(Response::fault(
            ErrorKind::Internal,
            format!(
                "{op}: posterior sample contains NaN \
                 (numerically failed solve); cannot rank nodes"
            ),
        ))
    } else {
        None
    }
}

/// Handle one already-parsed request against the state. Write requests
/// run as a single-element write batch (the batcher coalesces longer
/// ones).
pub fn handle(state: &ServerState, req: &Request) -> Response {
    state.requests_served.fetch_add(1, Ordering::Relaxed);
    match req {
        Request::Observe { .. }
        | Request::AddEdge { .. }
        | Request::RemoveEdge { .. }
        | Request::AddNode => {
            let mut ms = state.model_guard();
            ms.apply_writes(std::slice::from_ref(req), state)
                .pop()
                .expect("one response per write")
        }
        Request::Predict { nodes, samples } => {
            // Wait-free: computed entirely off the published snapshot,
            // through the same implementation the batcher uses.
            let (snap, mean, var, seq) = predict_off_snapshot(state, *samples);
            if let Some(&bad) = nodes.iter().find(|&&n| n >= snap.n_nodes) {
                return Response::error(format!("node {bad} out of range"));
            }
            let mu: Vec<f64> = nodes.iter().map(|&i| mean[i]).collect();
            let vv: Vec<f64> = nodes.iter().map(|&i| var[i]).collect();
            batcher::predict_response(&mu, &vv, 1, snap.graph_version, seq)
        }
        Request::Sample => {
            let mut ms = state.model_guard();
            let mut rng = ms.rng.split(0x5A);
            ms.rng = ms.rng.split(1); // advance server stream
            let s = ms.model.posterior_sample(&mut rng);
            if let Some(err) = nan_guard(&s, "sample") {
                return err;
            }
            let (argmax, max) = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, v)| (i, *v))
                .expect("posterior sample is non-empty");
            Response::ok(vec![
                ("argmax", Json::from_uint(argmax as u64)),
                ("max", Json::Num(max)),
            ])
        }
        Request::Thompson => {
            let mut ms = state.model_guard();
            let mut rng = ms.rng.split(0x7A);
            ms.rng = ms.rng.split(2);
            let s = ms.model.posterior_sample(&mut rng);
            if let Some(err) = nan_guard(&s, "thompson") {
                return err;
            }
            let queried: std::collections::HashSet<usize> =
                ms.observations.iter().map(|(i, _)| *i).collect();
            match s
                .iter()
                .enumerate()
                .filter(|(i, _)| !queried.contains(i))
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
            {
                Some(next) => Response::ok(vec![
                    ("next", Json::from_uint(next as u64)),
                    ("exhausted", Json::Bool(false)),
                ]),
                // Every node has been queried: say so instead of
                // silently recommending node 0 again.
                None => Response::ok(vec![("exhausted", Json::Bool(true))]),
            }
        }
        Request::Stats => {
            let ms = state.model_guard();
            Response::ok(vec![
                ("n_nodes", Json::from_uint(ms.model.n() as u64)),
                (
                    "n_edges",
                    Json::from_uint(ms.stream.graph().num_edges() as u64),
                ),
                ("n_obs", Json::from_uint(ms.observations.len() as u64)),
                (
                    "graph_version",
                    Json::from_uint(state.graph_version.load(Ordering::SeqCst)),
                ),
                (
                    "deltas_applied",
                    Json::from_uint(ms.stream.deltas_applied() as u64),
                ),
                (
                    "walks_resampled",
                    Json::from_uint(ms.stream.walks_resampled_total() as u64),
                ),
                (
                    "shards",
                    Json::from_uint(ms.stream.n_shards() as u64),
                ),
                (
                    "overlay_rows",
                    Json::from_uint(ms.stream.overlay_rows() as u64),
                ),
                (
                    "hub_fallback_nodes",
                    Json::from_uint(ms.stream.saturated_hubs() as u64),
                ),
                (
                    "requests",
                    Json::from_uint(
                        state.requests_served.load(Ordering::Relaxed),
                    ),
                ),
                (
                    "published_snapshots",
                    Json::from_uint(state.snapshots.published()),
                ),
                (
                    "predicts_served",
                    Json::from_uint(state.predict_seq.load(Ordering::SeqCst)),
                ),
            ])
        }
        Request::Metrics { prometheus } => {
            // Lock-free by contract (unlike `stats`): the registry and
            // the server counters below are all atomics, so a scrape
            // can never contend with serving. The no-torn-reads
            // guarantee is per-histogram (count == Σ buckets from one
            // bucket read); see the obs module docs.
            // Scrape time is also alert time: every configured p99
            // rule is checked against the live histograms (atomics
            // only — the path stays lock-free).
            obs::alerts::evaluate(&state.config.alerts);
            if *prometheus {
                return Response::ok(vec![
                    ("format", Json::Str("prometheus".to_string())),
                    ("text", Json::Str(obs::prom::render())),
                ]);
            }
            let server = Json::obj(vec![
                (
                    "requests",
                    Json::from_uint(
                        state.requests_served.load(Ordering::Relaxed),
                    ),
                ),
                (
                    "graph_version",
                    Json::from_uint(state.graph_version.load(Ordering::SeqCst)),
                ),
                (
                    "published_snapshots",
                    Json::from_uint(state.snapshots.published()),
                ),
                (
                    "predicts_served",
                    Json::from_uint(state.predict_seq.load(Ordering::SeqCst)),
                ),
                (
                    "model_lock_acquisitions",
                    Json::from_uint(
                        state.model_lock_acquisitions.load(Ordering::SeqCst),
                    ),
                ),
                (
                    "active_connections",
                    Json::from_uint(
                        state.active_connections.load(Ordering::SeqCst) as u64,
                    ),
                ),
                (
                    "n_nodes",
                    Json::from_uint(state.n_nodes.load(Ordering::SeqCst) as u64),
                ),
                ("telemetry_enabled", Json::Bool(obs::enabled())),
            ]);
            Response::ok(vec![
                ("metrics", obs::registry::to_json()),
                ("server", server),
            ])
        }
        Request::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            Response::ok(vec![("bye", Json::Bool(true))])
        }
        Request::Fault { locked } => {
            if !state.config.fault_injection {
                return Response::error(
                    "fault injection is disabled on this server",
                );
            }
            if *locked {
                // Poison the model mutex mid-panic: the suite proves
                // other clients recover the lock and keep serving.
                let _ms = state.model_guard();
                panic!("injected fault while holding the model lock");
            }
            panic!("injected fault");
        }
    }
}

/// Decrements the live-connection count on every exit path (normal
/// EOF, error return, or a panic escaping `catch_unwind`'s closure).
struct ConnGuard<'a>(&'a ServerState);

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.active_connections.fetch_sub(1, Ordering::SeqCst);
    }
}

fn write_response(writer: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let mut line = resp.to_json().to_string();
    line.push('\n');
    writer.write_all(line.as_bytes())
}

/// Stamp a `trace_id` onto a response (one monotone dispatch sequence
/// value per decoded frame, prefixed with the current graph version —
/// see the module-level "Observability" section) and return the id.
fn stamp_trace(state: &ServerState, resp: &mut Response) -> String {
    let seq = state.trace_seq.fetch_add(1, Ordering::Relaxed);
    let gv = state.graph_version.load(Ordering::SeqCst);
    let id = format!("{gv:x}-{seq:x}");
    resp.fields
        .push(("trace_id".to_string(), Json::Str(id.clone())));
    id
}

/// The structured single-line record logged (to stderr) for a request
/// slower than `slow_request_ms`. Split out so the shape is unit
/// testable: one JSON object, keyed by the same `trace_id` the client
/// received.
pub fn slow_request_record(
    op: &str,
    elapsed: Duration,
    trace_id: &str,
    resp: &Response,
) -> Json {
    let error_kind = resp
        .fields
        .iter()
        .find(|(k, _)| k == "error_kind")
        .and_then(|(_, v)| v.as_str())
        .unwrap_or("");
    Json::obj(vec![
        ("slow_request", Json::Bool(true)),
        ("op", Json::Str(op.to_string())),
        ("ms", Json::Num(elapsed.as_secs_f64() * 1e3)),
        ("ok", Json::Bool(resp.ok)),
        ("error_kind", Json::Str(error_kind.to_string())),
        ("trace_id", Json::Str(trace_id.to_string())),
    ])
}

/// Per-request telemetry epilogue shared by every decoded frame: per-op
/// counter + latency histogram, `error_kind` counters, `trace_id`
/// stamping, and the slow-request outlier log.
fn finish_request(
    state: &ServerState,
    op: &str,
    started: Instant,
    mut resp: Response,
) -> Response {
    let elapsed = started.elapsed();
    if let Some((count, latency)) = obs::registry::request_metrics(op) {
        count.inc();
        latency.record_duration(elapsed);
    }
    if !resp.ok {
        let kind = resp
            .fields
            .iter()
            .find(|(k, _)| k == "error_kind")
            .and_then(|(_, v)| v.as_str());
        if let Some(c) = kind.and_then(obs::registry::error_counter) {
            c.inc();
        }
    }
    let trace_id = stamp_trace(state, &mut resp);
    let threshold = state.config.slow_request_ms;
    if threshold > 0 && elapsed >= Duration::from_millis(threshold) {
        obs::registry::SLOW_REQUESTS.inc();
        let line = slow_request_record(op, elapsed, &trace_id, &resp).to_string();
        eprintln!("{line}");
    }
    resp
}

/// Run one decoded frame to a response. Handler panics are caught here
/// and become `internal` errors — one poisoned request must not tear
/// down the connection thread (and through `thread::scope`, the whole
/// server). `AssertUnwindSafe` is justified by the poison-recovering
/// lock discipline documented on [`ServerState::model_guard`].
fn dispatch(state: &ServerState, batcher: &Batcher, frame: &Json) -> Response {
    let started = Instant::now();
    let req = match Request::from_json(frame) {
        Ok(req) => req,
        Err(e) => {
            // Unparsable request: no per-op metrics (the op may be
            // unknown), but the error-kind counter and trace id still
            // apply.
            return finish_request(state, "", started, Response::error(e));
        }
    };
    let op = req.op_name();
    let submitted = catch_unwind(AssertUnwindSafe(|| batcher.submit(state, req)));
    let resp = match submitted {
        Ok(resp) => resp,
        Err(payload) => {
            let what = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Response::fault(ErrorKind::Internal, format!("handler panicked: {what}"))
        }
    };
    finish_request(state, op, started, resp)
}

/// Per-connection loop: raw timed reads feed the bounded streaming
/// decoder; each complete frame gets exactly one reply line. The read
/// timeout doubles as the shutdown/idle poll, so an idle peer cannot
/// hold this thread past shutdown (the old `BufReader::lines` loop
/// blocked forever there).
fn client_loop(
    mut stream: TcpStream,
    state: &ServerState,
    batcher: &Batcher,
) -> Result<()> {
    let cfg = &state.config;
    stream
        .set_read_timeout(Some(cfg.read_timeout))
        .context("set read timeout")?;
    stream
        .set_write_timeout(Some(cfg.write_timeout))
        .context("set write timeout")?;
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut decoder = WireDecoder::new(cfg.wire.clone());
    let mut chunk = vec![0u8; 16 * 1024];
    let mut frames: Vec<std::result::Result<Json, WireError>> = Vec::new();
    let mut last_frame = Instant::now();
    'conn: loop {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let k = match stream.read(&mut chunk) {
            // EOF: a partial frame at disconnect is dropped silently
            // (there is no one left to send the error to).
            Ok(0) => break,
            Ok(k) => k,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Read-timeout tick: re-check shutdown (top of loop)
                // and the idle deadline.
                if last_frame.elapsed() >= cfg.idle_timeout {
                    let _ = write_response(
                        &mut writer,
                        &Response::error("closing idle connection"),
                    );
                    break;
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        frames.clear();
        decoder.feed(&chunk[..k], &mut frames);
        if !frames.is_empty() {
            // Completed frames (even erroneous ones) count as progress;
            // trickling bytes without ever finishing a frame does not.
            last_frame = Instant::now();
        }
        for frame in frames.drain(..) {
            let resp = match frame {
                Ok(json) => dispatch(state, batcher, &json),
                Err(we) => {
                    // Wire-layer rejects (bad JSON, oversized frame)
                    // never reach `dispatch`, so they are accounted —
                    // and trace-stamped — here.
                    if let Some(c) =
                        obs::registry::error_counter(we.kind.as_str())
                    {
                        c.inc();
                    }
                    let mut resp = Response::fault(we.kind, we.msg);
                    stamp_trace(state, &mut resp);
                    resp
                }
            };
            write_response(&mut writer, &resp)?;
            if state.shutdown.load(Ordering::SeqCst) {
                break 'conn;
            }
        }
    }
    Ok(())
}

/// Minimal, dependency-free HTTP exposition endpoint (`--metrics-addr`):
/// answers `GET /metrics` with the Prometheus text rendering
/// ([`crate::obs::prom::render`]) so a stock scraper can pull the
/// registry without speaking the JSON wire protocol. One request per
/// connection (`Connection: close`); reads/writes are bounded by the
/// server's timeouts; every scrape also evaluates the configured p99
/// alert rules ([`crate::obs::alerts`]). Polls shutdown on the accept
/// loop, so it drains with the rest of the server.
fn serve_metrics_http(listener: TcpListener, state: &ServerState) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let mut conn = match listener.accept() {
            Ok((c, _)) => c,
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            Err(_) => return,
        };
        let _ = conn.set_nonblocking(false);
        let _ = conn.set_read_timeout(Some(state.config.read_timeout));
        let _ = conn.set_write_timeout(Some(state.config.write_timeout));
        // One bounded read is enough to route: the request line fits
        // the head buffer, and nothing after it changes the answer.
        let mut head = [0u8; 1024];
        let k = match conn.read(&mut head) {
            Ok(k) => k,
            Err(_) => continue,
        };
        let line = String::from_utf8_lossy(&head[..k]);
        let target = line.split_whitespace().nth(1).unwrap_or("");
        let routed = line.starts_with("GET ")
            && (target == "/metrics" || target.starts_with("/metrics?"));
        let (status, body) = if routed {
            obs::alerts::evaluate(&state.config.alerts);
            ("200 OK", obs::prom::render())
        } else {
            ("404 Not Found", "only GET /metrics is served here\n".to_string())
        };
        let resp = format!(
            "HTTP/1.0 {status}\r\n\
             Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len(),
        );
        let _ = conn.write_all(resp.as_bytes());
    }
}

/// One builder for every way to start the server — listen address,
/// serving-edge [`ServerConfig`] (shards, metrics listener, alert
/// rules, slow-request threshold, wire limits), model seed, and the
/// walk-[`Termination`] scheme — replacing the old
/// `serve`/`serve_with`/`serve_on`/`serve_on_with` family (kept as
/// deprecated shims).
///
/// ```no_run
/// use grfgp::gp::{Hypers, Modulation};
/// use grfgp::graph::generators;
/// use grfgp::server::ServeOptions;
/// use grfgp::stream::StreamingFeatures;
/// use grfgp::walks::{Termination, WalkConfig};
///
/// let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 10), 0.1);
/// let stream = StreamingFeatures::new(
///     generators::ring(512),
///     WalkConfig::default(),
///     hypers.modulation.coeffs(),
///     0,
/// );
/// ServeOptions::new()
///     .addr("127.0.0.1:7701")
///     .shards(4)
///     .termination(Termination::Qmc)
///     .serve(stream, hypers)
///     .unwrap();
/// ```
///
/// Tests that bind port 0 themselves hand the bound listener to
/// [`ServeOptions::serve_on`] instead of [`ServeOptions::serve`].
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    addr: Option<String>,
    config: ServerConfig,
    seed: u64,
    termination: Option<Termination>,
}

impl ServeOptions {
    /// Defaults: `127.0.0.1:7701`, `ServerConfig::default()`, seed 0,
    /// and the termination scheme the stream was sampled with.
    pub fn new() -> ServeOptions {
        ServeOptions::default()
    }

    /// Listen address for [`ServeOptions::serve`] (default
    /// `127.0.0.1:7701`).
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.addr = Some(addr.into());
        self
    }

    /// Replace the whole serving-edge config (wire limits, timeouts,
    /// connection caps, ...). Knobs set *before* this call are
    /// overwritten; the dedicated setters below are sugar over the
    /// same struct, so order them after.
    pub fn config(mut self, config: ServerConfig) -> Self {
        self.config = config;
        self
    }

    /// Model/server RNG seed (default 0).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Feature-maintenance shard count (`--shards`; 1 = mono engine).
    pub fn shards(mut self, shards: usize) -> Self {
        self.config.shards = shards;
        self
    }

    /// Plain-HTTP Prometheus exposition listener (`--metrics-addr`).
    pub fn metrics_addr(mut self, addr: impl Into<String>) -> Self {
        self.config.metrics_addr = Some(addr.into());
        self
    }

    /// p99 latency alert rules, evaluated at scrape time
    /// (`--alert-p99-ms`).
    pub fn alerts(mut self, rules: Vec<obs::alerts::AlertRule>) -> Self {
        self.config.alerts = rules;
        self
    }

    /// Slow-request outlier log threshold in ms (`--slow-request-ms`;
    /// 0 = off).
    pub fn slow_request_ms(mut self, ms: u64) -> Self {
        self.config.slow_request_ms = ms;
        self
    }

    /// Walk-termination scheme for the served feature state
    /// (`--termination`). When it differs from the scheme the handed-in
    /// stream was sampled under, the stream is resampled once at
    /// startup; unset leaves the stream as built.
    pub fn termination(mut self, scheme: Termination) -> Self {
        self.termination = Some(scheme);
        self
    }

    /// Bind the configured address and serve until shutdown.
    pub fn serve(self, stream: StreamingFeatures, hypers: Hypers) -> Result<()> {
        let addr = self.addr.clone().unwrap_or_else(|| "127.0.0.1:7701".into());
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        eprintln!("grfgp server listening on {local}");
        self.serve_on(stream, hypers, listener)
    }

    /// Serve on an already-bound listener (tests bind port 0
    /// themselves) until a shutdown request arrives. The GP model is
    /// built from the stream's components, so graph deltas patch both
    /// consistently.
    pub fn serve_on(
        self,
        stream: StreamingFeatures,
        hypers: Hypers,
        listener: TcpListener,
    ) -> Result<()> {
        let stream = apply_termination_override(stream, self.termination);
        serve_inner(stream, hypers, listener, self.seed, self.config)
    }
}

/// Resample the feature state under `scheme` when it differs from the
/// one the stream was built with (`None` / matching scheme: handed
/// back untouched). One startup-time rebuild, same graph / modulation
/// / seed.
fn apply_termination_override(
    stream: StreamingFeatures,
    scheme: Option<Termination>,
) -> StreamingFeatures {
    match scheme {
        Some(term) if stream.config().termination != term => {
            let mut cfg = stream.config().clone();
            cfg.termination = term;
            StreamingFeatures::new(
                stream.graph().clone(),
                cfg,
                stream.modulation().to_vec(),
                stream.seed(),
            )
        }
        _ => stream,
    }
}

/// Serve the streaming state on `addr` until a shutdown request
/// arrives.
#[deprecated(note = "use ServeOptions::new().addr(..).seed(..).serve(..)")]
pub fn serve(
    stream: StreamingFeatures,
    hypers: Hypers,
    addr: &str,
    seed: u64,
) -> Result<()> {
    ServeOptions::new().addr(addr).seed(seed).serve(stream, hypers)
}

/// [`serve`] with explicit serving-edge limits.
#[deprecated(note = "use ServeOptions::new().addr(..).config(..).serve(..)")]
pub fn serve_with(
    stream: StreamingFeatures,
    hypers: Hypers,
    addr: &str,
    seed: u64,
    config: ServerConfig,
) -> Result<()> {
    ServeOptions::new()
        .addr(addr)
        .seed(seed)
        .config(config)
        .serve(stream, hypers)
}

/// Serve on an already-bound listener.
#[deprecated(note = "use ServeOptions::new().seed(..).serve_on(..)")]
pub fn serve_on(
    stream: StreamingFeatures,
    hypers: Hypers,
    listener: TcpListener,
    seed: u64,
) -> Result<()> {
    ServeOptions::new().seed(seed).serve_on(stream, hypers, listener)
}

/// [`serve_on`] with explicit serving-edge limits.
#[deprecated(note = "use ServeOptions::new().config(..).seed(..).serve_on(..)")]
pub fn serve_on_with(
    stream: StreamingFeatures,
    hypers: Hypers,
    listener: TcpListener,
    seed: u64,
    config: ServerConfig,
) -> Result<()> {
    ServeOptions::new()
        .config(config)
        .seed(seed)
        .serve_on(stream, hypers, listener)
}

/// The accept loop behind every [`ServeOptions`] entry.
fn serve_inner(
    stream: StreamingFeatures,
    hypers: Hypers,
    listener: TcpListener,
    seed: u64,
    config: ServerConfig,
) -> Result<()> {
    let ms = ModelState::new_sharded(stream, hypers, seed, config.shards);
    let max_batch = config.max_batch;
    let metrics_listener = match &config.metrics_addr {
        Some(addr) => {
            let l = TcpListener::bind(addr.as_str())
                .with_context(|| format!("bind metrics listener {addr}"))?;
            eprintln!(
                "grfgp metrics exposition on http://{}/metrics",
                l.local_addr()?
            );
            Some(l)
        }
        None => None,
    };
    let state = Arc::new(ServerState::new(ms, config));
    let batcher = Arc::new(Batcher::new(max_batch));
    listener.set_nonblocking(true)?;
    std::thread::scope(|scope| -> Result<()> {
        if let Some(ml) = metrics_listener {
            let st = state.clone();
            scope.spawn(move || serve_metrics_http(ml, &st));
        }
        loop {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    // Connection cap: answer with one typed busy line
                    // and close (drop) instead of serving. Only the
                    // accept loop increments the count, so load+add
                    // cannot race another admission.
                    let live = state.active_connections.load(Ordering::SeqCst);
                    if live >= state.config.max_connections {
                        obs::registry::ERR_OVERLOAD.inc();
                        let mut stream = stream;
                        let _ = stream
                            .set_write_timeout(Some(state.config.write_timeout));
                        let _ = write_response(
                            &mut stream,
                            &Response::fault(
                                ErrorKind::Overload,
                                format!(
                                    "server busy: connection cap {} reached",
                                    state.config.max_connections
                                ),
                            ),
                        );
                        continue;
                    }
                    state.active_connections.fetch_add(1, Ordering::SeqCst);
                    let st = state.clone();
                    let ba = batcher.clone();
                    scope.spawn(move || {
                        let _guard = ConnGuard(&st);
                        // Belt-and-braces: client_loop's dispatch already
                        // catches handler panics; this outer guard keeps
                        // any unexpected panic (decoder, IO plumbing)
                        // from propagating into `thread::scope` and
                        // aborting the whole server.
                        match catch_unwind(AssertUnwindSafe(|| {
                            client_loop(stream, &st, &ba)
                        })) {
                            Ok(Ok(())) => {}
                            Ok(Err(e)) => eprintln!("client error: {e:#}"),
                            Err(_) => {
                                eprintln!("client thread panicked (isolated)")
                            }
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::Modulation;
    use crate::graph::generators;
    use crate::walks::WalkConfig;

    fn small_stream(termination: Termination) -> StreamingFeatures {
        let cfg = WalkConfig {
            n_walks: 6,
            p_halt: 0.3,
            max_len: 3,
            threads: 1,
            termination,
            ..Default::default()
        };
        let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
        StreamingFeatures::new(
            generators::ring(24),
            cfg,
            hypers.modulation.coeffs(),
            5,
        )
    }

    /// The dedicated setters are sugar over `ServerConfig` — each one
    /// must land on the same field a hand-built config would set, and
    /// `config()` must replace the whole struct.
    #[test]
    fn serve_options_setters_write_through_to_config() {
        let opts = ServeOptions::new()
            .shards(3)
            .metrics_addr("127.0.0.1:9464")
            .slow_request_ms(25)
            .alerts(vec![]);
        assert_eq!(opts.config.shards, 3);
        assert_eq!(opts.config.metrics_addr.as_deref(), Some("127.0.0.1:9464"));
        assert_eq!(opts.config.slow_request_ms, 25);
        assert!(opts.config.alerts.is_empty());
        assert_eq!(opts.seed, 0);
        assert_eq!(opts.termination, None);

        // `config()` replaces wholesale: sugar applied before it is lost,
        // sugar applied after it sticks (the documented ordering rule).
        let replaced = ServeOptions::new()
            .shards(3)
            .config(ServerConfig::default())
            .slow_request_ms(7)
            .seed(11)
            .termination(Termination::Antithetic);
        assert_eq!(replaced.config.shards, ServerConfig::default().shards);
        assert_eq!(replaced.config.slow_request_ms, 7);
        assert_eq!(replaced.seed, 11);
        assert_eq!(replaced.termination, Some(Termination::Antithetic));
    }

    /// `--termination` at the serve boundary: no override (or a
    /// matching one) hands the stream back untouched; a differing
    /// scheme rebuilds it bitwise-identical to constructing under that
    /// scheme directly.
    #[test]
    fn termination_override_resamples_only_on_mismatch() {
        let iid = small_stream(Termination::Iid);
        let phi_iid = iid.phi_snapshot();

        let untouched = apply_termination_override(
            small_stream(Termination::Iid),
            None,
        );
        assert_eq!(untouched.config().termination, Termination::Iid);
        assert_eq!(untouched.phi_snapshot(), phi_iid);

        let matching = apply_termination_override(
            small_stream(Termination::Iid),
            Some(Termination::Iid),
        );
        assert_eq!(matching.phi_snapshot(), phi_iid);

        let overridden = apply_termination_override(
            small_stream(Termination::Iid),
            Some(Termination::Qmc),
        );
        assert_eq!(overridden.config().termination, Termination::Qmc);
        let direct = small_stream(Termination::Qmc);
        assert_eq!(overridden.phi_snapshot(), direct.phi_snapshot());
        assert_ne!(
            overridden.phi_snapshot(),
            phi_iid,
            "qmc override produced the iid features — the rebuild did not \
             change the termination stream"
        );
    }
}
