//! GP inference server — the L3 "coordinator" surface.
//!
//! A std-net TCP server speaking newline-delimited JSON, in the style
//! of a model-inference router: a listener thread accepts connections,
//! requests are routed into a shared queue, and a worker pool owns the
//! GP model behind a mutex, micro-batching compatible requests (e.g.
//! several `predict` requests are merged into one posterior evaluation
//! under a single lock acquisition / feature borrow).
//!
//! Protocol (one JSON object per line):
//!   {"op":"observe","node":17,"y":0.42}
//!   {"op":"predict","nodes":[1,2,3],"samples":16}
//!   {"op":"sample"}                       → full posterior draw argmax
//!   {"op":"thompson"}                     → next query node
//!   {"op":"stats"}
//!   {"op":"shutdown"}
//! Responses: {"ok":true, ...} or {"ok":false,"error":"..."}.

pub mod batcher;

use crate::gp::model::GpModel;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use batcher::{Batcher, Request, Response};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Server shared state.
pub struct ServerState {
    pub model: Mutex<ModelState>,
    pub requests_served: AtomicU64,
    pub shutdown: AtomicBool,
}

/// The mutable model + data the workers operate on.
pub struct ModelState {
    pub model: GpModel,
    pub observations: Vec<(usize, f64)>,
    pub rng: Rng,
}

impl ModelState {
    fn refresh(&mut self) {
        let nodes: Vec<usize> =
            self.observations.iter().map(|(i, _)| *i).collect();
        let ys: Vec<f64> = self.observations.iter().map(|(_, v)| *v).collect();
        self.model.set_data(&nodes, &ys);
    }
}

/// Handle one already-parsed request against the state.
pub fn handle(state: &ServerState, req: &Request) -> Response {
    state.requests_served.fetch_add(1, Ordering::Relaxed);
    match req {
        Request::Observe { node, y } => {
            let mut ms = state.model.lock().unwrap();
            if *node >= ms.model.n() {
                return Response::error(format!("node {node} out of range"));
            }
            ms.observations.push((*node, *y));
            ms.refresh();
            Response::ok(vec![("n_obs", Json::Num(ms.observations.len() as f64))])
        }
        Request::Predict { nodes, samples } => {
            let mut ms = state.model.lock().unwrap();
            if let Some(&bad) = nodes.iter().find(|&&n| n >= ms.model.n()) {
                return Response::error(format!("node {bad} out of range"));
            }
            let mut rng = ms.rng.split(ms.observations.len() as u64);
            let (mean, var) = ms.model.predict(*samples, &mut rng);
            let mu: Vec<f64> = nodes.iter().map(|&i| mean[i]).collect();
            let vv: Vec<f64> = nodes.iter().map(|&i| var[i]).collect();
            Response::ok(vec![
                ("mean", Json::arr_f64(&mu)),
                ("var", Json::arr_f64(&vv)),
            ])
        }
        Request::Sample => {
            let mut ms = state.model.lock().unwrap();
            let mut rng = ms.rng.split(0x5A);
            ms.rng = ms.rng.split(1); // advance server stream
            let s = ms.model.posterior_sample(&mut rng);
            let (argmax, max) = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, v)| (i, *v))
                .unwrap();
            Response::ok(vec![
                ("argmax", Json::Num(argmax as f64)),
                ("max", Json::Num(max)),
            ])
        }
        Request::Thompson => {
            let mut ms = state.model.lock().unwrap();
            let mut rng = ms.rng.split(0x7A);
            ms.rng = ms.rng.split(2);
            let s = ms.model.posterior_sample(&mut rng);
            let queried: std::collections::HashSet<usize> =
                ms.observations.iter().map(|(i, _)| *i).collect();
            let next = s
                .iter()
                .enumerate()
                .filter(|(i, _)| !queried.contains(i))
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            Response::ok(vec![("next", Json::Num(next as f64))])
        }
        Request::Stats => {
            let ms = state.model.lock().unwrap();
            Response::ok(vec![
                ("n_nodes", Json::Num(ms.model.n() as f64)),
                ("n_obs", Json::Num(ms.observations.len() as f64)),
                (
                    "requests",
                    Json::Num(state.requests_served.load(Ordering::Relaxed) as f64),
                ),
            ])
        }
        Request::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            Response::ok(vec![("bye", Json::Bool(true))])
        }
    }
}

fn client_loop(stream: TcpStream, state: Arc<ServerState>, batcher: Arc<Batcher>) -> Result<()> {
    let mut writer = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::parse(&line) {
            Ok(req) => batcher.submit(&state, req),
            Err(e) => Response::error(e),
        };
        writer.write_all(resp.to_json().to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// Serve `model` on `addr` until a shutdown request arrives.
pub fn serve(model: GpModel, addr: &str, seed: u64) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    eprintln!("grfgp server listening on {local}");
    serve_on(model, listener, seed)
}

/// Serve on an already-bound listener (tests bind port 0 themselves).
pub fn serve_on(model: GpModel, listener: TcpListener, seed: u64) -> Result<()> {
    let state = Arc::new(ServerState {
        model: Mutex::new(ModelState {
            model,
            observations: Vec::new(),
            rng: Rng::new(seed),
        }),
        requests_served: AtomicU64::new(0),
        shutdown: AtomicBool::new(false),
    });
    let batcher = Arc::new(Batcher::new(8));
    listener.set_nonblocking(true)?;
    std::thread::scope(|scope| -> Result<()> {
        loop {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let st = state.clone();
                    let ba = batcher.clone();
                    scope.spawn(move || {
                        if let Err(e) = client_loop(stream, st, ba) {
                            eprintln!("client error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    })
}
