//! Published read snapshots — the wait-free half of the serving path.
//!
//! Writers (the batcher's coalesced write path) mutate the private
//! [`crate::server`] model state under its mutex, then **publish** an
//! immutable [`ReadSnapshot`] here. Readers (`predict`) grab the
//! latest published snapshot — one brief `RwLock` read to clone an
//! `Arc` — and compute entirely off it, never touching the model
//! mutex. Reclamation is just `Arc` refcounts: a reader pinned to an
//! old snapshot keeps it alive; the last drop frees it. Snapshots are
//! cheap to build (the Φ/Φᵀ compacted bases and packed ELL operands
//! are `Arc`-shared with the live model; see
//! [`crate::gp::GpModel::read_view`]), so writers publish once per
//! engine call without a memory cliff.
//!
//! ## Determinism contract
//!
//! Every predict computed off a snapshot derives its rng as
//! `rng_base.split(PREDICT_STREAM).split(seq)` where `rng_base` is
//! the server rng captured at publish time and `seq` is a
//! monotonically increasing per-request counter
//! ([`crate::server`]'s `predict_seq`). The `seq` is echoed in the
//! response (`rng_seq`), so a client — or a test — can reproduce any
//! prediction bit-for-bit from `(stamped graph_version, rng_seq)`
//! alone. Predict traffic no longer advances the server's write-side
//! rng, so read volume cannot perturb `sample`/`thompson` draws.

use crate::gp::ModelReadView;
use crate::obs;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, PoisonError, RwLock};
use std::time::Instant;

/// Stream id predictions split off the published rng base. (Kept at
/// the historic batcher constant so the serving rng lineage is
/// recognisable in older traces.)
pub const PREDICT_STREAM: u64 = 0xBA7C;

/// Everything a prediction reads, frozen at one publication point.
pub struct ReadSnapshot {
    /// Owned inference inputs (Φ/Φᵀ views, ELL operands, mask/y,
    /// hypers, solver settings, Jacobi diagonal, lazy cached mean).
    pub view: ModelReadView,
    /// Graph version this state corresponds to — stamped on every
    /// response computed off this snapshot.
    pub graph_version: u64,
    /// Node count of `view` (responses validate node ids against
    /// this, not the live mirror, so a torn read is impossible).
    pub n_nodes: usize,
    /// Observation count at publish time.
    pub n_obs: usize,
    /// Stream compaction count at publish time (observability; when
    /// sharded, the sum over shards — per-shard cadences legitimately
    /// differ, see [`crate::shard`]).
    pub compactions: usize,
    /// How many feature-maintenance shards composed this snapshot's
    /// operands (1 = mono). The composition invariant: the write path
    /// joins **every** shard worker before it patches the model and
    /// publishes, so a snapshot can never mix two generations of
    /// per-shard state — one `graph_version` stamps all rows.
    pub shards: usize,
    /// Monotone publication sequence number (assigned by
    /// [`SnapshotCell::publish`]).
    pub publish_seq: u64,
    /// Server rng captured at publish time; per-request predict rngs
    /// split off it (see module docs).
    pub rng_base: Rng,
    /// Swap instant (stamped by [`SnapshotCell`]) — lets each predict
    /// record the age of the snapshot it computed off
    /// (`predict_snapshot_lag_ns`), i.e. the staleness the RCU read
    /// path actually delivers.
    pub published_at: Instant,
}

impl ReadSnapshot {
    /// The deterministic per-request rng for predict sequence number
    /// `seq` under this snapshot.
    pub fn predict_rng(&self, seq: u64) -> Rng {
        self.rng_base.split(PREDICT_STREAM).split(seq)
    }
}

/// The publication point: an atomically swappable `Arc<ReadSnapshot>`.
///
/// `load` is a reader-lock acquisition held only for one `Arc` clone —
/// never across a solve — so readers cannot block a writer for longer
/// than that clone, and a writer swap cannot tear a reader (the reader
/// either sees the old `Arc` or the new one, both fully constructed).
pub struct SnapshotCell {
    slot: RwLock<Arc<ReadSnapshot>>,
    /// Count of publications (== `publish_seq` of the current
    /// snapshot); readable without the lock for monotonicity asserts.
    published: AtomicU64,
}

impl SnapshotCell {
    /// Initialise with the first snapshot (publication 0 — the server
    /// constructor publishes before accepting connections, so readers
    /// always find a snapshot).
    pub fn new(mut first: ReadSnapshot) -> SnapshotCell {
        first.publish_seq = 0;
        SnapshotCell {
            slot: RwLock::new(Arc::new(first)),
            published: AtomicU64::new(0),
        }
    }

    /// The latest published snapshot.
    pub fn load(&self) -> Arc<ReadSnapshot> {
        self.slot
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Swap in a new snapshot; returns its publication sequence
    /// number. Callers publish **before** acking the writes the
    /// snapshot reflects, so an acked `graph_version` is always
    /// servable.
    pub fn publish(&self, mut snap: ReadSnapshot) -> u64 {
        let seq = self.published.fetch_add(1, Ordering::AcqRel) + 1;
        snap.publish_seq = seq;
        snap.published_at = Instant::now();
        let next = Arc::new(snap);
        let mut slot = self.slot.write().unwrap_or_else(PoisonError::into_inner);
        *slot = next;
        obs::registry::SNAPSHOT_PUBLISHES.inc();
        seq
    }

    /// Publication count (sequence number of the current snapshot).
    pub fn published(&self) -> u64 {
        self.published.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_rng_is_pure_in_seq() {
        let base = Rng::new(7);
        let a = base.split(PREDICT_STREAM).split(3);
        let mut b = Rng::new(7).split(PREDICT_STREAM).split(3);
        let mut a2 = a.clone();
        assert_eq!(a2.next_u64(), b.next_u64());
        // Different seq → different stream.
        let mut c = Rng::new(7).split(PREDICT_STREAM).split(4);
        let mut a3 = a.clone();
        assert_ne!(a3.next_u64(), c.next_u64());
    }
}
