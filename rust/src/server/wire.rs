//! Hardened wire layer: bounded incremental frame decoding plus the
//! server's structured error taxonomy.
//!
//! The protocol is newline-delimited JSON. The old read path
//! (`BufReader::lines`) buffered an unbounded line in memory and only
//! then parsed it — a single client could hold a multi-gigabyte
//! allocation with one newline-free stream. [`WireDecoder`] replaces it
//! with an incremental decoder fed raw bytes as they arrive from the
//! socket:
//!
//! - Memory per connection is bounded: the reassembly buffer never
//!   holds more than [`WireConfig::max_frame_bytes`]. When a frame
//!   exceeds the cap the decoder switches to *dropping* mode,
//!   discarding bytes (counting, not storing them) until the next
//!   newline, then emits exactly one typed `protocol` error for the
//!   whole oversized frame and resynchronises.
//! - Parsing is depth-capped ([`WireConfig::max_parse_depth`]) so
//!   `[[[[…` bombs fail cleanly instead of exhausting the stack, and
//!   strict about Unicode by default (lone surrogates and invalid
//!   UTF-8 are `parse` errors; see [`UnicodeMode`] for the documented
//!   replace mode).
//! - Chunk boundaries are invisible: bytes may arrive one at a time or
//!   in arbitrary splits and the decoded frame stream is identical.
//!
//! Every error carries an [`ErrorKind`] so clients can distinguish
//! their own malformed input (`parse`/`protocol`) from server-side
//! conditions (`overload`/`internal`) — the taxonomy every error reply
//! is tagged with (`error_kind` field, see `server` module docs).

use crate::util::json::{Json, ParseOptions, UnicodeMode};

/// Coarse classification for every error reply the server emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// The frame was not valid JSON (bad syntax, nesting past the
    /// depth cap, invalid Unicode under strict mode).
    Parse,
    /// The frame was valid JSON but not a valid request (unknown op,
    /// missing/ill-typed fields, out-of-range ids, oversized frame).
    Protocol,
    /// The server refused the work due to load (connection cap).
    Overload,
    /// The server failed internally (handler panic, batch timeout).
    Internal,
}

impl ErrorKind {
    /// Wire spelling of the kind (the `error_kind` response field).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::Parse => "parse",
            ErrorKind::Protocol => "protocol",
            ErrorKind::Overload => "overload",
            ErrorKind::Internal => "internal",
        }
    }
}

/// A typed wire-level error: what went wrong and how it is classified.
#[derive(Clone, Debug)]
pub struct WireError {
    pub kind: ErrorKind,
    pub msg: String,
}

impl WireError {
    pub fn parse(msg: impl Into<String>) -> WireError {
        WireError { kind: ErrorKind::Parse, msg: msg.into() }
    }

    pub fn protocol(msg: impl Into<String>) -> WireError {
        WireError { kind: ErrorKind::Protocol, msg: msg.into() }
    }
}

/// Limits for one connection's decoder.
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Hard cap on one newline-delimited frame, in bytes. Also the
    /// bound on the decoder's reassembly buffer.
    pub max_frame_bytes: usize,
    /// JSON nesting cap within a frame (see `ParseOptions::max_depth`).
    pub max_parse_depth: usize,
    /// `\uXXXX` surrogate / invalid-UTF-8 policy. Strict by default;
    /// `Replace` substitutes U+FFFD for callers that prefer lossy
    /// decoding over rejection.
    pub unicode: UnicodeMode,
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig {
            max_frame_bytes: 256 * 1024,
            max_parse_depth: 64,
            unicode: UnicodeMode::Strict,
        }
    }
}

impl WireConfig {
    fn parse_options(&self) -> ParseOptions {
        ParseOptions { max_depth: self.max_parse_depth, unicode: self.unicode }
    }
}

/// Incremental newline-delimited JSON frame decoder with bounded
/// memory. Feed it socket reads as they happen; it emits one
/// `Result<Json, WireError>` per complete non-blank frame.
pub struct WireDecoder {
    cfg: WireConfig,
    /// Partial-frame reassembly buffer; invariant: `buf.len() <=
    /// cfg.max_frame_bytes` at all times.
    buf: Vec<u8>,
    /// True while discarding an oversized frame (until next newline).
    dropping: bool,
    /// Bytes discarded from the frame currently being dropped.
    dropped: usize,
}

impl WireDecoder {
    pub fn new(cfg: WireConfig) -> WireDecoder {
        assert!(cfg.max_frame_bytes > 0, "max_frame_bytes must be positive");
        WireDecoder { cfg, buf: Vec::new(), dropping: false, dropped: 0 }
    }

    /// Bytes currently buffered for a partial frame. Bounded by
    /// `max_frame_bytes` — tests assert on this to pin the per-
    /// connection memory bound.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when a partial frame is pending (a disconnect now would be
    /// mid-frame).
    pub fn mid_frame(&self) -> bool {
        !self.buf.is_empty() || self.dropping
    }

    /// Feed one chunk of bytes; push one result per completed frame
    /// onto `out`. Whitespace-only frames (blank lines, bare `\r`) are
    /// skipped without emitting anything, matching the old reader.
    pub fn feed(&mut self, bytes: &[u8], out: &mut Vec<Result<Json, WireError>>) {
        let mut rest = bytes;
        while let Some(pos) = rest.iter().position(|&c| c == b'\n') {
            let (line, tail) = rest.split_at(pos);
            rest = &tail[1..];
            if self.dropping {
                // The newline ends the frame we were discarding.
                self.dropped += line.len();
                out.push(Err(self.oversize_error()));
                self.dropping = false;
                self.dropped = 0;
                continue;
            }
            if self.buf.len() + line.len() > self.cfg.max_frame_bytes {
                self.dropped = self.buf.len() + line.len();
                self.buf.clear();
                out.push(Err(self.oversize_error()));
                self.dropped = 0;
                continue;
            }
            let opts = self.cfg.parse_options();
            let frame: &[u8] = if self.buf.is_empty() {
                line
            } else {
                self.buf.extend_from_slice(line);
                &self.buf
            };
            if !frame.iter().all(|b| b.is_ascii_whitespace()) {
                out.push(Json::parse_with(frame, &opts).map_err(WireError::parse));
            }
            self.buf.clear();
        }
        // Tail with no newline yet: buffer it, or start dropping if it
        // would breach the cap — memory stays bounded while an
        // oversized frame streams in.
        if self.dropping {
            self.dropped = self.dropped.saturating_add(rest.len());
        } else if self.buf.len() + rest.len() > self.cfg.max_frame_bytes {
            self.dropped = self.buf.len() + rest.len();
            self.buf.clear();
            self.dropping = true;
        } else {
            self.buf.extend_from_slice(rest);
        }
    }

    fn oversize_error(&self) -> WireError {
        WireError::protocol(format!(
            "frame exceeds max_frame_bytes={} ({} bytes discarded)",
            self.cfg.max_frame_bytes, self.dropped
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_all(dec: &mut WireDecoder, bytes: &[u8]) -> Vec<Result<Json, WireError>> {
        let mut out = Vec::new();
        dec.feed(bytes, &mut out);
        out
    }

    #[test]
    fn frames_split_across_arbitrary_chunks() {
        let mut dec = WireDecoder::new(WireConfig::default());
        let mut out = Vec::new();
        dec.feed(b"{\"a\"", &mut out);
        assert!(out.is_empty());
        assert!(dec.mid_frame());
        dec.feed(b":1}\ntru", &mut out);
        dec.feed(b"e\n", &mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_ref().unwrap().get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(out[1].as_ref().unwrap(), &Json::Bool(true));
        assert!(!dec.mid_frame());
    }

    #[test]
    fn blank_and_crlf_frames_are_skipped() {
        let mut dec = WireDecoder::new(WireConfig::default());
        let out = decode_all(&mut dec, b"\n  \n1\r\n\r\n2\n");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_ref().unwrap(), &Json::Num(1.0));
        assert_eq!(out[1].as_ref().unwrap(), &Json::Num(2.0));
    }

    #[test]
    fn oversized_frame_dropped_with_bounded_buffer_then_recovers() {
        let cfg = WireConfig { max_frame_bytes: 16, ..Default::default() };
        let mut dec = WireDecoder::new(cfg);
        let mut out = Vec::new();
        for _ in 0..100 {
            dec.feed(b"xxxxxxxx", &mut out); // 800 bytes, no newline
            assert!(dec.buffered() <= 16, "buffer breached the cap");
        }
        assert!(out.is_empty());
        dec.feed(b"\ntrue\n", &mut out);
        assert_eq!(out.len(), 2);
        let err = out[0].as_ref().err().expect("oversize must error");
        assert_eq!(err.kind, ErrorKind::Protocol);
        assert!(err.msg.contains("max_frame_bytes"), "{}", err.msg);
        assert_eq!(out[1].as_ref().unwrap(), &Json::Bool(true));
    }

    #[test]
    fn oversized_single_chunk_line_also_rejected() {
        let cfg = WireConfig { max_frame_bytes: 8, ..Default::default() };
        let mut dec = WireDecoder::new(cfg);
        let out = decode_all(&mut dec, b"[1,2,3,4,5,6]\n7\n");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_ref().err().unwrap().kind, ErrorKind::Protocol);
        assert_eq!(out[1].as_ref().unwrap(), &Json::Num(7.0));
    }

    #[test]
    fn garbage_frames_yield_parse_errors_and_resync() {
        let mut dec = WireDecoder::new(WireConfig::default());
        let out = decode_all(&mut dec, b"\xff\xfe{[\n{\"ok\":true}\n");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_ref().err().unwrap().kind, ErrorKind::Parse);
        assert!(out[1].is_ok());
    }

    #[test]
    fn depth_cap_applies_per_frame() {
        let cfg = WireConfig { max_parse_depth: 4, ..Default::default() };
        let mut dec = WireDecoder::new(cfg);
        let out = decode_all(&mut dec, b"[[[[[1]]]]]\n[[1]]\n");
        assert_eq!(out.len(), 2);
        let err = out[0].as_ref().err().expect("depth bomb must error");
        assert_eq!(err.kind, ErrorKind::Parse);
        assert!(err.msg.contains("max_depth"), "{}", err.msg);
        assert!(out[1].is_ok());
    }
}
