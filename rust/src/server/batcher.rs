//! Request parsing + micro-batching.
//!
//! The batcher coalesces requests that can share one expensive engine
//! call, in two classes:
//!
//! * **Predict** requests arriving within the batching window are
//!   merged into a single prediction over the union of their nodes
//!   (the expensive part — posterior mean solve + pathwise variance
//!   samples — is shared), then results are scattered back per
//!   request. Predictions are computed **entirely off the published
//!   read snapshot** ([`super::predict_off_snapshot`]) — the predict
//!   path never acquires the model mutex, so reads cannot block
//!   writers (or each other's admission).
//! * **Write** requests (`observe`, `add_edge`, `remove_edge`,
//!   `add_node`) are coalesced into one ordered batch applied under a
//!   single lock: runs of observations flush with one `set_data`, and
//!   each graph delta runs one incremental feature patch + warm
//!   re-solve ([`crate::gp::GpModel::apply_graph_delta`]). The write
//!   batch ends by publishing a fresh snapshot (before acks), which is
//!   what makes the read path's staleness bounded.
//!
//! Leadership is take-based: after the window, whichever participant
//! still finds its batch pending takes it out, runs it, and publishes
//! the results in a per-generation `done` map that participants drain
//! (entries are removed once every span is claimed). A pending batch
//! is never replaced: requests that cannot join (key mismatch, full
//! batch) execute solo instead, so a batch can never be evicted
//! before its results reach every client. An **idle fast path** skips
//! the batching window when no other predict is in flight (an atomic
//! in-flight gate — there is nothing to coalesce with, so serial
//! clients pay no window latency); the write side keeps the
//! lock-uncontended probe.

use super::wire::ErrorKind;
use super::ServerState;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::Duration;

/// Parsed request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Observe { node: usize, y: f64 },
    Predict { nodes: Vec<usize>, samples: usize },
    AddEdge { u: usize, v: usize, w: f64 },
    RemoveEdge { u: usize, v: usize },
    AddNode,
    Sample,
    Thompson,
    Stats,
    Shutdown,
    /// Test-only op (`{"op":"fault","mode":"panic"|"panic_locked"}`):
    /// panics inside the handler, optionally while holding the model
    /// lock. Rejected unless `ServerConfig::fault_injection` is on —
    /// the fault-injection suite uses it to prove panic isolation and
    /// lock-poison recovery over a real connection.
    Fault { locked: bool },
    /// Telemetry scrape (`{"op":"metrics"}`): exports the
    /// [`crate::obs`] registry, as structured JSON by default or as
    /// Prometheus text exposition when `"format":"prometheus"`. Served
    /// lock-free off the registry's atomics — see the "Observability"
    /// section in [`crate::server`].
    Metrics { prometheus: bool },
}

/// How the batcher routes a request.
enum BatchClass {
    Direct,
    Predict(usize),
    Write,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad JSON: {e}"))?;
        Request::from_json(&j)
    }

    /// Field extraction from an already-parsed frame (the wire decoder
    /// hands over `Json` values; see `server::wire`). Errors here are
    /// `protocol`-kind: the JSON was fine, the request was not.
    pub fn from_json(j: &Json) -> Result<Request, String> {
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or("missing op".to_string())?;
        match op {
            "observe" => {
                let node = j
                    .get("node")
                    .and_then(Json::as_usize)
                    .ok_or("observe needs node")?;
                let y = j
                    .get("y")
                    .and_then(Json::as_f64)
                    .ok_or("observe needs y")?;
                Ok(Request::Observe { node, y })
            }
            "predict" => {
                let nodes = j
                    .get("nodes")
                    .and_then(|a| a.as_arr())
                    .ok_or("predict needs nodes")?
                    .iter()
                    .map(|v| v.as_usize().ok_or("bad node id"))
                    .collect::<Result<Vec<_>, _>>()?;
                let samples =
                    j.get("samples").and_then(Json::as_usize).unwrap_or(16);
                Ok(Request::Predict { nodes, samples })
            }
            "add_edge" => {
                let u = j
                    .get("u")
                    .and_then(Json::as_usize)
                    .ok_or("add_edge needs u")?;
                let v = j
                    .get("v")
                    .and_then(Json::as_usize)
                    .ok_or("add_edge needs v")?;
                let w = j.get("w").and_then(Json::as_f64).unwrap_or(1.0);
                Ok(Request::AddEdge { u, v, w })
            }
            "remove_edge" => {
                let u = j
                    .get("u")
                    .and_then(Json::as_usize)
                    .ok_or("remove_edge needs u")?;
                let v = j
                    .get("v")
                    .and_then(Json::as_usize)
                    .ok_or("remove_edge needs v")?;
                Ok(Request::RemoveEdge { u, v })
            }
            "add_node" => Ok(Request::AddNode),
            "sample" => Ok(Request::Sample),
            "thompson" => Ok(Request::Thompson),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            "metrics" => match j.get("format").and_then(Json::as_str) {
                None | Some("json") => Ok(Request::Metrics { prometheus: false }),
                Some("prometheus") | Some("prom") => {
                    Ok(Request::Metrics { prometheus: true })
                }
                Some(other) => Err(format!(
                    "metrics format must be \"json\" or \"prometheus\", got {other:?}"
                )),
            },
            "fault" => match j.get("mode").and_then(Json::as_str) {
                Some("panic") => Ok(Request::Fault { locked: false }),
                Some("panic_locked") => Ok(Request::Fault { locked: true }),
                _ => Err("fault needs mode \"panic\" or \"panic_locked\"".into()),
            },
            other => Err(format!("unknown op {other:?}")),
        }
    }

    /// Wire op name of this request — the key under which its
    /// telemetry is accounted (`req_<op>`, `request_ns_<op>`; see
    /// [`crate::obs::registry::request_metrics`]).
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Observe { .. } => "observe",
            Request::Predict { .. } => "predict",
            Request::AddEdge { .. } => "add_edge",
            Request::RemoveEdge { .. } => "remove_edge",
            Request::AddNode => "add_node",
            Request::Sample => "sample",
            Request::Thompson => "thompson",
            Request::Stats => "stats",
            Request::Shutdown => "shutdown",
            Request::Fault { .. } => "fault",
            Request::Metrics { .. } => "metrics",
        }
    }

    fn class(&self) -> BatchClass {
        match self {
            Request::Predict { samples, .. } => BatchClass::Predict(*samples),
            Request::Observe { .. }
            | Request::AddEdge { .. }
            | Request::RemoveEdge { .. }
            | Request::AddNode => BatchClass::Write,
            _ => BatchClass::Direct,
        }
    }
}

/// Response wrapper.
#[derive(Clone, Debug)]
pub struct Response {
    pub ok: bool,
    pub fields: Vec<(String, Json)>,
}

impl Response {
    pub fn ok(fields: Vec<(&str, Json)>) -> Response {
        Response {
            ok: true,
            fields: fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        }
    }

    /// Error reply with the default `protocol` classification (the
    /// JSON parsed but the request was unusable) — the common case for
    /// handler-level rejections.
    pub fn error(msg: impl Into<String>) -> Response {
        Response::fault(ErrorKind::Protocol, msg)
    }

    /// Error reply with an explicit [`ErrorKind`]. Every error the
    /// server emits carries `error_kind` so clients can tell their own
    /// bad input (`parse`/`protocol`) from server conditions
    /// (`overload`/`internal`).
    pub fn fault(kind: ErrorKind, msg: impl Into<String>) -> Response {
        Response {
            ok: false,
            fields: vec![
                ("error".to_string(), Json::Str(msg.into())),
                ("error_kind".to_string(), Json::Str(kind.as_str().to_string())),
            ],
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj: Vec<(&str, Json)> =
            vec![("ok", Json::Bool(self.ok))];
        for (k, v) in &self.fields {
            obj.push((k.as_str(), v.clone()));
        }
        Json::obj(obj)
    }
}

/// The wire shape of every successful predict response — both serving
/// entry points (`server::handle` and the batcher) emit through this
/// one constructor, so they cannot drift. `batched` is the participant
/// count of the shared engine call; `graph_version` + `rng_seq`
/// together let a client (or test) reproduce the prediction
/// bit-for-bit (see `server::snapshot`).
pub fn predict_response(
    mu: &[f64],
    var: &[f64],
    parts: usize,
    version: u64,
    rng_seq: u64,
) -> Response {
    Response::ok(vec![
        ("mean", Json::arr_f64(mu)),
        ("var", Json::arr_f64(var)),
        ("batched", Json::from_uint(parts as u64)),
        ("graph_version", Json::from_uint(version)),
        ("rng_seq", Json::from_uint(rng_seq)),
    ])
}

struct PendingPredict {
    generation: u64,
    /// Batch key: the `samples` parameter (requests must agree on it).
    key: usize,
    nodes: Vec<usize>,
    /// (offset, len) per participant, in arrival order.
    spans: Vec<(usize, usize)>,
}

struct PredictDone {
    mu: Vec<f64>,
    var: Vec<f64>,
    /// Graph version at compute time — lets clients detect whether a
    /// response predates a graph delta they already saw acknowledged.
    graph_version: u64,
    /// Predict rng sequence number of the shared engine call (echoed in
    /// every participant's response; see `server::snapshot` docs).
    rng_seq: u64,
    /// Node count of the snapshot the batch was computed off. A
    /// participant whose nodes passed the live mirror but exceed this
    /// (its request raced a not-yet-published `add_node`) converts its
    /// claim into an out-of-range error instead of reading the NaN
    /// placeholders the leader gathered for those ids.
    n_snap: usize,
    parts: usize,
    claimed: usize,
    /// Publication time: entries older than [`RESULT_TIMEOUT`] can have
    /// no live claimant (every deadline predates publication + timeout)
    /// and are swept.
    published: std::time::Instant,
}

/// A participant's slice of a published batch result.
struct ClaimedPredict {
    mu: Vec<f64>,
    var: Vec<f64>,
    parts: usize,
    graph_version: u64,
    rng_seq: u64,
    n_snap: usize,
}

struct PendingWrites {
    generation: u64,
    reqs: Vec<Request>,
}

struct WriteDone {
    results: Vec<Response>,
    claimed: usize,
    /// See [`PredictDone::published`].
    published: std::time::Instant,
}

struct PredictSlot {
    next_gen: u64,
    pending: Option<PendingPredict>,
    done: HashMap<u64, PredictDone>,
}

struct WriteSlot {
    next_gen: u64,
    pending: Option<PendingWrites>,
    done: HashMap<u64, WriteDone>,
}

/// Micro-batcher: the first request of a class in a window opens a
/// batch; compatible requests arriving while it is pending join it.
/// `max_batch` bounds the **participant count** of a predict batch and
/// the length of a write batch; `max_union_nodes` independently bounds
/// the union node count of a predict batch (the size of the shared
/// solve — without it, `max_batch` many-node requests could build an
/// unboundedly large batched predict).
pub struct Batcher {
    max_batch: usize,
    /// Cap on `Σ |nodes|` across a predict batch's participants.
    max_union_nodes: usize,
    /// Upper bound on waiting for a leader's results; also the age past
    /// which a published `done` entry can have no live claimant.
    result_timeout: Duration,
    /// Predict requests currently inside `submit_predict` — the idle
    /// fast path's gate. Predicts never probe the model mutex, so lock
    /// contention can't be the "is anyone else here?" signal; this
    /// atomic is.
    predicts_inflight: AtomicUsize,
    predicts: Mutex<PredictSlot>,
    pcv: Condvar,
    writes: Mutex<WriteSlot>,
    wcv: Condvar,
}

/// Decrements the in-flight predict gate on every exit path (including
/// panics unwinding through the dispatch guard).
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// How long a joiner waits for stragglers before taking leadership.
const BATCH_WINDOW: Duration = Duration::from_millis(2);
/// Default upper bound on waiting for a leader's results.
const RESULT_TIMEOUT: Duration = Duration::from_secs(30);

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher::with_limits(max_batch, max_batch * 64, RESULT_TIMEOUT)
    }

    /// Construct with explicit caps — tests shrink `result_timeout` to
    /// exercise the stale-entry sweeps without 30s waits.
    pub fn with_limits(
        max_batch: usize,
        max_union_nodes: usize,
        result_timeout: Duration,
    ) -> Batcher {
        Batcher {
            max_batch,
            max_union_nodes,
            result_timeout,
            predicts_inflight: AtomicUsize::new(0),
            predicts: Mutex::new(PredictSlot {
                next_gen: 0,
                pending: None,
                done: HashMap::new(),
            }),
            pcv: Condvar::new(),
            writes: Mutex::new(WriteSlot {
                next_gen: 0,
                pending: None,
                done: HashMap::new(),
            }),
            wcv: Condvar::new(),
        }
    }

    /// Execute a request, batching predicts and writes.
    pub fn submit(&self, state: &ServerState, req: Request) -> Response {
        match req.class() {
            BatchClass::Direct => super::handle(state, &req),
            BatchClass::Write => self.submit_write(state, req),
            BatchClass::Predict(key) => {
                let Request::Predict { nodes, .. } = req else {
                    unreachable!()
                };
                self.submit_predict(state, nodes, key)
            }
        }
    }

    /// Snapshot-based predict + per-request gather. `Err` is the typed
    /// response for nodes past the snapshot's node count — the request
    /// raced an `add_node` that reached the live mirror but not yet the
    /// publication point.
    fn predict_gather(
        state: &ServerState,
        nodes: &[usize],
        key: usize,
    ) -> Result<(Vec<f64>, Vec<f64>, u64, u64), Response> {
        let (snap, mean, var, rng_seq) = super::predict_off_snapshot(state, key);
        if let Some(&bad) = nodes.iter().find(|&&i| i >= snap.n_nodes) {
            return Err(Response::error(format!("node {bad} out of range")));
        }
        let mu = nodes.iter().map(|&i| mean[i]).collect();
        let vv = nodes.iter().map(|&i| var[i]).collect();
        Ok((mu, vv, snap.graph_version, rng_seq))
    }

    fn submit_predict(
        &self,
        state: &ServerState,
        nodes: Vec<usize>,
        key: usize,
    ) -> Response {
        // Validate up front against the lock-free node-count mirror
        // (nodes stay valid: the graph only grows, and the mirror is
        // updated before any delta is acknowledged).
        let n = state.n_nodes.load(Ordering::SeqCst);
        if let Some(&bad) = nodes.iter().find(|&&i| i >= n) {
            return Response::error(format!("node {bad} out of range"));
        }
        // Idle fast path: no other predict in flight means there is
        // nothing to coalesce with — skip the batching window entirely.
        // Predicts never touch the model mutex, so lock contention
        // can't signal company; the in-flight gate does.
        let solo =
            self.predicts_inflight.fetch_add(1, Ordering::AcqRel) == 0;
        let _inflight = InflightGuard(&self.predicts_inflight);
        if solo {
            let resp = match Self::predict_gather(state, &nodes, key) {
                Ok((mu, var, version, rng_seq)) => {
                    predict_response(&mu, &var, 1, version, rng_seq)
                }
                Err(resp) => resp,
            };
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return resp;
        }
        // Join the pending batch if compatible, open one if none is
        // pending; an incompatible pending batch (different samples
        // key, participant cap, or union-size cap) is left intact and
        // this request runs solo.
        let joined = self.join_predict(&nodes, key);
        let Some((generation, span)) = joined else {
            // Solo slow path — still wait-free, just without having
            // skipped the admission bookkeeping.
            let resp = match Self::predict_gather(state, &nodes, key) {
                Ok((mu, var, version, rng_seq)) => {
                    predict_response(&mu, &var, 1, version, rng_seq)
                }
                Err(resp) => resp,
            };
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return resp;
        };
        std::thread::sleep(BATCH_WINDOW);
        // Leader = whoever still finds its batch pending; it takes the
        // batch out, so late arrivals open a fresh one.
        let batch = {
            let mut slot = self.predicts.lock().unwrap_or_else(PoisonError::into_inner);
            let mine = matches!(
                slot.pending.as_ref(),
                Some(b) if b.generation == generation
            );
            if mine {
                slot.pending.take()
            } else {
                None
            }
        };
        if let Some(b) = batch {
            let (snap, mean, variance, rng_seq) =
                super::predict_off_snapshot(state, b.key);
            // Gather the union off the snapshot. Ids past the
            // snapshot's node count (possible only for a request that
            // raced a not-yet-published add_node) gather as NaN
            // placeholders; the claim path converts any span containing
            // one into a typed error via `n_snap`, so a NaN never
            // reaches a client.
            let mu: Vec<f64> = b
                .nodes
                .iter()
                .map(|&i| mean.get(i).copied().unwrap_or(f64::NAN))
                .collect();
            let vv: Vec<f64> = b
                .nodes
                .iter()
                .map(|&i| variance.get(i).copied().unwrap_or(f64::NAN))
                .collect();
            let mut slot = self.predicts.lock().unwrap_or_else(PoisonError::into_inner);
            // Bounded-stale sweep: a participant that timed out never
            // claims its span, so its entry could linger — drop entries
            // older than the claim deadline (no live claimant remains;
            // claimants' deadlines start before publication). The claim
            // path runs the same sweep, covering quiescent traffic.
            let timeout = self.result_timeout;
            slot.done
                .retain(|_, d| d.published.elapsed() < timeout);
            slot.done.insert(
                b.generation,
                PredictDone {
                    mu,
                    var: vv,
                    graph_version: snap.graph_version,
                    rng_seq,
                    n_snap: snap.n_nodes,
                    parts: b.spans.len(),
                    claimed: 0,
                    published: std::time::Instant::now(),
                },
            );
            drop(slot);
            self.pcv.notify_all();
        }
        match self.claim_predict(generation, span) {
            Some(claim) => {
                if let Some(&bad) =
                    nodes.iter().find(|&&i| i >= claim.n_snap)
                {
                    return Response::error(format!(
                        "node {bad} out of range"
                    ));
                }
                state.requests_served.fetch_add(1, Ordering::Relaxed);
                predict_response(
                    &claim.mu,
                    &claim.var,
                    claim.parts,
                    claim.graph_version,
                    claim.rng_seq,
                )
            }
            None => Response::fault(ErrorKind::Internal, "predict batch timed out"),
        }
    }

    /// Join (or open) the pending predict batch. Returns the
    /// `(generation, span)` ticket, or `None` when the pending batch is
    /// incompatible: different `samples` key, participant count at
    /// `max_batch`, or the union node count would exceed
    /// `max_union_nodes`.
    fn join_predict(
        &self,
        nodes: &[usize],
        key: usize,
    ) -> Option<(u64, (usize, usize))> {
        let mut slot = self.predicts.lock().unwrap_or_else(PoisonError::into_inner);
        match slot.pending.as_mut() {
            Some(b)
                if b.key == key
                    && b.spans.len() < self.max_batch
                    && b.nodes.len() + nodes.len() <= self.max_union_nodes =>
            {
                let span = (b.nodes.len(), nodes.len());
                b.nodes.extend_from_slice(nodes);
                b.spans.push(span);
                Some((b.generation, span))
            }
            Some(_) => None,
            None => {
                let generation = slot.next_gen;
                slot.next_gen += 1;
                let span = (0, nodes.len());
                slot.pending = Some(PendingPredict {
                    generation,
                    key,
                    nodes: nodes.to_vec(),
                    spans: vec![span],
                });
                Some((generation, span))
            }
        }
    }

    /// Wait for and claim this participant's span of the published
    /// results (hard deadline — spurious wakeups from other batches
    /// must not restart the clock). After a *failed* lookup, each
    /// wakeup also sweeps `done` entries older than `result_timeout`:
    /// the publish-path sweep only runs when a later leader publishes,
    /// so under quiescent traffic a timed-out participant's entry
    /// would otherwise linger forever. The own-generation lookup comes
    /// **before** the sweep so a claimant descheduled past the timeout
    /// still collects its published result instead of evicting it;
    /// sweeping other entries is safe because their claimants'
    /// deadlines started before publication.
    fn claim_predict(
        &self,
        generation: u64,
        span: (usize, usize),
    ) -> Option<ClaimedPredict> {
        let deadline = std::time::Instant::now() + self.result_timeout;
        let mut slot = self.predicts.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(done) = slot.done.get_mut(&generation) {
                let (off, len) = span;
                let claim = ClaimedPredict {
                    mu: done.mu[off..off + len].to_vec(),
                    var: done.var[off..off + len].to_vec(),
                    parts: done.parts,
                    graph_version: done.graph_version,
                    rng_seq: done.rng_seq,
                    n_snap: done.n_snap,
                };
                done.claimed += 1;
                if done.claimed >= done.parts {
                    slot.done.remove(&generation);
                }
                return Some(claim);
            }
            let timeout = self.result_timeout;
            slot.done.retain(|_, d| d.published.elapsed() < timeout);
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self
                .pcv
                .wait_timeout(slot, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            slot = g;
        }
    }

    fn submit_write(&self, state: &ServerState, req: Request) -> Response {
        // Idle fast path: uncontended model → apply immediately; the
        // common serial-client observe stream pays no window latency.
        if let Some(mut ms) = state.try_model_guard() {
            let resp = ms
                .apply_writes(std::slice::from_ref(&req), state)
                .pop()
                .expect("one response per write");
            drop(ms);
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return resp;
        }
        // Join the pending write batch, open one if none is pending; a
        // full batch is left intact and this request runs solo.
        let joined = {
            let mut slot = self.writes.lock().unwrap_or_else(PoisonError::into_inner);
            match slot.pending.as_mut() {
                Some(b) if b.reqs.len() < self.max_batch => {
                    b.reqs.push(req.clone());
                    Some((b.generation, b.reqs.len() - 1))
                }
                Some(_) => None,
                None => {
                    let generation = slot.next_gen;
                    slot.next_gen += 1;
                    slot.pending = Some(PendingWrites {
                        generation,
                        reqs: vec![req.clone()],
                    });
                    Some((generation, 0))
                }
            }
        };
        let Some((generation, idx)) = joined else {
            // Solo slow path (blocking lock), preserving write order
            // within this connection.
            let mut ms = state.model_guard();
            let resp = ms
                .apply_writes(std::slice::from_ref(&req), state)
                .pop()
                .expect("one response per write");
            drop(ms);
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return resp;
        };
        std::thread::sleep(BATCH_WINDOW);
        let batch = {
            let mut slot = self.writes.lock().unwrap_or_else(PoisonError::into_inner);
            let mine = matches!(
                slot.pending.as_ref(),
                Some(b) if b.generation == generation
            );
            if mine {
                slot.pending.take()
            } else {
                None
            }
        };
        if let Some(b) = batch {
            let results = {
                let mut ms = state.model_guard();
                ms.apply_writes(&b.reqs, state)
            };
            let mut slot = self.writes.lock().unwrap_or_else(PoisonError::into_inner);
            let timeout = self.result_timeout;
            slot.done
                .retain(|_, d| d.published.elapsed() < timeout);
            slot.done.insert(
                b.generation,
                WriteDone {
                    results,
                    claimed: 0,
                    published: std::time::Instant::now(),
                },
            );
            drop(slot);
            self.wcv.notify_all();
        }
        match self.claim_write(generation, idx) {
            Some(resp) => {
                state.requests_served.fetch_add(1, Ordering::Relaxed);
                resp
            }
            None => Response::fault(ErrorKind::Internal, "write batch timed out"),
        }
    }

    /// Write-side twin of [`Batcher::claim_predict`]: own-generation
    /// lookup first, stale-entry sweep after each failed lookup.
    fn claim_write(&self, generation: u64, idx: usize) -> Option<Response> {
        let deadline = std::time::Instant::now() + self.result_timeout;
        let mut slot = self.writes.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(done) = slot.done.get_mut(&generation) {
                let resp = done
                    .results
                    .get(idx)
                    .cloned()
                    .unwrap_or_else(|| {
                        Response::error("write batch result missing")
                    });
                done.claimed += 1;
                if done.claimed >= done.results.len() {
                    slot.done.remove(&generation);
                }
                return Some(resp);
            }
            let timeout = self.result_timeout;
            slot.done.retain(|_, d| d.published.elapsed() < timeout);
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self
                .wcv
                .wait_timeout(slot, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            slot = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_ops() {
        assert_eq!(
            Request::parse(r#"{"op":"observe","node":3,"y":1.5}"#).unwrap(),
            Request::Observe { node: 3, y: 1.5 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"predict","nodes":[1,2]}"#).unwrap(),
            Request::Predict { nodes: vec![1, 2], samples: 16 }
        );
        assert_eq!(Request::parse(r#"{"op":"sample"}"#).unwrap(), Request::Sample);
        assert_eq!(
            Request::parse(r#"{"op":"thompson"}"#).unwrap(),
            Request::Thompson
        );
        assert_eq!(Request::parse(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn parse_graph_mutation_ops() {
        assert_eq!(
            Request::parse(r#"{"op":"add_edge","u":3,"v":7,"w":0.5}"#).unwrap(),
            Request::AddEdge { u: 3, v: 7, w: 0.5 }
        );
        // Weight defaults to 1.0.
        assert_eq!(
            Request::parse(r#"{"op":"add_edge","u":1,"v":2}"#).unwrap(),
            Request::AddEdge { u: 1, v: 2, w: 1.0 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"remove_edge","u":4,"v":0}"#).unwrap(),
            Request::RemoveEdge { u: 4, v: 0 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"add_node"}"#).unwrap(),
            Request::AddNode
        );
        assert!(Request::parse(r#"{"op":"add_edge","u":1}"#).is_err());
        assert!(Request::parse(r#"{"op":"remove_edge","v":1}"#).is_err());
    }

    #[test]
    fn predict_join_caps_participants_and_union_size() {
        // max_batch bounds participants; max_union_nodes bounds the
        // total node count of the shared solve.
        let b = Batcher::with_limits(3, 5, RESULT_TIMEOUT);
        let (g0, s0) = b.join_predict(&[1, 2, 3], 16).expect("opens a batch");
        assert_eq!(s0, (0, 3));
        // 3 + 3 > 5: union cap rejects even though participants < 3.
        assert!(b.join_predict(&[4, 5, 6], 16).is_none());
        // 3 + 2 <= 5 fits.
        let (g1, s1) = b.join_predict(&[7, 8], 16).expect("joins under caps");
        assert_eq!(g1, g0);
        assert_eq!(s1, (3, 2));
        // Key mismatch rejects regardless of size.
        assert!(b.join_predict(&[9], 8).is_none());
        // Union is exactly full: even one more node is rejected.
        assert!(b.join_predict(&[9], 16).is_none());
        // Participant cap: shrink to a fresh batcher with roomy union.
        let b2 = Batcher::with_limits(2, 100, RESULT_TIMEOUT);
        b2.join_predict(&[1], 4).unwrap();
        b2.join_predict(&[2], 4).unwrap();
        assert!(
            b2.join_predict(&[3], 4).is_none(),
            "third participant must run solo"
        );
    }

    #[test]
    fn claim_path_sweeps_stale_done_entries() {
        // A timed-out participant's published entry must not linger
        // forever under quiescent traffic: the *claim* path sweeps
        // entries older than the (shrunken) result timeout — but only
        // after the claimant's own lookup, so a claimant descheduled
        // past the timeout still collects its result.
        let timeout = Duration::from_millis(25);
        let b = Batcher::with_limits(8, 512, timeout);
        {
            let mut slot = b.predicts.lock().unwrap();
            slot.done.insert(
                7,
                PredictDone {
                    mu: vec![1.0],
                    var: vec![2.0],
                    graph_version: 3,
                    rng_seq: 11,
                    n_snap: 4,
                    parts: 1,
                    claimed: 0,
                    published: std::time::Instant::now(),
                },
            );
        }
        std::thread::sleep(Duration::from_millis(60)); // age past timeout
        let claim = b
            .claim_predict(7, (0, 1))
            .expect("own aged entry must still be claimable");
        assert_eq!(claim.mu, vec![1.0]);
        assert_eq!(claim.var, vec![2.0]);
        assert_eq!(
            (claim.parts, claim.graph_version, claim.rng_seq, claim.n_snap),
            (1, 3, 11, 4)
        );
        // Generation 10: published, one of two participants claimed,
        // the other timed out — the lingering case. A later claim (even
        // one that itself times out) sweeps it.
        {
            let mut slot = b.predicts.lock().unwrap();
            slot.done.insert(
                10,
                PredictDone {
                    mu: vec![4.0],
                    var: vec![1.0],
                    graph_version: 0,
                    rng_seq: 0,
                    n_snap: 1,
                    parts: 2,
                    claimed: 1,
                    published: std::time::Instant::now(),
                },
            );
        }
        std::thread::sleep(Duration::from_millis(60)); // age it out
        assert!(
            b.claim_predict(99, (0, 0)).is_none(),
            "unpublished generation times out"
        );
        let slot = b.predicts.lock().unwrap();
        assert!(
            slot.done.is_empty(),
            "stale entry must be swept on the claim path"
        );
    }

    #[test]
    fn write_claim_sweeps_and_times_out() {
        let timeout = Duration::from_millis(25);
        let b = Batcher::with_limits(8, 512, timeout);
        {
            let mut slot = b.writes.lock().unwrap();
            slot.done.insert(
                3,
                WriteDone {
                    results: vec![Response::ok(vec![])],
                    claimed: 0,
                    published: std::time::Instant::now(),
                },
            );
        }
        std::thread::sleep(Duration::from_millis(60));
        // Claiming a generation that was never published times out
        // quickly under the shrunken timeout and sweeps the stale one.
        let started = std::time::Instant::now();
        assert!(b.claim_write(99, 0).is_none());
        assert!(started.elapsed() < Duration::from_secs(5));
        let slot = b.writes.lock().unwrap();
        assert!(slot.done.is_empty(), "stale write entry not swept");
    }

    #[test]
    fn response_serialises() {
        let r = Response::ok(vec![("x", Json::Num(1.0))]);
        let j = r.to_json().to_string();
        assert!(j.contains("\"ok\":true"));
        let e = Response::error("boom");
        let s = e.to_json().to_string();
        assert!(s.contains("boom"));
        assert!(s.contains("\"error_kind\":\"protocol\""), "{s}");
        let i = Response::fault(ErrorKind::Internal, "oops");
        assert!(i.to_json().to_string().contains("\"error_kind\":\"internal\""));
    }

    #[test]
    fn negative_or_fractional_ids_are_rejected_not_truncated() {
        // `-1 as usize` used to saturate to 0 — a silent write to node
        // 0. Every id field must reject non-index numbers outright.
        assert!(Request::parse(r#"{"op":"observe","node":-1,"y":0.5}"#).is_err());
        assert!(Request::parse(r#"{"op":"observe","node":1.5,"y":0.5}"#).is_err());
        assert!(Request::parse(r#"{"op":"predict","nodes":[0,-3]}"#).is_err());
        assert!(Request::parse(r#"{"op":"add_edge","u":-2,"v":1}"#).is_err());
        assert!(Request::parse(r#"{"op":"remove_edge","u":0,"v":-1}"#).is_err());
        // `samples` is a tuning knob, not an id: an unusable value
        // falls back to the default rather than failing the request.
        assert!(
            Request::parse(r#"{"op":"predict","nodes":[1],"samples":2.5}"#)
                .map(|r| r == Request::Predict { nodes: vec![1], samples: 16 })
                .unwrap_or(false),
            "absent-or-unusable samples falls back to the default"
        );
    }

    #[test]
    fn parse_metrics_op() {
        assert_eq!(
            Request::parse(r#"{"op":"metrics"}"#).unwrap(),
            Request::Metrics { prometheus: false }
        );
        assert_eq!(
            Request::parse(r#"{"op":"metrics","format":"json"}"#).unwrap(),
            Request::Metrics { prometheus: false }
        );
        assert_eq!(
            Request::parse(r#"{"op":"metrics","format":"prometheus"}"#).unwrap(),
            Request::Metrics { prometheus: true }
        );
        assert_eq!(
            Request::parse(r#"{"op":"metrics","format":"prom"}"#).unwrap(),
            Request::Metrics { prometheus: true }
        );
        assert!(Request::parse(r#"{"op":"metrics","format":"xml"}"#).is_err());
    }

    #[test]
    fn op_names_match_wire_ops() {
        // Every op name must round-trip through the parser back to the
        // same variant — the telemetry keys are derived from these.
        for (req, op) in [
            (Request::AddNode, "add_node"),
            (Request::Sample, "sample"),
            (Request::Thompson, "thompson"),
            (Request::Stats, "stats"),
            (Request::Shutdown, "shutdown"),
            (Request::Metrics { prometheus: false }, "metrics"),
        ] {
            assert_eq!(req.op_name(), op);
            assert_eq!(
                Request::parse(&format!(r#"{{"op":"{op}"}}"#)).unwrap(),
                req
            );
        }
        assert_eq!(Request::Observe { node: 0, y: 0.0 }.op_name(), "observe");
        assert_eq!(
            Request::Predict { nodes: vec![], samples: 1 }.op_name(),
            "predict"
        );
        assert_eq!(Request::AddEdge { u: 0, v: 1, w: 1.0 }.op_name(), "add_edge");
        assert_eq!(Request::RemoveEdge { u: 0, v: 1 }.op_name(), "remove_edge");
        assert_eq!(Request::Fault { locked: false }.op_name(), "fault");
    }

    #[test]
    fn parse_fault_op() {
        assert_eq!(
            Request::parse(r#"{"op":"fault","mode":"panic"}"#).unwrap(),
            Request::Fault { locked: false }
        );
        assert_eq!(
            Request::parse(r#"{"op":"fault","mode":"panic_locked"}"#).unwrap(),
            Request::Fault { locked: true }
        );
        assert!(Request::parse(r#"{"op":"fault"}"#).is_err());
        assert!(Request::parse(r#"{"op":"fault","mode":"rm -rf"}"#).is_err());
    }
}
