//! Request parsing + micro-batching.
//!
//! The batcher coalesces requests that can share one model-lock
//! acquisition. Predict requests arriving within the batching window
//! are merged into a single `predict` over the union of their nodes
//! (the expensive part — posterior mean solve + pathwise variance
//! samples — is shared), then results are scattered back per request.

use super::ServerState;
use crate::util::json::Json;
use std::sync::{Condvar, Mutex};

/// Parsed request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Observe { node: usize, y: f64 },
    Predict { nodes: Vec<usize>, samples: usize },
    Sample,
    Thompson,
    Stats,
    Shutdown,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad JSON: {e}"))?;
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or("missing op".to_string())?;
        match op {
            "observe" => {
                let node = j
                    .get("node")
                    .and_then(Json::as_usize)
                    .ok_or("observe needs node")?;
                let y = j
                    .get("y")
                    .and_then(Json::as_f64)
                    .ok_or("observe needs y")?;
                Ok(Request::Observe { node, y })
            }
            "predict" => {
                let nodes = j
                    .get("nodes")
                    .and_then(|a| a.as_arr())
                    .ok_or("predict needs nodes")?
                    .iter()
                    .map(|v| v.as_usize().ok_or("bad node id"))
                    .collect::<Result<Vec<_>, _>>()?;
                let samples =
                    j.get("samples").and_then(Json::as_usize).unwrap_or(16);
                Ok(Request::Predict { nodes, samples })
            }
            "sample" => Ok(Request::Sample),
            "thompson" => Ok(Request::Thompson),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    fn batch_key(&self) -> Option<usize> {
        match self {
            Request::Predict { samples, .. } => Some(*samples),
            _ => None,
        }
    }
}

/// Response wrapper.
#[derive(Clone, Debug)]
pub struct Response {
    pub ok: bool,
    pub fields: Vec<(String, Json)>,
}

impl Response {
    pub fn ok(fields: Vec<(&str, Json)>) -> Response {
        Response {
            ok: true,
            fields: fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        }
    }

    pub fn error(msg: impl Into<String>) -> Response {
        Response {
            ok: false,
            fields: vec![("error".to_string(), Json::Str(msg.into()))],
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj: Vec<(&str, Json)> =
            vec![("ok", Json::Bool(self.ok))];
        for (k, v) in &self.fields {
            obj.push((k.as_str(), v.clone()));
        }
        Json::obj(obj)
    }
}

struct PendingBatch {
    key: usize,
    nodes: Vec<usize>,
    /// (offset, len) per participant, in arrival order.
    spans: Vec<(usize, usize)>,
    /// Results, filled by the leader.
    result: Option<(Vec<f64>, Vec<f64>)>,
    generation: u64,
}

/// Micro-batcher: the first predict request in a window becomes the
/// leader; followers that arrive while the leader is waiting join the
/// batch. `max_batch` bounds the union size.
pub struct Batcher {
    max_batch: usize,
    pending: Mutex<Option<PendingBatch>>,
    cv: Condvar,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher {
            max_batch,
            pending: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    /// Execute a request, batching predicts.
    pub fn submit(&self, state: &ServerState, req: Request) -> Response {
        let Some(key) = req.batch_key() else {
            return super::handle(state, &req);
        };
        let Request::Predict { nodes, samples } = req else {
            unreachable!()
        };
        // Try to join or create a batch.
        let (generation, span) = {
            let mut guard = self.pending.lock().unwrap();
            match guard.as_mut() {
                Some(b)
                    if b.key == key
                        && b.result.is_none()
                        && b.spans.len() < self.max_batch =>
                {
                    let span = (b.nodes.len(), nodes.len());
                    b.nodes.extend_from_slice(&nodes);
                    b.spans.push(span);
                    (b.generation, span)
                }
                _ => {
                    let generation = guard
                        .as_ref()
                        .map(|b| b.generation + 1)
                        .unwrap_or(0);
                    *guard = Some(PendingBatch {
                        key,
                        nodes: nodes.clone(),
                        spans: vec![(0, nodes.len())],
                        result: None,
                        generation,
                    });
                    (generation, (0, nodes.len()))
                }
            }
        };
        // Tiny batching window so concurrent clients can pile on.
        std::thread::sleep(std::time::Duration::from_millis(2));
        // Leader = whoever gets the lock first with result unset.
        let mut guard = self.pending.lock().unwrap();
        let needs_run = matches!(
            guard.as_ref(),
            Some(b) if b.generation == generation && b.result.is_none()
        );
        if needs_run {
            let batch_nodes = guard.as_ref().unwrap().nodes.clone();
            drop(guard);
            let full = {
                let mut ms = state.model.lock().unwrap();
                let mut rng = ms.rng.split(0xBA7C);
                ms.rng = ms.rng.split(3);
                ms.model.predict(key, &mut rng)
            };
            let mut g2 = self.pending.lock().unwrap();
            if let Some(b) = g2.as_mut() {
                if b.generation == generation {
                    let mu: Vec<f64> =
                        batch_nodes.iter().map(|&i| full.0[i]).collect();
                    let var: Vec<f64> =
                        batch_nodes.iter().map(|&i| full.1[i]).collect();
                    b.result = Some((mu, var));
                }
            }
            self.cv.notify_all();
            guard = g2;
        }
        // Wait for the leader (or ourselves) to have filled results.
        loop {
            match guard.as_ref() {
                Some(b) if b.generation == generation => {
                    if let Some((mu, var)) = &b.result {
                        let (off, len) = span;
                        let m = mu[off..off + len].to_vec();
                        let v = var[off..off + len].to_vec();
                        state
                            .requests_served
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        return Response::ok(vec![
                            ("mean", Json::arr_f64(&m)),
                            ("var", Json::arr_f64(&v)),
                            ("batched", Json::Num(b.spans.len() as f64)),
                        ]);
                    }
                }
                _ => {
                    return Response::error("batch evicted before completion")
                }
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(guard, std::time::Duration::from_secs(5))
                .unwrap();
            guard = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_ops() {
        assert_eq!(
            Request::parse(r#"{"op":"observe","node":3,"y":1.5}"#).unwrap(),
            Request::Observe { node: 3, y: 1.5 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"predict","nodes":[1,2]}"#).unwrap(),
            Request::Predict { nodes: vec![1, 2], samples: 16 }
        );
        assert_eq!(Request::parse(r#"{"op":"sample"}"#).unwrap(), Request::Sample);
        assert_eq!(
            Request::parse(r#"{"op":"thompson"}"#).unwrap(),
            Request::Thompson
        );
        assert_eq!(Request::parse(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn response_serialises() {
        let r = Response::ok(vec![("x", Json::Num(1.0))]);
        let j = r.to_json().to_string();
        assert!(j.contains("\"ok\":true"));
        let e = Response::error("boom");
        assert!(e.to_json().to_string().contains("boom"));
    }
}
