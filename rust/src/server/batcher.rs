//! Request parsing + micro-batching.
//!
//! The batcher coalesces requests that can share one model-lock
//! acquisition, in two classes:
//!
//! * **Predict** requests arriving within the batching window are
//!   merged into a single `predict` over the union of their nodes (the
//!   expensive part — posterior mean solve + pathwise variance samples
//!   — is shared), then results are scattered back per request.
//! * **Write** requests (`observe`, `add_edge`, `remove_edge`,
//!   `add_node`) are coalesced into one ordered batch applied under a
//!   single lock: runs of observations flush with one `set_data`, and
//!   each graph delta runs one incremental feature patch + warm
//!   re-solve ([`crate::gp::GpModel::apply_graph_delta`]).
//!
//! Leadership is take-based: after the window, whichever participant
//! still finds its batch pending takes it out, runs it, and publishes
//! the results in a per-generation `done` map that participants drain
//! (entries are removed once every span is claimed). A pending batch
//! is never replaced: requests that cannot join (key mismatch, full
//! batch) execute solo instead, so a batch can never be evicted
//! before its results reach every client. An **idle fast path** skips
//! the batching window when the model lock is uncontended — there is
//! nothing to coalesce with, so serial clients pay no window latency.

use super::{ModelState, ServerState};
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Parsed request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Observe { node: usize, y: f64 },
    Predict { nodes: Vec<usize>, samples: usize },
    AddEdge { u: usize, v: usize, w: f64 },
    RemoveEdge { u: usize, v: usize },
    AddNode,
    Sample,
    Thompson,
    Stats,
    Shutdown,
}

/// How the batcher routes a request.
enum BatchClass {
    Direct,
    Predict(usize),
    Write,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad JSON: {e}"))?;
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or("missing op".to_string())?;
        match op {
            "observe" => {
                let node = j
                    .get("node")
                    .and_then(Json::as_usize)
                    .ok_or("observe needs node")?;
                let y = j
                    .get("y")
                    .and_then(Json::as_f64)
                    .ok_or("observe needs y")?;
                Ok(Request::Observe { node, y })
            }
            "predict" => {
                let nodes = j
                    .get("nodes")
                    .and_then(|a| a.as_arr())
                    .ok_or("predict needs nodes")?
                    .iter()
                    .map(|v| v.as_usize().ok_or("bad node id"))
                    .collect::<Result<Vec<_>, _>>()?;
                let samples =
                    j.get("samples").and_then(Json::as_usize).unwrap_or(16);
                Ok(Request::Predict { nodes, samples })
            }
            "add_edge" => {
                let u = j
                    .get("u")
                    .and_then(Json::as_usize)
                    .ok_or("add_edge needs u")?;
                let v = j
                    .get("v")
                    .and_then(Json::as_usize)
                    .ok_or("add_edge needs v")?;
                let w = j.get("w").and_then(Json::as_f64).unwrap_or(1.0);
                Ok(Request::AddEdge { u, v, w })
            }
            "remove_edge" => {
                let u = j
                    .get("u")
                    .and_then(Json::as_usize)
                    .ok_or("remove_edge needs u")?;
                let v = j
                    .get("v")
                    .and_then(Json::as_usize)
                    .ok_or("remove_edge needs v")?;
                Ok(Request::RemoveEdge { u, v })
            }
            "add_node" => Ok(Request::AddNode),
            "sample" => Ok(Request::Sample),
            "thompson" => Ok(Request::Thompson),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    fn class(&self) -> BatchClass {
        match self {
            Request::Predict { samples, .. } => BatchClass::Predict(*samples),
            Request::Observe { .. }
            | Request::AddEdge { .. }
            | Request::RemoveEdge { .. }
            | Request::AddNode => BatchClass::Write,
            _ => BatchClass::Direct,
        }
    }
}

/// Response wrapper.
#[derive(Clone, Debug)]
pub struct Response {
    pub ok: bool,
    pub fields: Vec<(String, Json)>,
}

impl Response {
    pub fn ok(fields: Vec<(&str, Json)>) -> Response {
        Response {
            ok: true,
            fields: fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        }
    }

    pub fn error(msg: impl Into<String>) -> Response {
        Response {
            ok: false,
            fields: vec![("error".to_string(), Json::Str(msg.into()))],
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj: Vec<(&str, Json)> =
            vec![("ok", Json::Bool(self.ok))];
        for (k, v) in &self.fields {
            obj.push((k.as_str(), v.clone()));
        }
        Json::obj(obj)
    }
}

struct PendingPredict {
    generation: u64,
    /// Batch key: the `samples` parameter (requests must agree on it).
    key: usize,
    nodes: Vec<usize>,
    /// (offset, len) per participant, in arrival order.
    spans: Vec<(usize, usize)>,
}

struct PredictDone {
    mu: Vec<f64>,
    var: Vec<f64>,
    /// Graph version at compute time — lets clients detect whether a
    /// response predates a graph delta they already saw acknowledged.
    graph_version: u64,
    parts: usize,
    claimed: usize,
    /// Publication time: entries older than [`RESULT_TIMEOUT`] can have
    /// no live claimant (every deadline predates publication + timeout)
    /// and are swept.
    published: std::time::Instant,
}

struct PendingWrites {
    generation: u64,
    reqs: Vec<Request>,
}

struct WriteDone {
    results: Vec<Response>,
    claimed: usize,
    /// See [`PredictDone::published`].
    published: std::time::Instant,
}

struct PredictSlot {
    next_gen: u64,
    pending: Option<PendingPredict>,
    done: HashMap<u64, PredictDone>,
}

struct WriteSlot {
    next_gen: u64,
    pending: Option<PendingWrites>,
    done: HashMap<u64, WriteDone>,
}

/// Micro-batcher: the first request of a class in a window opens a
/// batch; compatible requests arriving while it is pending join it.
/// `max_batch` bounds the union size of a predict batch and the length
/// of a write batch.
pub struct Batcher {
    max_batch: usize,
    predicts: Mutex<PredictSlot>,
    pcv: Condvar,
    writes: Mutex<WriteSlot>,
    wcv: Condvar,
}

/// How long a joiner waits for stragglers before taking leadership.
const BATCH_WINDOW: Duration = Duration::from_millis(2);
/// Upper bound on waiting for a leader's results.
const RESULT_TIMEOUT: Duration = Duration::from_secs(30);

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher {
            max_batch,
            predicts: Mutex::new(PredictSlot {
                next_gen: 0,
                pending: None,
                done: HashMap::new(),
            }),
            pcv: Condvar::new(),
            writes: Mutex::new(WriteSlot {
                next_gen: 0,
                pending: None,
                done: HashMap::new(),
            }),
            wcv: Condvar::new(),
        }
    }

    /// Execute a request, batching predicts and writes.
    pub fn submit(&self, state: &ServerState, req: Request) -> Response {
        match req.class() {
            BatchClass::Direct => super::handle(state, &req),
            BatchClass::Write => self.submit_write(state, req),
            BatchClass::Predict(key) => {
                let Request::Predict { nodes, .. } = req else {
                    unreachable!()
                };
                self.submit_predict(state, nodes, key)
            }
        }
    }

    /// Shared-lock predict computation + result gather + version stamp.
    fn predict_under_lock(
        state: &ServerState,
        ms: &mut ModelState,
        nodes: &[usize],
        key: usize,
    ) -> (Vec<f64>, Vec<f64>, u64) {
        let mut rng = ms.rng.split(0xBA7C);
        ms.rng = ms.rng.split(3);
        let (mean, variance) = ms.model.predict(key, &mut rng);
        let mu: Vec<f64> = nodes.iter().map(|&i| mean[i]).collect();
        let vv: Vec<f64> = nodes.iter().map(|&i| variance[i]).collect();
        // Read the version inside the lock: the response is exactly as
        // fresh as this snapshot.
        (mu, vv, state.graph_version.load(Ordering::SeqCst))
    }

    fn predict_response(mu: &[f64], var: &[f64], parts: usize, version: u64) -> Response {
        Response::ok(vec![
            ("mean", Json::arr_f64(mu)),
            ("var", Json::arr_f64(var)),
            ("batched", Json::Num(parts as f64)),
            ("graph_version", Json::Num(version as f64)),
        ])
    }

    fn submit_predict(
        &self,
        state: &ServerState,
        nodes: Vec<usize>,
        key: usize,
    ) -> Response {
        // Validate up front against the lock-free node-count mirror
        // (nodes stay valid: the graph only grows, and the mirror is
        // updated before any delta is acknowledged).
        let n = state.n_nodes.load(Ordering::SeqCst);
        if let Some(&bad) = nodes.iter().find(|&&i| i >= n) {
            return Response::error(format!("node {bad} out of range"));
        }
        // Idle fast path: an uncontended model means there is nothing
        // to coalesce with — skip the batching window entirely.
        if let Ok(mut ms) = state.model.try_lock() {
            let (mu, var, version) =
                Self::predict_under_lock(state, &mut ms, &nodes, key);
            drop(ms);
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return Self::predict_response(&mu, &var, 1, version);
        }
        // Join the pending batch if compatible, open one if none is
        // pending; an incompatible pending batch (different samples
        // key, or full) is left intact and this request runs solo.
        let joined = {
            let mut slot = self.predicts.lock().unwrap();
            match slot.pending.as_mut() {
                Some(b) if b.key == key && b.spans.len() < self.max_batch => {
                    let span = (b.nodes.len(), nodes.len());
                    b.nodes.extend_from_slice(&nodes);
                    b.spans.push(span);
                    Some((b.generation, span))
                }
                Some(_) => None,
                None => {
                    let generation = slot.next_gen;
                    slot.next_gen += 1;
                    let span = (0, nodes.len());
                    slot.pending = Some(PendingPredict {
                        generation,
                        key,
                        nodes: nodes.clone(),
                        spans: vec![span],
                    });
                    Some((generation, span))
                }
            }
        };
        let Some((generation, span)) = joined else {
            // Solo slow path (blocking lock).
            let mut ms = state.model.lock().unwrap();
            let (mu, var, version) =
                Self::predict_under_lock(state, &mut ms, &nodes, key);
            drop(ms);
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return Self::predict_response(&mu, &var, 1, version);
        };
        std::thread::sleep(BATCH_WINDOW);
        // Leader = whoever still finds its batch pending; it takes the
        // batch out, so late arrivals open a fresh one.
        let batch = {
            let mut slot = self.predicts.lock().unwrap();
            let mine = matches!(
                slot.pending.as_ref(),
                Some(b) if b.generation == generation
            );
            if mine {
                slot.pending.take()
            } else {
                None
            }
        };
        if let Some(b) = batch {
            let (mu, var, version) = {
                let mut ms = state.model.lock().unwrap();
                Self::predict_under_lock(state, &mut ms, &b.nodes, b.key)
            };
            let mut slot = self.predicts.lock().unwrap();
            // Bounded-stale sweep: a participant that timed out never
            // claims its span, so its entry could linger — drop entries
            // older than the claim deadline (no live claimant remains;
            // claimants' deadlines start before publication).
            slot.done
                .retain(|_, d| d.published.elapsed() < RESULT_TIMEOUT);
            slot.done.insert(
                b.generation,
                PredictDone {
                    mu,
                    var,
                    graph_version: version,
                    parts: b.spans.len(),
                    claimed: 0,
                    published: std::time::Instant::now(),
                },
            );
            drop(slot);
            self.pcv.notify_all();
        }
        // Claim this request's span of the published results (hard
        // deadline — spurious wakeups from other batches must not
        // restart the clock).
        let deadline = std::time::Instant::now() + RESULT_TIMEOUT;
        let mut slot = self.predicts.lock().unwrap();
        loop {
            if let Some(done) = slot.done.get_mut(&generation) {
                let (off, len) = span;
                let m = done.mu[off..off + len].to_vec();
                let v = done.var[off..off + len].to_vec();
                let parts = done.parts;
                let version = done.graph_version;
                done.claimed += 1;
                if done.claimed >= parts {
                    slot.done.remove(&generation);
                }
                state
                    .requests_served
                    .fetch_add(1, Ordering::Relaxed);
                return Self::predict_response(&m, &v, parts, version);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Response::error("predict batch timed out");
            }
            let (g, _) = self.pcv.wait_timeout(slot, deadline - now).unwrap();
            slot = g;
        }
    }

    fn submit_write(&self, state: &ServerState, req: Request) -> Response {
        // Idle fast path: uncontended model → apply immediately; the
        // common serial-client observe stream pays no window latency.
        if let Ok(mut ms) = state.model.try_lock() {
            let resp = ms
                .apply_writes(std::slice::from_ref(&req), state)
                .pop()
                .expect("one response per write");
            drop(ms);
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return resp;
        }
        // Join the pending write batch, open one if none is pending; a
        // full batch is left intact and this request runs solo.
        let joined = {
            let mut slot = self.writes.lock().unwrap();
            match slot.pending.as_mut() {
                Some(b) if b.reqs.len() < self.max_batch => {
                    b.reqs.push(req.clone());
                    Some((b.generation, b.reqs.len() - 1))
                }
                Some(_) => None,
                None => {
                    let generation = slot.next_gen;
                    slot.next_gen += 1;
                    slot.pending = Some(PendingWrites {
                        generation,
                        reqs: vec![req.clone()],
                    });
                    Some((generation, 0))
                }
            }
        };
        let Some((generation, idx)) = joined else {
            // Solo slow path (blocking lock), preserving write order
            // within this connection.
            let mut ms = state.model.lock().unwrap();
            let resp = ms
                .apply_writes(std::slice::from_ref(&req), state)
                .pop()
                .expect("one response per write");
            drop(ms);
            state.requests_served.fetch_add(1, Ordering::Relaxed);
            return resp;
        };
        std::thread::sleep(BATCH_WINDOW);
        let batch = {
            let mut slot = self.writes.lock().unwrap();
            let mine = matches!(
                slot.pending.as_ref(),
                Some(b) if b.generation == generation
            );
            if mine {
                slot.pending.take()
            } else {
                None
            }
        };
        if let Some(b) = batch {
            let results = {
                let mut ms = state.model.lock().unwrap();
                ms.apply_writes(&b.reqs, state)
            };
            let mut slot = self.writes.lock().unwrap();
            slot.done
                .retain(|_, d| d.published.elapsed() < RESULT_TIMEOUT);
            slot.done.insert(
                b.generation,
                WriteDone {
                    results,
                    claimed: 0,
                    published: std::time::Instant::now(),
                },
            );
            drop(slot);
            self.wcv.notify_all();
        }
        let deadline = std::time::Instant::now() + RESULT_TIMEOUT;
        let mut slot = self.writes.lock().unwrap();
        loop {
            if let Some(done) = slot.done.get_mut(&generation) {
                let resp = done
                    .results
                    .get(idx)
                    .cloned()
                    .unwrap_or_else(|| {
                        Response::error("write batch result missing")
                    });
                done.claimed += 1;
                if done.claimed >= done.results.len() {
                    slot.done.remove(&generation);
                }
                state
                    .requests_served
                    .fetch_add(1, Ordering::Relaxed);
                return resp;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Response::error("write batch timed out");
            }
            let (g, _) = self.wcv.wait_timeout(slot, deadline - now).unwrap();
            slot = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_ops() {
        assert_eq!(
            Request::parse(r#"{"op":"observe","node":3,"y":1.5}"#).unwrap(),
            Request::Observe { node: 3, y: 1.5 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"predict","nodes":[1,2]}"#).unwrap(),
            Request::Predict { nodes: vec![1, 2], samples: 16 }
        );
        assert_eq!(Request::parse(r#"{"op":"sample"}"#).unwrap(), Request::Sample);
        assert_eq!(
            Request::parse(r#"{"op":"thompson"}"#).unwrap(),
            Request::Thompson
        );
        assert_eq!(Request::parse(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn parse_graph_mutation_ops() {
        assert_eq!(
            Request::parse(r#"{"op":"add_edge","u":3,"v":7,"w":0.5}"#).unwrap(),
            Request::AddEdge { u: 3, v: 7, w: 0.5 }
        );
        // Weight defaults to 1.0.
        assert_eq!(
            Request::parse(r#"{"op":"add_edge","u":1,"v":2}"#).unwrap(),
            Request::AddEdge { u: 1, v: 2, w: 1.0 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"remove_edge","u":4,"v":0}"#).unwrap(),
            Request::RemoveEdge { u: 4, v: 0 }
        );
        assert_eq!(
            Request::parse(r#"{"op":"add_node"}"#).unwrap(),
            Request::AddNode
        );
        assert!(Request::parse(r#"{"op":"add_edge","u":1}"#).is_err());
        assert!(Request::parse(r#"{"op":"remove_edge","v":1}"#).is_err());
    }

    #[test]
    fn response_serialises() {
        let r = Response::ok(vec![("x", Json::Num(1.0))]);
        let j = r.to_json().to_string();
        assert!(j.contains("\"ok\":true"));
        let e = Response::error("boom");
        assert!(e.to_json().to_string().contains("boom"));
    }
}
