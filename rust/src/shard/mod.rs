//! Node-partitioned shard layer: per-shard feature maintenance that
//! composes **bitwise** into the unsharded engine.
//!
//! GRFs are embarrassingly parallel across source nodes — node `i`'s
//! feature row is a pure function of (graph, seed, i) through the
//! per-walk RNG streams ([`crate::walks::walk_rng`]). A shard therefore
//! owns a *subset of rows*, not a subgraph: every shard keeps the full
//! graph (topology is shared, cheap, and needed to replay any walk that
//! wanders across the partition), but samples and maintains only the
//! walks sourced at its own nodes. Composition is pure row routing:
//!
//! * **Partition rule** ([`Partition`]): round-robin `owner(i) = i mod
//!   S`. Stays balanced as [`crate::stream::GraphDelta::AddNode`]
//!   appends rows, and is a pure function of the node id — no routing
//!   table to maintain or replicate.
//! * **Delta fan-out** ([`ShardedFeatures::apply_delta_batch`]): the
//!   same validated batch goes to every shard. Each shard applies the
//!   graph mutations to its replica and resamples the invalidated walks
//!   *it owns* — a cross-shard edge `(u, v)` invalidates walks on both
//!   endpoints' owners and on any third shard whose walks stepped
//!   through `u` or `v`, exactly today's union rule restricted to each
//!   shard's visit index. Owners patch only their own Φ rows, so the
//!   shards' row sets stay disjoint and their union is exactly the
//!   unsharded resample set (the per-shard hub cap may saturate at
//!   different times than the global one, which only ever *widens* a
//!   shard's resample set — replayed walks are bit-identical, so Φ is
//!   unchanged; see the hub-cap section of [`crate::stream`]).
//! * **Operand composition** ([`ShardedOverlay`]): Φ and Φᵀ live as one
//!   [`RowOverlay`] per shard, each holding the full logical shape with
//!   only the owned rows nonzero. Every kernel computes output row `i`
//!   with the exact CSR per-row accumulation against the owner's
//!   storage — same entries, same order, same f64 additions — so SpMV,
//!   SpMM and the incremental transpose maintenance are **bitwise**
//!   equal to the unsharded [`RowOverlay`] on the same logical matrix.
//!   (No partial sums are ever combined across shards: summing
//!   per-shard partial vectors would reassociate floating-point adds.)
//! * **ELL**: the packed fast path is not offered while sharded
//!   ([`Operand::select_ell`] returns `None`) — per-part packing is
//!   future work; the per-row dispatch kernels serve, exactly as they
//!   do between compactions today.
//!
//! Φᵀ is partitioned by the *same* node partition (its rows are feature
//! columns ≡ nodes), and maintained by a sharded mirror of
//! [`RowOverlay::patch_transpose_rows`] with an identical per-row merge
//! — the only difference is which part a merged row is staged into.
//!
//! Per-shard compaction cadences legitimately drift from the unsharded
//! engine (each shard's overlay fills at its own rate); this is
//! observability-only and excluded from the bitwise contract, which
//! covers Φ, Φᵀ, predictions, and `graph_version` stamps (property
//! suite in `tests/shard.rs`, shard counts driven by
//! `GRFGP_TEST_SHARDS` in CI).

use crate::graph::Graph;
use crate::obs;
use crate::sparse::{Csr, Ell, FeatureLayout, RowOverlay};
use crate::stream::{BatchSummary, DeltaAck, DeltaEngine, GraphDelta, StreamingFeatures};
use crate::util::parallel;
use crate::walks::{WalkComponents, WalkConfig};
use std::collections::{BTreeMap, BTreeSet};

/// The node → shard map: round-robin by node id (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    n_shards: u32,
}

impl Partition {
    pub fn new(n_shards: usize) -> Partition {
        assert!(n_shards > 0, "at least one shard");
        assert!(n_shards <= u32::MAX as usize, "shard count overflow");
        Partition { n_shards: n_shards as u32 }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards as usize
    }

    /// The shard that owns node `i`'s walks and feature row.
    #[inline]
    pub fn owner(&self, i: usize) -> usize {
        (i as u32 % self.n_shards) as usize
    }
}

/// Build a canonical CSR from per-row content (cols already sorted).
///
/// Rows are emitted in order with their given value bits — unlike
/// [`crate::sparse::CooBuilder`] this performs no merge and never drops
/// explicit entries, so a composed matrix is bitwise the row
/// concatenation of its sources.
fn csr_from_rows(
    n_rows: usize,
    n_cols: usize,
    mut row: impl FnMut(usize) -> (Vec<u32>, Vec<f64>),
) -> Csr {
    let mut offsets = Vec::with_capacity(n_rows + 1);
    offsets.push(0usize);
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for r in 0..n_rows {
        let (rc, rv) = row(r);
        debug_assert_eq!(rc.len(), rv.len());
        debug_assert!(rc.windows(2).all(|w| w[0] < w[1]));
        cols.extend_from_slice(&rc);
        vals.extend_from_slice(&rv);
        offsets.push(cols.len());
    }
    Csr { n_rows, n_cols, offsets, cols, vals }
}

// ---------------------------------------------------------------------
// Sharded feature maintenance
// ---------------------------------------------------------------------

/// `S` partition-filtered [`StreamingFeatures`] engines plus the fan-out
/// that keeps them in lockstep (module docs). Shard `s` samples and
/// maintains exactly the walks of nodes with `owner(i) == s`; its
/// component matrices and Φ hold full logical shape with only those
/// rows nonzero.
pub struct ShardedFeatures {
    partition: Partition,
    shards: Vec<StreamingFeatures>,
}

impl ShardedFeatures {
    /// Sample every shard's owned rows under the shared `seed`. Each
    /// walk is seeded by `(seed, node, walk)` alone, so the union over
    /// shards is bitwise the unsharded sample.
    pub fn new(
        graph: Graph,
        cfg: WalkConfig,
        f: Vec<f64>,
        seed: u64,
        n_shards: usize,
    ) -> ShardedFeatures {
        let partition = Partition::new(n_shards);
        let shards = (0..n_shards as u32)
            .map(|s| {
                StreamingFeatures::new_owned(
                    graph.clone(),
                    cfg.clone(),
                    f.clone(),
                    seed,
                    Some((s, n_shards as u32)),
                )
            })
            .collect();
        ShardedFeatures { partition, shards }
    }

    pub fn partition(&self) -> Partition {
        self.partition
    }

    pub fn n_shards(&self) -> usize {
        self.partition.n_shards()
    }

    /// The per-shard engines (tests / diagnostics).
    pub fn shards(&self) -> &[StreamingFeatures] {
        &self.shards
    }

    pub fn n(&self) -> usize {
        self.shards[0].n()
    }

    /// The shared graph replica (shard 0's copy; all replicas apply the
    /// same validated mutation stream, so they are identical).
    pub fn graph(&self) -> &Graph {
        self.shards[0].graph()
    }

    pub fn config(&self) -> &WalkConfig {
        self.shards[0].config()
    }

    pub fn seed(&self) -> u64 {
        self.shards[0].seed()
    }

    pub fn modulation(&self) -> &[f64] {
        self.shards[0].modulation()
    }

    /// Overlay rows staged across all shards.
    pub fn overlay_rows(&self) -> usize {
        self.shards.iter().map(|s| s.overlay_rows()).sum()
    }

    /// Saturated hub entries summed over the per-shard visit indices
    /// (a node can saturate on several shards independently).
    pub fn saturated_hubs(&self) -> usize {
        self.shards.iter().map(|s| s.saturated_hubs()).sum()
    }

    /// Batches applied (identical on every shard; shard 0 reports).
    pub fn deltas_applied(&self) -> usize {
        self.shards[0].deltas_applied
    }

    /// Walks resampled summed over shards. May exceed the unsharded
    /// count when a per-shard hub cap saturates earlier than the global
    /// one would (superset resamples — observability only).
    pub fn walks_resampled_total(&self) -> usize {
        self.shards.iter().map(|s| s.walks_resampled_total).sum()
    }

    /// Overlay compactions summed over shards (cadences drift per
    /// shard; see module docs).
    pub fn compactions(&self) -> usize {
        self.shards.iter().map(|s| s.compactions).sum()
    }

    pub fn set_hub_cap(&mut self, k: usize) {
        for s in &mut self.shards {
            s.set_hub_cap(k);
        }
    }

    pub fn set_compact_threshold(&mut self, rows: usize) {
        for s in &mut self.shards {
            s.set_compact_threshold(rows);
        }
    }

    /// Compose the per-shard component matrices into the full
    /// [`WalkComponents`] by row routing — bitwise the unsharded
    /// sampler's output.
    pub fn components(&self) -> WalkComponents {
        let n = self.n();
        let n_len = self.config().max_len + 1;
        let c = (0..n_len)
            .map(|l| {
                csr_from_rows(n, n, |r| {
                    self.shards[self.partition.owner(r)].component_row(l, r)
                })
            })
            .collect();
        WalkComponents::new(c)
    }

    /// Compose the current Φ by row routing (see
    /// [`StreamingFeatures::phi_snapshot`]).
    pub fn phi_snapshot(&self) -> Csr {
        let n = self.n();
        let snaps: Vec<Csr> = self.shards.iter().map(|s| s.phi_snapshot()).collect();
        csr_from_rows(n, n, |r| {
            let (c, v) = snaps[self.partition.owner(r)].row(r);
            (c.to_vec(), v.to_vec())
        })
    }

    /// Fan one validated batch out to every shard in parallel and
    /// compose the per-shard outcomes (module docs). Validation is
    /// deterministic and runs against identical graph replicas, so the
    /// shards unanimously accept (and mutate) or unanimously reject
    /// (and stay untouched) — the composed engine keeps the
    /// errors-leave-state-untouched contract of the trait.
    pub fn apply_delta_batch(
        &mut self,
        deltas: &[GraphDelta],
    ) -> Result<BatchSummary, String> {
        let results: Vec<Result<BatchSummary, String>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .shards
                    .iter_mut()
                    .enumerate()
                    .map(|(s, shard)| {
                        scope.spawn(move || {
                            let (walks_c, rows_c, ns_h) =
                                obs::registry::shard_metrics(s);
                            let (res, _secs) = obs::span::timed(ns_h, || {
                                shard.apply_delta_batch(deltas)
                            });
                            if let Ok(sum) = &res {
                                walks_c.add(sum.resampled.len() as u64);
                                rows_c.add(sum.affected_rows.len() as u64);
                            }
                            res
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard worker panicked"))
                    .collect()
            });
        let mut summaries = Vec::with_capacity(results.len());
        for r in results {
            summaries.push(r?);
        }
        let mut deltas_out = vec![
            DeltaAck { invalidated: 0, added_node: None };
            deltas.len()
        ];
        let mut resampled = Vec::new();
        let mut affected_rows = Vec::new();
        let mut compacted = false;
        for sum in &summaries {
            for (ack, sa) in deltas_out.iter_mut().zip(&sum.deltas) {
                // Per-shard invalidation sets are disjoint (each shard
                // only tracks walks it owns), so the composed count is
                // their sum.
                ack.invalidated += sa.invalidated;
                ack.added_node = ack.added_node.or(sa.added_node);
            }
            resampled.extend_from_slice(&sum.resampled);
            affected_rows.extend_from_slice(&sum.affected_rows);
            compacted |= sum.compacted;
        }
        // Disjoint-by-owner, so sorting restores the unsharded order.
        resampled.sort_unstable();
        affected_rows.sort_unstable();
        Ok(BatchSummary {
            deltas: deltas_out,
            resampled,
            affected_rows,
            compacted,
        })
    }
}

impl DeltaEngine for ShardedFeatures {
    fn n(&self) -> usize {
        ShardedFeatures::n(self)
    }

    fn walk_config(&self) -> &WalkConfig {
        self.config()
    }

    fn apply_delta_batch(&mut self, deltas: &[GraphDelta]) -> Result<BatchSummary, String> {
        ShardedFeatures::apply_delta_batch(self, deltas)
    }

    fn component_row(&self, l: usize, r: usize) -> (Vec<u32>, Vec<f64>) {
        self.shards[self.partition.owner(r)].component_row(l, r)
    }
}

/// The server-facing engine: one handle over either maintenance mode,
/// so `ModelState` and the wire handlers stay shard-agnostic.
pub enum FeatureEngine {
    /// Single-engine path (today's default).
    Mono(StreamingFeatures),
    /// Partitioned path behind `--shards`.
    Sharded(ShardedFeatures),
}

impl FeatureEngine {
    pub fn n(&self) -> usize {
        match self {
            FeatureEngine::Mono(s) => s.n(),
            FeatureEngine::Sharded(s) => s.n(),
        }
    }

    /// Shard count (1 for the mono path).
    pub fn n_shards(&self) -> usize {
        match self {
            FeatureEngine::Mono(_) => 1,
            FeatureEngine::Sharded(s) => s.n_shards(),
        }
    }

    /// The partition when sharded.
    pub fn partition(&self) -> Option<Partition> {
        match self {
            FeatureEngine::Mono(_) => None,
            FeatureEngine::Sharded(s) => Some(s.partition()),
        }
    }

    pub fn graph(&self) -> &Graph {
        match self {
            FeatureEngine::Mono(s) => s.graph(),
            FeatureEngine::Sharded(s) => s.graph(),
        }
    }

    pub fn config(&self) -> &WalkConfig {
        match self {
            FeatureEngine::Mono(s) => s.config(),
            FeatureEngine::Sharded(s) => s.config(),
        }
    }

    pub fn seed(&self) -> u64 {
        match self {
            FeatureEngine::Mono(s) => s.seed(),
            FeatureEngine::Sharded(s) => s.seed(),
        }
    }

    pub fn modulation(&self) -> &[f64] {
        match self {
            FeatureEngine::Mono(s) => s.modulation(),
            FeatureEngine::Sharded(s) => s.modulation(),
        }
    }

    pub fn components(&self) -> WalkComponents {
        match self {
            FeatureEngine::Mono(s) => s.components(),
            FeatureEngine::Sharded(s) => s.components(),
        }
    }

    pub fn phi_snapshot(&self) -> Csr {
        match self {
            FeatureEngine::Mono(s) => s.phi_snapshot(),
            FeatureEngine::Sharded(s) => s.phi_snapshot(),
        }
    }

    pub fn overlay_rows(&self) -> usize {
        match self {
            FeatureEngine::Mono(s) => s.overlay_rows(),
            FeatureEngine::Sharded(s) => s.overlay_rows(),
        }
    }

    pub fn saturated_hubs(&self) -> usize {
        match self {
            FeatureEngine::Mono(s) => s.saturated_hubs(),
            FeatureEngine::Sharded(s) => s.saturated_hubs(),
        }
    }

    pub fn deltas_applied(&self) -> usize {
        match self {
            FeatureEngine::Mono(s) => s.deltas_applied,
            FeatureEngine::Sharded(s) => s.deltas_applied(),
        }
    }

    pub fn walks_resampled_total(&self) -> usize {
        match self {
            FeatureEngine::Mono(s) => s.walks_resampled_total,
            FeatureEngine::Sharded(s) => s.walks_resampled_total(),
        }
    }

    pub fn compactions(&self) -> usize {
        match self {
            FeatureEngine::Mono(s) => s.compactions,
            FeatureEngine::Sharded(s) => s.compactions(),
        }
    }

    pub fn set_hub_cap(&mut self, k: usize) {
        match self {
            FeatureEngine::Mono(s) => s.set_hub_cap(k),
            FeatureEngine::Sharded(s) => s.set_hub_cap(k),
        }
    }

    pub fn set_compact_threshold(&mut self, rows: usize) {
        match self {
            FeatureEngine::Mono(s) => s.set_compact_threshold(rows),
            FeatureEngine::Sharded(s) => s.set_compact_threshold(rows),
        }
    }
}

impl DeltaEngine for FeatureEngine {
    fn n(&self) -> usize {
        FeatureEngine::n(self)
    }

    fn walk_config(&self) -> &WalkConfig {
        self.config()
    }

    fn apply_delta_batch(&mut self, deltas: &[GraphDelta]) -> Result<BatchSummary, String> {
        match self {
            FeatureEngine::Mono(s) => s.apply_delta_batch(deltas),
            FeatureEngine::Sharded(s) => s.apply_delta_batch(deltas),
        }
    }

    fn component_row(&self, l: usize, r: usize) -> (Vec<u32>, Vec<f64>) {
        match self {
            FeatureEngine::Mono(s) => s.component_row(l, r),
            FeatureEngine::Sharded(s) => DeltaEngine::component_row(s, l, r),
        }
    }
}

// ---------------------------------------------------------------------
// Sharded model operand
// ---------------------------------------------------------------------

/// A logical matrix row-partitioned over per-shard [`RowOverlay`]
/// parts. Part `s` carries the full logical shape with only the rows
/// `owner(i) == s` nonzero; reads route each row to its owner, so the
/// assembled matrix is bitwise the unsharded overlay on the same
/// content (module docs — no cross-shard arithmetic anywhere).
#[derive(Clone, Debug)]
pub struct ShardedOverlay {
    partition: Partition,
    parts: Vec<RowOverlay>,
    n_rows: usize,
    n_cols: usize,
}

impl ShardedOverlay {
    /// Split `m` into per-shard parts by row ownership.
    pub fn from_csr(m: &Csr, partition: Partition) -> ShardedOverlay {
        let s_count = partition.n_shards();
        let parts = (0..s_count)
            .map(|s| {
                let part = csr_from_rows(m.n_rows, m.n_cols, |r| {
                    if partition.owner(r) == s {
                        let (c, v) = m.row(r);
                        (c.to_vec(), v.to_vec())
                    } else {
                        (Vec::new(), Vec::new())
                    }
                });
                RowOverlay::from(part)
            })
            .collect();
        ShardedOverlay {
            partition,
            parts,
            n_rows: m.n_rows,
            n_cols: m.n_cols,
        }
    }

    pub fn partition(&self) -> Partition {
        self.partition
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Row `i` from its owner part.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        self.parts[self.partition.owner(i)].row(i)
    }

    /// Grow the logical shape (every part tracks the full shape).
    pub fn grow(&mut self, n_rows: usize, n_cols: usize) {
        assert!(n_rows >= self.n_rows && n_cols >= self.n_cols);
        self.n_rows = n_rows;
        self.n_cols = n_cols;
        for p in &mut self.parts {
            p.grow(n_rows, n_cols);
        }
    }

    /// Stage new content for row `r` in its owner part.
    pub fn patch_row(&mut self, r: u32, cols: Vec<u32>, vals: Vec<f64>) {
        self.parts[self.partition.owner(r as usize)].patch_row(r, cols, vals);
    }

    /// Fold every part's overlay (each part compacts independently in
    /// production — this is the model-side compaction hook).
    pub fn compact(&mut self) {
        for p in &mut self.parts {
            p.compact();
        }
    }

    pub fn overlay_rows(&self) -> usize {
        self.parts.iter().map(|p| p.overlay_rows()).sum()
    }

    pub fn compactions(&self) -> usize {
        self.parts.iter().map(|p| p.compactions()).sum()
    }

    pub fn nnz(&self) -> usize {
        self.parts.iter().map(|p| p.nnz()).sum()
    }

    /// Materialise the composed content as canonical CSR.
    pub fn to_csr(&self) -> Csr {
        csr_from_rows(self.n_rows, self.n_cols, |r| {
            let (c, v) = self.row(r);
            (c.to_vec(), v.to_vec())
        })
    }

    /// Dense expansion (tests / small-N oracles only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0; self.n_cols]; self.n_rows];
        for (r, row) in out.iter_mut().enumerate() {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                row[*c as usize] += v;
            }
        }
        out
    }

    /// Thread-parallel transpose of the composed content.
    pub fn transpose_par(&self, threads: usize) -> Csr {
        self.to_csr().transpose_par(threads)
    }

    // -----------------------------------------------------------------
    // Kernels: bitwise `RowOverlay`'s on the same logical matrix — the
    // identical per-row accumulation, with the row read routed to its
    // owner part.
    // -----------------------------------------------------------------

    /// Rows [s, e) of y = A x into `out[0..e-s]`.
    #[inline]
    fn rows_matvec(&self, x: &[f64], s: usize, e: usize, out: &mut [f64]) {
        for i in s..e {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                // SAFETY: *c < n_cols == x.len() — part rows come from
                // CSR construction or `patch_row`'s hard bound check.
                acc += v * unsafe { x.get_unchecked(*c as usize) };
            }
            out[i - s] = acc;
        }
    }

    /// Rows [s, e) of Y = A X (row-major `ncols` block) into `out`.
    #[inline]
    fn rows_matmat(&self, x: &[f64], ncols: usize, s: usize, e: usize, out: &mut [f64]) {
        for i in s..e {
            let (cols, vals) = self.row(i);
            let yi = &mut out[(i - s) * ncols..(i - s + 1) * ncols];
            yi.fill(0.0);
            for (c, v) in cols.iter().zip(vals) {
                let base = *c as usize * ncols;
                // SAFETY: *c < n_cols (see rows_matvec), so the slice
                // is in bounds by the callers' asserted shape contract.
                let xr = unsafe { x.get_unchecked(base..base + ncols) };
                for (yj, xj) in yi.iter_mut().zip(xr) {
                    *yj += v * xj;
                }
            }
        }
    }

    /// y = A x into a caller-provided buffer (serial).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        self.rows_matvec(x, 0, self.n_rows, y);
    }

    /// Allocating wrapper over [`ShardedOverlay::matvec_into`].
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Thread-parallel y = A x over disjoint *global* row chunks (row
    /// routing happens inside each chunk), allocation-free.
    pub fn matvec_par_into(&self, x: &[f64], y: &mut [f64], threads: usize) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        parallel::par_rows_mut(y, 1, threads, |s, e, ys| {
            self.rows_matvec(x, s, e, ys);
        });
    }

    /// Allocating wrapper over [`ShardedOverlay::matvec_par_into`].
    pub fn matvec_par(&self, x: &[f64], threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_par_into(x, &mut y, threads);
        y
    }

    /// SpMM Y = A X over a row-major `n_cols × ncols` block.
    pub fn matmat_into(&self, x: &[f64], ncols: usize, y: &mut [f64]) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        self.rows_matmat(x, ncols, 0, self.n_rows, y);
    }

    /// Allocating wrapper over [`ShardedOverlay::matmat_into`].
    pub fn matmat(&self, x: &[f64], ncols: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_into(x, ncols, &mut y);
        y
    }

    /// Thread-parallel SpMM over disjoint global row chunks.
    pub fn matmat_par_into(&self, x: &[f64], ncols: usize, y: &mut [f64], threads: usize) {
        assert!(ncols > 0, "block width must be positive");
        assert_eq!(x.len(), self.n_cols * ncols);
        assert_eq!(y.len(), self.n_rows * ncols);
        parallel::par_rows_mut(y, ncols, threads, |s, e, rows| {
            self.rows_matmat(x, ncols, s, e, rows);
        });
    }

    /// Allocating wrapper over [`ShardedOverlay::matmat_par_into`].
    pub fn matmat_par(&self, x: &[f64], ncols: usize, threads: usize) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows * ncols];
        self.matmat_par_into(x, ncols, &mut y, threads);
        y
    }

    /// Instrumented y = A x — always the CSR dispatch path (no packed
    /// operand while sharded; see module docs).
    #[inline]
    pub fn spmv(&self, x: &[f64], y: &mut [f64], threads: usize, par: bool) {
        obs::registry::SPMV_CSR.inc();
        let _s = obs::span::Span::new(&obs::registry::SPMV_CSR_NS);
        if par {
            self.matvec_par_into(x, y, threads)
        } else {
            self.matvec_into(x, y)
        }
    }

    /// Instrumented blocked Y = A X (see [`ShardedOverlay::spmv`]).
    #[inline]
    pub fn spmm(&self, x: &[f64], ncols: usize, y: &mut [f64], threads: usize, par: bool) {
        obs::registry::SPMM_CSR.inc();
        let _s = obs::span::Span::new(&obs::registry::SPMM_CSR_NS);
        if par {
            self.matmat_par_into(x, ncols, y, threads)
        } else {
            self.matmat_into(x, ncols, y)
        }
    }

    /// Column-scatter the changed primal rows into `self = primalᵀ` —
    /// the sharded mirror of [`RowOverlay::patch_transpose_rows`]: the
    /// per-row merge is byte-for-byte the same; only the storage a
    /// merged row is staged into is routed by the (node ≡ transpose
    /// row) partition.
    pub fn patch_transpose_rows(
        &mut self,
        primal: &ShardedOverlay,
        affected: &[u32],
        old_supports: &[(u32, Vec<u32>)],
    ) {
        debug_assert!(affected.windows(2).all(|w| w[0] < w[1]));
        self.grow(primal.n_cols(), primal.n_rows());
        // Fresh entries of the affected primal rows, bucketed per
        // column j. `affected` is sorted ascending, so each bucket
        // comes out sorted by source row.
        let mut adds: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = BTreeMap::new();
        for &r in affected {
            let (cols, vals) = primal.row(r as usize);
            for (c, v) in cols.iter().zip(vals) {
                let e = adds.entry(*c).or_default();
                e.0.push(r);
                e.1.push(*v);
            }
        }
        let mut touched: BTreeSet<u32> = adds.keys().copied().collect();
        for (_, cols) in old_supports {
            touched.extend(cols.iter().copied());
        }
        let empty = (Vec::new(), Vec::new());
        let mut patches: Vec<(u32, Vec<u32>, Vec<f64>)> =
            Vec::with_capacity(touched.len());
        for &j in &touched {
            let (oc, ov) = self.row(j as usize);
            let (ac, av) = adds.get(&j).unwrap_or(&empty);
            let mut cols = Vec::with_capacity(oc.len() + ac.len());
            let mut vals = Vec::with_capacity(oc.len() + ac.len());
            let mut ai = 0;
            for (c, v) in oc.iter().zip(ov) {
                if affected.binary_search(c).is_ok() {
                    continue; // this column's primal row was rebuilt: drop
                }
                while ai < ac.len() && ac[ai] < *c {
                    cols.push(ac[ai]);
                    vals.push(av[ai]);
                    ai += 1;
                }
                cols.push(*c);
                vals.push(*v);
            }
            while ai < ac.len() {
                cols.push(ac[ai]);
                vals.push(av[ai]);
                ai += 1;
            }
            patches.push((j, cols, vals));
        }
        for (j, cols, vals) in patches {
            self.patch_row(j, cols, vals);
        }
    }
}

/// Logical equality against the unsharded overlay: same shape, same
/// per-row content with bitwise values.
impl PartialEq<RowOverlay> for ShardedOverlay {
    fn eq(&self, other: &RowOverlay) -> bool {
        if self.n_rows != other.n_rows() || self.n_cols != other.n_cols() {
            return false;
        }
        (0..self.n_rows).all(|r| self.row(r) == other.row(r))
    }
}

// ---------------------------------------------------------------------
// The model operand: one handle over both storage modes
// ---------------------------------------------------------------------

/// Φ / Φᵀ as held by the GP model: an unsharded [`RowOverlay`] or a
/// row-partitioned [`ShardedOverlay`]. Every kernel and maintenance
/// entry point dispatches per variant; the two variants are bitwise
/// interchangeable on the same logical matrix.
#[derive(Clone, Debug)]
pub enum Operand {
    Mono(RowOverlay),
    Sharded(ShardedOverlay),
}

impl Operand {
    /// Wrap a materialised matrix under the given partitioning mode.
    pub fn from_csr(m: Csr, partition: Option<Partition>) -> Operand {
        match partition {
            None => Operand::Mono(RowOverlay::from(m)),
            Some(p) => Operand::Sharded(ShardedOverlay::from_csr(&m, p)),
        }
    }

    /// The partition when sharded.
    pub fn partition(&self) -> Option<Partition> {
        match self {
            Operand::Mono(_) => None,
            Operand::Sharded(s) => Some(s.partition()),
        }
    }

    pub fn n_rows(&self) -> usize {
        match self {
            Operand::Mono(o) => o.n_rows(),
            Operand::Sharded(o) => o.n_rows(),
        }
    }

    pub fn n_cols(&self) -> usize {
        match self {
            Operand::Mono(o) => o.n_cols(),
            Operand::Sharded(o) => o.n_cols(),
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        match self {
            Operand::Mono(o) => o.row(i),
            Operand::Sharded(o) => o.row(i),
        }
    }

    pub fn grow(&mut self, n_rows: usize, n_cols: usize) {
        match self {
            Operand::Mono(o) => o.grow(n_rows, n_cols),
            Operand::Sharded(o) => o.grow(n_rows, n_cols),
        }
    }

    pub fn patch_row(&mut self, r: u32, cols: Vec<u32>, vals: Vec<f64>) {
        match self {
            Operand::Mono(o) => o.patch_row(r, cols, vals),
            Operand::Sharded(o) => o.patch_row(r, cols, vals),
        }
    }

    pub fn compact(&mut self) {
        match self {
            Operand::Mono(o) => o.compact(),
            Operand::Sharded(o) => o.compact(),
        }
    }

    pub fn overlay_rows(&self) -> usize {
        match self {
            Operand::Mono(o) => o.overlay_rows(),
            Operand::Sharded(o) => o.overlay_rows(),
        }
    }

    pub fn compactions(&self) -> usize {
        match self {
            Operand::Mono(o) => o.compactions(),
            Operand::Sharded(o) => o.compactions(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Operand::Mono(o) => o.nnz(),
            Operand::Sharded(o) => o.nnz(),
        }
    }

    pub fn to_csr(&self) -> Csr {
        match self {
            Operand::Mono(o) => o.to_csr(),
            Operand::Sharded(o) => o.to_csr(),
        }
    }

    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        match self {
            Operand::Mono(o) => o.to_dense(),
            Operand::Sharded(o) => o.to_dense(),
        }
    }

    pub fn transpose_par(&self, threads: usize) -> Csr {
        match self {
            Operand::Mono(o) => o.transpose_par(threads),
            Operand::Sharded(o) => o.transpose_par(threads),
        }
    }

    pub fn transpose(&self) -> Csr {
        match self {
            Operand::Mono(o) => o.transpose(),
            Operand::Sharded(o) => o.to_csr().transpose(),
        }
    }

    /// Run the ELL layout policy — `None` while sharded (per-part
    /// packing is future work; module docs) or while a mono overlay is
    /// live.
    pub fn select_ell(&self, layout: FeatureLayout) -> Option<Ell> {
        match self {
            Operand::Mono(o) => o.select_ell(layout),
            Operand::Sharded(_) => None,
        }
    }

    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            Operand::Mono(o) => o.matvec_into(x, y),
            Operand::Sharded(o) => o.matvec_into(x, y),
        }
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            Operand::Mono(o) => o.matvec(x),
            Operand::Sharded(o) => o.matvec(x),
        }
    }

    pub fn matvec_par_into(&self, x: &[f64], y: &mut [f64], threads: usize) {
        match self {
            Operand::Mono(o) => o.matvec_par_into(x, y, threads),
            Operand::Sharded(o) => o.matvec_par_into(x, y, threads),
        }
    }

    pub fn matvec_par(&self, x: &[f64], threads: usize) -> Vec<f64> {
        match self {
            Operand::Mono(o) => o.matvec_par(x, threads),
            Operand::Sharded(o) => o.matvec_par(x, threads),
        }
    }

    pub fn matmat_into(&self, x: &[f64], ncols: usize, y: &mut [f64]) {
        match self {
            Operand::Mono(o) => o.matmat_into(x, ncols, y),
            Operand::Sharded(o) => o.matmat_into(x, ncols, y),
        }
    }

    pub fn matmat(&self, x: &[f64], ncols: usize) -> Vec<f64> {
        match self {
            Operand::Mono(o) => o.matmat(x, ncols),
            Operand::Sharded(o) => o.matmat(x, ncols),
        }
    }

    pub fn matmat_par_into(&self, x: &[f64], ncols: usize, y: &mut [f64], threads: usize) {
        match self {
            Operand::Mono(o) => o.matmat_par_into(x, ncols, y, threads),
            Operand::Sharded(o) => o.matmat_par_into(x, ncols, y, threads),
        }
    }

    pub fn matmat_par(&self, x: &[f64], ncols: usize, threads: usize) -> Vec<f64> {
        match self {
            Operand::Mono(o) => o.matmat_par(x, ncols, threads),
            Operand::Sharded(o) => o.matmat_par(x, ncols, threads),
        }
    }

    /// Instrumented y = A x through the selected operand (`ell` is only
    /// ever `Some` for a mono operand — sharded selection returns
    /// `None` by construction).
    #[inline]
    pub fn spmv(&self, ell: Option<&Ell>, x: &[f64], y: &mut [f64], threads: usize, par: bool) {
        match self {
            Operand::Mono(o) => o.spmv(ell, x, y, threads, par),
            Operand::Sharded(o) => {
                debug_assert!(ell.is_none(), "no packed operand while sharded");
                o.spmv(x, y, threads, par)
            }
        }
    }

    /// Instrumented blocked Y = A X (see [`Operand::spmv`]).
    #[inline]
    pub fn spmm(
        &self,
        ell: Option<&Ell>,
        x: &[f64],
        ncols: usize,
        y: &mut [f64],
        threads: usize,
        par: bool,
    ) {
        match self {
            Operand::Mono(o) => o.spmm(ell, x, ncols, y, threads, par),
            Operand::Sharded(o) => {
                debug_assert!(ell.is_none(), "no packed operand while sharded");
                o.spmm(x, ncols, y, threads, par)
            }
        }
    }

    /// Incremental transpose maintenance — both operands must be in the
    /// same storage mode (the model converts Φ and Φᵀ together).
    pub fn patch_transpose_rows(
        &mut self,
        primal: &Operand,
        affected: &[u32],
        old_supports: &[(u32, Vec<u32>)],
    ) {
        match (self, primal) {
            (Operand::Mono(t), Operand::Mono(p)) => {
                t.patch_transpose_rows(p, affected, old_supports)
            }
            (Operand::Sharded(t), Operand::Sharded(p)) => {
                t.patch_transpose_rows(p, affected, old_supports)
            }
            _ => unreachable!("Φ and Φᵀ always share a storage mode"),
        }
    }

    /// Diagonal of `σ² I + mask ⊙ Φ Φᵀ` — the Jacobi preconditioner.
    /// Mirrors [`crate::sparse::ops::jacobi_diag`] exactly (the per-row
    /// accumulation reads the same value bits in the same order in both
    /// storage modes).
    pub fn jacobi_diag(&self, mask: Option<&[f64]>, sigma2: f64) -> Vec<f64> {
        match self {
            Operand::Mono(o) => crate::sparse::ops::jacobi_diag(o, mask, sigma2),
            Operand::Sharded(o) => {
                let n = o.n_rows();
                let mut d = vec![sigma2; n];
                for (i, di) in d.iter_mut().enumerate() {
                    if let Some(m) = mask {
                        if m[i] == 0.0 {
                            continue;
                        }
                    }
                    let (_, vals) = o.row(i);
                    let mut acc = 0.0;
                    for v in vals {
                        acc += v * v;
                    }
                    *di += acc;
                }
                d
            }
        }
    }
}

impl PartialEq<Csr> for Operand {
    /// Logical (post-fold) equality against a plain CSR — test oracle.
    fn eq(&self, other: &Csr) -> bool {
        match self {
            Operand::Mono(o) => o == other,
            Operand::Sharded(o) => o.to_csr() == *other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    fn wcfg(threads: usize) -> WalkConfig {
        WalkConfig {
            n_walks: 12,
            p_halt: 0.25,
            max_len: 3,
            reweight: true,
            normalize: true,
            termination: crate::walks::Termination::Iid,
            threads,
        }
    }

    fn diffusion_f(max_len: usize) -> Vec<f64> {
        let mut f = vec![0.0; max_len + 1];
        let mut acc = 1.0;
        for (l, fl) in f.iter_mut().enumerate() {
            if l > 0 {
                acc *= 0.5 / l as f64;
            }
            *fl = acc;
        }
        f
    }

    #[test]
    fn partition_is_total_and_balanced() {
        let p = Partition::new(4);
        let mut counts = [0usize; 4];
        for i in 0..101 {
            counts[p.owner(i)] += 1;
        }
        let (lo, hi) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(hi - lo <= 1, "round-robin must stay balanced: {counts:?}");
        assert_eq!(Partition::new(1).owner(12345), 0);
    }

    /// The composed sharded engine is bitwise the mono engine: fresh
    /// sample, then a mixed mutation batch (cross-shard edges, node
    /// append) with a tight hub cap and forced compactions.
    #[test]
    fn sharded_features_compose_bitwise() {
        let mut rng = Rng::new(42);
        let g = generators::barabasi_albert(40, 3, &mut rng);
        let cfg = wcfg(2);
        let f = diffusion_f(cfg.max_len);
        let mut mono = StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), 99);
        mono.set_hub_cap(1);
        mono.set_compact_threshold(2);
        for s_count in [2usize, 3, 7] {
            let mut sharded =
                ShardedFeatures::new(g.clone(), cfg.clone(), f.clone(), 99, s_count);
            sharded.set_hub_cap(1);
            sharded.set_compact_threshold(2);
            assert!(
                sharded.phi_snapshot() == mono.phi_snapshot(),
                "fresh Φ differs at S={s_count}"
            );
        }
        // Mutate: mono and a 3-shard engine in lockstep.
        let mut sharded = ShardedFeatures::new(g, cfg, f, 99, 3);
        sharded.set_hub_cap(1);
        sharded.set_compact_threshold(2);
        let gone = mono.graph().neighbors(2)[0] as usize;
        let deltas = vec![
            GraphDelta::AddEdge { u: 0, v: 17, w: 0.8 },
            GraphDelta::AddNode,
            GraphDelta::AddEdge { u: 40, v: 5, w: 1.5 },
            GraphDelta::RemoveEdge { u: 2, v: gone },
        ];
        let ms = mono.apply_delta_batch(&deltas).unwrap();
        let ss = sharded.apply_delta_batch(&deltas).unwrap();
        // Saturation cadences differ between the aggregated and the
        // per-shard visit indices, so the *resampled sets* are allowed
        // to drift (both are supersets of the true visitors) — the
        // features they produce are not.
        assert_eq!(
            ms.deltas[1].added_node, ss.deltas[1].added_node,
            "node append diverged"
        );
        assert!(
            sharded.phi_snapshot() == mono.phi_snapshot(),
            "post-batch Φ differs"
        );
        let mc = mono.components();
        let sc = sharded.components();
        for (l, (a, b)) in mc.c.iter().zip(&sc.c).enumerate() {
            assert!(a == b, "component {l} differs");
        }
        // Errors leave every shard untouched, like the mono engine.
        let before = sharded.phi_snapshot();
        let bad = vec![GraphDelta::AddEdge { u: 0, v: 9999, w: 1.0 }];
        assert!(sharded.apply_delta_batch(&bad).is_err());
        assert!(mono.apply_delta_batch(&bad).is_err());
        assert!(sharded.phi_snapshot() == before, "failed batch mutated state");
    }

    /// The composition contract is termination-scheme independent:
    /// every scheme derives its draws from `(seed, node, walk)` alone,
    /// so the partitioned engines reproduce the mono engine bitwise
    /// under each entry of the scheme matrix (`GRFGP_TEST_TERMINATION`
    /// narrows the matrix; default covers all schemes).
    #[test]
    fn sharded_compose_bitwise_under_every_scheme() {
        let mut rng = Rng::new(7);
        let g = generators::barabasi_albert(36, 3, &mut rng);
        for scheme in crate::walks::Termination::test_matrix() {
            let cfg = WalkConfig { termination: scheme, ..wcfg(2) };
            let f = diffusion_f(cfg.max_len);
            let mut mono =
                StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), 5);
            mono.set_hub_cap(1);
            mono.set_compact_threshold(2);
            let mut sharded =
                ShardedFeatures::new(g.clone(), cfg.clone(), f.clone(), 5, 3);
            sharded.set_hub_cap(1);
            sharded.set_compact_threshold(2);
            assert!(
                sharded.phi_snapshot() == mono.phi_snapshot(),
                "fresh Φ differs under {scheme:?}"
            );
            let deltas = vec![
                GraphDelta::AddEdge { u: 0, v: 17, w: 0.8 },
                GraphDelta::AddNode,
                GraphDelta::AddEdge { u: 36, v: 5, w: 1.5 },
            ];
            mono.apply_delta_batch(&deltas).unwrap();
            sharded.apply_delta_batch(&deltas).unwrap();
            assert!(
                sharded.phi_snapshot() == mono.phi_snapshot(),
                "post-batch Φ differs under {scheme:?}"
            );
            let (mc, sc) = (mono.components(), sharded.components());
            for (l, (a, b)) in mc.c.iter().zip(&sc.c).enumerate() {
                assert!(a == b, "{scheme:?}: component {l} differs");
            }
        }
    }

    fn random_csr(rng: &mut Rng, n_rows: usize, n_cols: usize, nnz: usize) -> Csr {
        let mut b = crate::sparse::CooBuilder::new(n_rows, n_cols);
        for _ in 0..nnz {
            b.push(
                rng.below(n_rows) as u32,
                rng.below(n_cols) as u32,
                rng.normal(),
            );
        }
        b.build()
    }

    fn random_row(rng: &mut Rng, n_cols: usize, width: usize) -> (Vec<u32>, Vec<f64>) {
        let mut cols: Vec<u32> = (0..width).map(|_| rng.below(n_cols) as u32).collect();
        cols.sort_unstable();
        cols.dedup();
        let vals: Vec<f64> = cols.iter().map(|_| rng.normal()).collect();
        (cols, vals)
    }

    /// Every sharded kernel is bitwise the unsharded overlay's on the
    /// same logical matrix, through patches, growth, and compaction.
    #[test]
    fn sharded_overlay_kernels_bitwise_match_row_overlay() {
        let mut rng = Rng::new(3);
        let m = random_csr(&mut rng, 23, 23, 140);
        let mut mono = RowOverlay::from(m.clone());
        let mut sharded = ShardedOverlay::from_csr(&m, Partition::new(4));
        assert!(sharded == mono, "fresh split differs");
        assert_eq!(sharded.nnz(), mono.nnz());
        // Patch a handful of rows (plus growth) in both.
        mono.grow(25, 25);
        sharded.grow(25, 25);
        for r in [0u32, 7, 11, 23, 24] {
            let (cols, vals) = random_row(&mut rng, 25, 6);
            mono.patch_row(r, cols.clone(), vals.clone());
            sharded.patch_row(r, cols, vals);
        }
        assert!(sharded == mono, "patched content differs");
        let x: Vec<f64> = (0..25).map(|_| rng.normal()).collect();
        assert_eq!(mono.matvec(&x), sharded.matvec(&x), "matvec");
        assert_eq!(
            mono.matvec_par(&x, 3),
            sharded.matvec_par(&x, 3),
            "matvec_par"
        );
        let xb: Vec<f64> = (0..25 * 4).map(|_| rng.normal()).collect();
        assert_eq!(mono.matmat(&xb, 4), sharded.matmat(&xb, 4), "matmat");
        assert_eq!(
            mono.matmat_par(&xb, 4, 3),
            sharded.matmat_par(&xb, 4, 3),
            "matmat_par"
        );
        assert_eq!(mono.to_csr(), sharded.to_csr(), "to_csr");
        sharded.compact();
        mono.compact();
        assert!(sharded == mono, "compaction diverged");
        assert_eq!(mono.matvec(&x), sharded.matvec(&x), "compacted matvec");
    }

    /// The sharded transpose maintenance replays the unsharded merge
    /// bitwise, and both equal a from-scratch transpose of the patched
    /// primal.
    #[test]
    fn sharded_patch_transpose_rows_bitwise() {
        let mut rng = Rng::new(17);
        let m = random_csr(&mut rng, 19, 19, 120);
        let p = Partition::new(3);
        let mut phi_m = RowOverlay::from(m.clone());
        let mut phi_s = ShardedOverlay::from_csr(&m, p);
        let mut pt_m = RowOverlay::from(m.transpose());
        let mut pt_s = ShardedOverlay::from_csr(&m.transpose(), p);
        for round in 0..3 {
            let mut affected: Vec<u32> =
                (0..4).map(|_| rng.below(19) as u32).collect();
            affected.sort_unstable();
            affected.dedup();
            let old_supports: Vec<(u32, Vec<u32>)> = affected
                .iter()
                .map(|&r| (r, phi_m.row(r as usize).0.to_vec()))
                .collect();
            for &r in &affected {
                let (cols, vals) = random_row(&mut rng, 19, 5);
                phi_m.patch_row(r, cols.clone(), vals.clone());
                phi_s.patch_row(r, cols, vals);
            }
            pt_m.patch_transpose_rows(&phi_m, &affected, &old_supports);
            pt_s.patch_transpose_rows(&phi_s, &affected, &old_supports);
            assert_eq!(pt_m.to_csr(), pt_s.to_csr(), "round {round}: Φᵀ differs");
            assert_eq!(
                pt_s.to_csr(),
                phi_m.to_csr().transpose(),
                "round {round}: Φᵀ is not the transpose of Φ"
            );
        }
    }

    #[test]
    fn operand_dispatch_and_jacobi_parity() {
        let mut rng = Rng::new(29);
        let m = random_csr(&mut rng, 15, 15, 70);
        let mono = Operand::from_csr(m.clone(), None);
        let sharded = Operand::from_csr(m, Some(Partition::new(2)));
        assert!(
            sharded.select_ell(FeatureLayout::Auto).is_none(),
            "no packed operand while sharded"
        );
        let mask: Vec<f64> = (0..15).map(|i| (i % 3 == 0) as u64 as f64).collect();
        assert_eq!(
            mono.jacobi_diag(Some(&mask), 0.3),
            sharded.jacobi_diag(Some(&mask), 0.3),
            "jacobi parity"
        );
        let x: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let mut ym = vec![0.0; 15];
        let mut ys = vec![0.0; 15];
        mono.spmv(None, &x, &mut ym, 2, true);
        sharded.spmv(None, &x, &mut ys, 2, true);
        assert_eq!(ym, ys, "spmv parity");
        assert_eq!(mono.to_dense(), sharded.to_dense());
    }
}
