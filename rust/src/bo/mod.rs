//! Bayesian optimisation on graphs (paper §4.3, Alg. 3).
//!
//! Graph Thompson sampling with the GRF-GP surrogate: each step draws
//! one pathwise-conditioning posterior sample over **all** N nodes
//! (O(N^{3/2})), queries its argmax, and appends the observation.
//! Baselines: random search, BFS, DFS (the paper's comparators).

use crate::gp::model::GpModel;
use crate::gp::{Hypers, Modulation};
use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::walks::{WalkConfig, WalkSampler};

/// A BO policy proposes the next node to query given history.
pub trait Policy {
    fn next_query(&mut self, observed: &[(usize, f64)], rng: &mut Rng) -> usize;
    fn name(&self) -> &'static str;
}

/// Result of one BO run.
#[derive(Clone, Debug)]
pub struct BoRun {
    pub policy: String,
    /// Queried node per step (including the initial design).
    pub queries: Vec<usize>,
    /// Observed (noisy) value per step.
    pub observed: Vec<f64>,
    /// Simple regret per step w.r.t. the true optimum.
    pub regret: Vec<f64>,
}

/// Shared BO experiment settings.
#[derive(Clone, Debug)]
pub struct BoConfig {
    pub n_init: usize,
    pub n_steps: usize,
    pub noise: f64,
    /// Retrain the surrogate's hyperparameters every `refit_every`
    /// steps (0 = never; the modulation is kept at its initial shape).
    pub refit_every: usize,
    pub refit_steps: usize,
    /// Model log1p(y) instead of y in the surrogate — stabilises GP
    /// regression on heavy-tailed objectives (social-network degrees).
    /// Monotone, so the Thompson argmax is unchanged in expectation.
    pub log_transform: bool,
    pub walk: WalkConfig,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            n_init: 20,
            n_steps: 100,
            noise: 0.1,
            refit_every: 0,
            refit_steps: 10,
            log_transform: false,
            walk: WalkConfig { n_walks: 100, p_halt: 0.1, max_len: 5, ..Default::default() },
        }
    }
}

/// Run any policy against black-box `h` on the graph's node set.
pub fn run_policy(
    policy: &mut dyn Policy,
    h: &dyn Fn(usize) -> f64,
    optimum: f64,
    n_nodes: usize,
    cfg: &BoConfig,
    rng: &mut Rng,
) -> BoRun {
    let mut queries = Vec::with_capacity(cfg.n_init + cfg.n_steps);
    let mut observed = Vec::with_capacity(cfg.n_init + cfg.n_steps);
    let mut true_vals = Vec::with_capacity(cfg.n_init + cfg.n_steps);
    // Initial design: uniform without replacement (Alg. 3 line 3).
    for i in rng.sample_without_replacement(n_nodes, cfg.n_init.min(n_nodes)) {
        queries.push(i);
        true_vals.push(h(i));
        observed.push(h(i) + cfg.noise.sqrt() * rng.normal());
    }
    for _ in 0..cfg.n_steps {
        let pairs: Vec<(usize, f64)> =
            queries.iter().cloned().zip(observed.iter().cloned()).collect();
        let x = policy.next_query(&pairs, rng);
        queries.push(x);
        true_vals.push(h(x));
        observed.push(h(x) + cfg.noise.sqrt() * rng.normal());
    }
    // Simple regret on the *noiseless* objective at queried nodes —
    // noisy observations could otherwise exceed the optimum.
    let regret = crate::gp::metrics::simple_regret_curve(&true_vals, optimum);
    BoRun {
        policy: policy.name().to_string(),
        queries,
        observed,
        regret,
    }
}

// ----------------------------------------------------------------------
// Thompson sampling with the GRF-GP surrogate
// ----------------------------------------------------------------------

pub struct ThompsonPolicy {
    model: GpModel,
    steps_since_fit: usize,
    refit_every: usize,
    refit_steps: usize,
    log_transform: bool,
    /// Warm-start each draw's data-column solve at the previous step's
    /// `α_y` (ROADMAP item: carry the posterior solves in policy
    /// state). One BO step changes a single observation, so the
    /// systems are nearly identical; the rng stream of the draws is
    /// untouched — only the CG iteration count drops.
    pub warm_start: bool,
    /// Previous step's data-column solve (the warm-start seed).
    prev_alpha: Option<Vec<f64>>,
    /// Total block-CG iterations spent in posterior draws so far —
    /// reported by `exp bo-*` to show the warm-start win.
    pub cg_iters: usize,
}

impl ThompsonPolicy {
    /// Build the surrogate: one walk-sampling pass (kernel init is O(N))
    /// reused for the whole BO run.
    pub fn new(g: &Graph, cfg: &BoConfig, rng: &mut Rng) -> ThompsonPolicy {
        let comps = WalkSampler::new(g, &cfg.walk, rng.next_u64()).components();
        let l_max = cfg.walk.max_len;
        let hypers = Hypers::new(
            Modulation::diffusion(1.0, 1.0, l_max),
            cfg.noise.max(1e-3),
        );
        let model = GpModel::new(comps, hypers, &[], &[]);
        ThompsonPolicy {
            model,
            steps_since_fit: 0,
            refit_every: cfg.refit_every,
            refit_steps: cfg.refit_steps,
            log_transform: cfg.log_transform,
            warm_start: true,
            prev_alpha: None,
            cg_iters: 0,
        }
    }

    pub fn model_mut(&mut self) -> &mut GpModel {
        &mut self.model
    }
}

impl Policy for ThompsonPolicy {
    fn next_query(&mut self, observed: &[(usize, f64)], rng: &mut Rng) -> usize {
        // Optional log1p for heavy-tailed objectives, then normalise to
        // zero mean / unit variance — keeps the prior scale sensible.
        let raw: Vec<f64> = observed
            .iter()
            .map(|(_, v)| {
                if self.log_transform {
                    (1.0 + v.max(0.0)).ln()
                } else {
                    *v
                }
            })
            .collect();
        let n_obs = raw.len().max(1) as f64;
        let mean = raw.iter().sum::<f64>() / n_obs;
        let var = raw.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n_obs;
        let scale = var.sqrt().max(1e-6);
        let nodes: Vec<usize> = observed.iter().map(|(i, _)| *i).collect();
        let ys: Vec<f64> = raw.iter().map(|v| (v - mean) / scale).collect();
        self.model.set_data(&nodes, &ys);
        if self.refit_every > 0 {
            self.steps_since_fit += 1;
            if self.steps_since_fit >= self.refit_every {
                self.steps_since_fit = 0;
                self.model.fit(self.refit_steps, 0.05, rng);
            }
        }
        // Pathwise Thompson draw, warm-started at the previous step's
        // data-column solve (same rng stream as `posterior_sample`).
        let warm = if self.warm_start {
            self.prev_alpha.as_deref()
        } else {
            None
        };
        let (sample, alpha_y, stats) = self.model.thompson_sample_warm(rng, warm);
        self.prev_alpha = Some(alpha_y);
        self.cg_iters += stats.iter().map(|s| s.iterations).sum::<usize>();
        // Argmax over unqueried nodes.
        let queried: std::collections::HashSet<usize> =
            nodes.iter().cloned().collect();
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &v) in sample.iter().enumerate() {
            if !queried.contains(&i) && v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "grf-thompson"
    }
}

// ----------------------------------------------------------------------
// Search baselines (paper App. C.6)
// ----------------------------------------------------------------------

/// Uniform random search without replacement.
pub struct RandomPolicy {
    n_nodes: usize,
}

impl RandomPolicy {
    pub fn new(n_nodes: usize) -> Self {
        RandomPolicy { n_nodes }
    }
}

impl Policy for RandomPolicy {
    fn next_query(&mut self, observed: &[(usize, f64)], rng: &mut Rng) -> usize {
        let queried: std::collections::HashSet<usize> =
            observed.iter().map(|(i, _)| *i).collect();
        loop {
            let c = rng.below(self.n_nodes);
            if !queried.contains(&c) {
                return c;
            }
        }
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Breadth-first expansion from the initial design.
pub struct BfsPolicy<'g> {
    g: &'g Graph,
    frontier: std::collections::VecDeque<usize>,
    seeded: bool,
}

impl<'g> BfsPolicy<'g> {
    pub fn new(g: &'g Graph) -> Self {
        BfsPolicy { g, frontier: Default::default(), seeded: false }
    }
}

impl Policy for BfsPolicy<'_> {
    fn next_query(&mut self, observed: &[(usize, f64)], rng: &mut Rng) -> usize {
        let queried: std::collections::HashSet<usize> =
            observed.iter().map(|(i, _)| *i).collect();
        if !self.seeded {
            for (i, _) in observed {
                self.frontier.push_back(*i);
            }
            self.seeded = true;
        }
        loop {
            match self.frontier.pop_front() {
                Some(u) => {
                    let mut found = None;
                    for &v in self.g.neighbors(u) {
                        let v = v as usize;
                        if !queried.contains(&v) {
                            found = Some(v);
                            break;
                        }
                    }
                    // Re-queue u: it may still have unvisited neighbors.
                    if let Some(v) = found {
                        self.frontier.push_back(u);
                        self.frontier.push_back(v);
                        return v;
                    }
                }
                None => {
                    // Exhausted: fall back to random restart.
                    loop {
                        let c = rng.below(self.g.num_nodes());
                        if !queried.contains(&c) {
                            self.frontier.push_back(c);
                            return c;
                        }
                    }
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "bfs"
    }
}

/// Depth-first expansion from the initial design.
pub struct DfsPolicy<'g> {
    g: &'g Graph,
    stack: Vec<usize>,
    seeded: bool,
}

impl<'g> DfsPolicy<'g> {
    pub fn new(g: &'g Graph) -> Self {
        DfsPolicy { g, stack: Vec::new(), seeded: false }
    }
}

impl Policy for DfsPolicy<'_> {
    fn next_query(&mut self, observed: &[(usize, f64)], rng: &mut Rng) -> usize {
        let queried: std::collections::HashSet<usize> =
            observed.iter().map(|(i, _)| *i).collect();
        if !self.seeded {
            for (i, _) in observed {
                self.stack.push(*i);
            }
            self.seeded = true;
        }
        loop {
            match self.stack.pop() {
                Some(u) => {
                    let mut found = None;
                    for &v in self.g.neighbors(u) {
                        let v = v as usize;
                        if !queried.contains(&v) {
                            found = Some(v);
                            break;
                        }
                    }
                    if let Some(v) = found {
                        self.stack.push(u);
                        self.stack.push(v);
                        return v;
                    }
                }
                None => loop {
                    let c = rng.below(self.g.num_nodes());
                    if !queried.contains(&c) {
                        self.stack.push(c);
                        return c;
                    }
                },
            }
        }
    }

    fn name(&self) -> &'static str {
        "dfs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn bump_objective(n: usize) -> impl Fn(usize) -> f64 {
        // Smooth bump centred at 0.37n, width ~5% of the ring: easy for
        // a graph-smooth surrogate to climb, hard for blind search to
        // hit exactly.
        move |i: usize| {
            let centre = 0.37 * n as f64;
            let mut d = (i as f64 - centre).abs();
            d = d.min(n as f64 - d);
            let w = 0.05 * n as f64;
            (-d * d / (2.0 * w * w)).exp()
        }
    }

    #[test]
    fn thompson_beats_random_on_smooth_ring() {
        let n = 400;
        let g = generators::ring(n);
        let h = bump_objective(n);
        let optimum = (0..n).map(&h).fold(f64::MIN, f64::max);
        let cfg = BoConfig {
            n_init: 10,
            n_steps: 50,
            noise: 0.01,
            walk: WalkConfig { n_walks: 64, max_len: 4, threads: 1, ..Default::default() },
            ..Default::default()
        };
        let mut final_ts = 0.0;
        let mut final_rand = 0.0;
        for seed in 0..4u64 {
            let mut rng = Rng::new(seed);
            let mut ts = ThompsonPolicy::new(&g, &cfg, &mut rng);
            let run = run_policy(&mut ts, &h, optimum, n, &cfg, &mut rng);
            final_ts += run.regret.last().unwrap() / 4.0;
            let mut rng = Rng::new(seed);
            let mut rp = RandomPolicy::new(n);
            let run = run_policy(&mut rp, &h, optimum, n, &cfg, &mut rng);
            final_rand += run.regret.last().unwrap() / 4.0;
        }
        assert!(
            final_ts < final_rand,
            "thompson {final_ts} should beat random {final_rand}"
        );
        assert!(final_ts < 0.3, "thompson should nearly find the bump: {final_ts}");
    }

    #[test]
    fn policies_never_requery() {
        let n = 60;
        let g = generators::grid2d(6, 10);
        let h = |i: usize| (i % 7) as f64;
        let cfg = BoConfig {
            n_init: 5,
            n_steps: 30,
            noise: 0.0,
            walk: WalkConfig { n_walks: 16, max_len: 3, threads: 1, ..Default::default() },
            ..Default::default()
        };
        let mut rng = Rng::new(0);
        for policy_name in ["random", "bfs", "dfs", "ts"] {
            let mut rng2 = Rng::new(42);
            let run = match policy_name {
                "random" => {
                    let mut p = RandomPolicy::new(n);
                    run_policy(&mut p, &h, 6.0, n, &cfg, &mut rng2)
                }
                "bfs" => {
                    let mut p = BfsPolicy::new(&g);
                    run_policy(&mut p, &h, 6.0, n, &cfg, &mut rng2)
                }
                "dfs" => {
                    let mut p = DfsPolicy::new(&g);
                    run_policy(&mut p, &h, 6.0, n, &cfg, &mut rng2)
                }
                _ => {
                    let mut p = ThompsonPolicy::new(&g, &cfg, &mut rng);
                    run_policy(&mut p, &h, 6.0, n, &cfg, &mut rng2)
                }
            };
            let mut seen = std::collections::HashSet::new();
            for &q in &run.queries {
                assert!(seen.insert(q), "{policy_name} requeried node {q}");
            }
            assert_eq!(run.regret.len(), run.observed.len());
        }
    }

    #[test]
    fn warm_started_thompson_resolve_beats_cold_start() {
        // One BO step changes a single observation, so the Thompson
        // re-solve is a nearly identical system: warm-starting the
        // block-CG at the previous step's solves must take strictly
        // fewer iterations than the cold start on the same system.
        let n = 400;
        let g = generators::ring(n);
        let walk = WalkConfig { n_walks: 64, max_len: 4, threads: 1, ..Default::default() };
        let comps = WalkSampler::new(&g, &walk, 3).components();
        let h = bump_objective(n);
        let nodes0: Vec<usize> = (0..40).map(|i| i * 10).collect();
        let y0: Vec<f64> = nodes0.iter().map(|&i| h(i)).collect();
        let mut model = GpModel::new(
            comps,
            crate::gp::Hypers::new(crate::gp::Modulation::diffusion(1.0, 1.0, 4), 0.1),
            &nodes0,
            &y0,
        );
        model.solve.tol = 1e-8;
        // Thompson-shaped rhs block [m·y, m·(y − s)] with a fixed draw
        // `s` standing in for the pathwise sample g + σε: the draw is
        // shared across BO steps so the two systems differ only by the
        // single-point data update.
        let mut draw = Rng::new(99);
        let s: Vec<f64> = (0..n).map(|_| draw.normal()).collect();
        let ncols = 2;
        let build_rhs = |m: &GpModel| -> Vec<f64> {
            let mut rhs = vec![0.0; n * ncols];
            for i in 0..n {
                rhs[i * ncols] = m.mask[i] * m.y[i];
                rhs[i * ncols + 1] = m.mask[i] * (m.y[i] - 0.5 * s[i]);
            }
            rhs
        };
        let rhs0 = build_rhs(&model);
        let (x_prev, st_prev) = model.solve_system_block(&rhs0, ncols);
        assert!(st_prev.iter().all(|st| st.converged));
        // The BO step: query one new node, append its observation.
        let mut nodes1 = nodes0.clone();
        nodes1.push(5);
        let y1: Vec<f64> = nodes1.iter().map(|&i| h(i)).collect();
        model.set_data(&nodes1, &y1);
        let rhs1 = build_rhs(&model);
        let (_, st_cold) = model.solve_system_block(&rhs1, ncols);
        let (_, st_warm) =
            model.solve_system_block_warm(&rhs1, ncols, Some(&x_prev));
        assert!(st_cold.iter().all(|st| st.converged));
        assert!(st_warm.iter().all(|st| st.converged));
        let cold: usize = st_cold.iter().map(|st| st.iterations).sum();
        let warm: usize = st_warm.iter().map(|st| st.iterations).sum();
        assert!(
            warm < cold,
            "warm-started re-solve must take strictly fewer iterations: \
             warm {warm} vs cold {cold}"
        );
    }

    #[test]
    fn thompson_policy_warm_start_saves_cg_iterations() {
        // Two identical policies fed the same growing observation
        // sequence with identical rng streams — the only difference is
        // the warm-start flag, so the fluctuation columns cost the
        // same and the warm data columns must win in total.
        let n = 300;
        let g = generators::ring(n);
        let h = bump_objective(n);
        let cfg = BoConfig {
            n_init: 5,
            n_steps: 0,
            noise: 0.01,
            walk: WalkConfig { n_walks: 64, max_len: 4, threads: 1, ..Default::default() },
            ..Default::default()
        };
        let mut rng_w = Rng::new(1);
        let mut warm_p = ThompsonPolicy::new(&g, &cfg, &mut rng_w);
        let mut rng_c = Rng::new(1);
        let mut cold_p = ThompsonPolicy::new(&g, &cfg, &mut rng_c);
        cold_p.warm_start = false;
        let nodes: Vec<usize> = (0..30).map(|i| (i * 7) % n).collect();
        for step in 5..30 {
            let observed: Vec<(usize, f64)> =
                nodes[..step].iter().map(|&i| (i, h(i))).collect();
            let mut ra = Rng::new(100 + step as u64);
            let mut rb = ra.clone();
            warm_p.next_query(&observed, &mut ra);
            cold_p.next_query(&observed, &mut rb);
        }
        assert!(
            warm_p.cg_iters < cold_p.cg_iters,
            "warm-started policy must spend strictly fewer CG iterations: \
             warm {} vs cold {}",
            warm_p.cg_iters,
            cold_p.cg_iters
        );
    }

    #[test]
    fn regret_hits_zero_when_optimum_found() {
        let n = 30;
        let h = |i: usize| if i == 17 { 10.0 } else { 0.0 };
        let cfg = BoConfig { n_init: 5, n_steps: 25, noise: 0.0, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut p = RandomPolicy::new(n);
        let run = run_policy(&mut p, &h, 10.0, n, &cfg, &mut rng);
        // All 30 nodes get queried across 30 steps => regret ends at 0.
        assert!(run.regret.last().unwrap().abs() < 1e-12);
    }
}
