//! The GRF random-walk engine — the paper's core estimator (Alg. 1/2).
//!
//! For every node `i` we simulate `n_walks` random walks with geometric
//! halting (probability `p_halt` per step). Every prefix subwalk of
//! length `l` ending at node `j` deposits its importance-sampling
//! *load* into the per-length **component matrix** `C_l[i, j]`.
//!
//! The GRF feature matrix for a modulation function `f` is then the
//! linear combination `Φ(f) = Σ_{l=0}^{l_max} f_l C_l`, which makes
//! `∂Φ/∂f_l = C_l` **exact** — hyperparameter gradients never need
//! re-walking (DESIGN.md §3). The walk engine runs once per model;
//! training re-combines the cached components every optimiser step.
//!
//! Unbiasedness: `E[C_l] = W^l` (tested in `engine.rs`), hence
//! `E[Φ] = Ψ = Σ_l f_l W^l` and `E[Φ Φᵀ] ≈ K_α` for `α = f ⊛ f`
//! (discrete convolution), exactly the paper's estimator.

pub mod components;
pub mod engine;
pub mod variance;

pub use components::{CombinedFeatures, WalkComponents};
pub use variance::kernel_variance_iid;
pub use engine::{
    resample_walk, rows_from_walks, sample_components,
    sample_components_indexed, sample_components_indexed_part,
    sample_features, walk_rng, IndexedWalks, NodeWalks, WalkConfig,
};
