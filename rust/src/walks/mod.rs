//! The GRF random-walk engine — the paper's core estimator (Alg. 1/2).
//!
//! For every node `i` we simulate `n_walks` random walks with geometric
//! halting (probability `p_halt` per step). Every prefix subwalk of
//! length `l` ending at node `j` deposits its importance-sampling
//! *load* into the per-length **component matrix** `C_l[i, j]`.
//!
//! The GRF feature matrix for a modulation function `f` is then the
//! linear combination `Φ(f) = Σ_{l=0}^{l_max} f_l C_l`, which makes
//! `∂Φ/∂f_l = C_l` **exact** — hyperparameter gradients never need
//! re-walking (DESIGN.md §3). The walk engine runs once per model;
//! training re-combines the cached components every optimiser step.
//!
//! Unbiasedness: `E[C_l] = W^l` (tested in `engine.rs`), hence
//! `E[Φ] = Ψ = Σ_l f_l W^l` and `E[Φ Φᵀ] ≈ K_α` for `α = f ⊛ f`
//! (discrete convolution), exactly the paper's estimator.
//!
//! The front door is [`WalkSampler`]: one `(graph, config, seed)`
//! binding with a typed request per output shape — `components()`
//! (features only), `indexed()` (+ per-walk deposit store and visit
//! index, for the streaming subsystem), `partition(shard, of)`
//! (+ ownership filter, for the sharded engine).
//!
//! ## Termination schemes
//!
//! [`Termination`] on [`WalkConfig`] selects how walk halting times
//! are sampled, after Reid et al., *Quasi-Monte Carlo Graph Random
//! Features* (arXiv 2305.12470):
//!
//! * **`Iid`** (default) — independent `bernoulli(p_halt)` per step,
//!   drawn from the walk's own RNG stream. Bit-identical to the
//!   historical walker (pinned by a regression test), so existing
//!   seeds reproduce byte-for-byte.
//! * **`Antithetic`** — walks `2t` and `2t+1` of each node draw their
//!   geometric length budgets from one shared uniform `u` and its
//!   mirror `1-u` (the *pairing rule*: the pair's uniform comes from a
//!   dedicated stream keyed by `(seed, node, pair)`, never from the
//!   walks' step streams). The coupling is comonotone in walk length:
//!   a short walk's pair runs long, cancelling halting-time noise in
//!   the node's average. Helps most when the modulation `f` still has
//!   weight at depths the geometric tail reaches (`p_halt·max_len`
//!   around 1 or above); with aggressive truncation
//!   (`p_halt·max_len ≪ 1`) nearly every walk hits `max_len` and no
//!   scheme has terminations left to correlate.
//! * **`Qmc`** — walk `t` maps the base-2 van der Corput point
//!   `vdc(t)` through a per-node Cranley-Patterson rotation into a
//!   geometric length budget, so each node's `n_walks` budgets
//!   stratify the halting-time quantiles near-perfectly (exactly one
//!   budget per quantile block when `n_walks` is a power of two).
//!   Dominates antithetic in every regime we measure; the randomised
//!   shift keeps the estimator unbiased across seeds.
//!
//! **Unbiasedness is scheme-independent**: every scheme realises the
//! same geometric marginal `P(length ≥ k) = (1-p_halt)^k` per walk
//! (tested), and budgets are independent of the step draws, so
//! `E[C_l] = W^l` holds under all three — only the *cross-walk*
//! covariance changes. Every scheme derives its randomness as a pure
//! function of `(seed, node, walk)`, so walk isolation (streaming
//! resample), thread-count determinism, and shard
//! partition-independence hold under all of them.
//!
//! [`kernel_variance`] measures the schemes' across-seed estimator
//! variance on sampled kernel entries (published as the
//! `grf_variance_{iid,antithetic,qmc}` gauges and
//! `metric_grf_variance_*` bench rows); at the bench configuration the
//! correlated schemes cut variance ~40-50% at fixed `n_walks` —
//! equivalently, fewer walks (smaller Φ nnz, cheaper SpMM/resampling)
//! at matched accuracy.

pub mod components;
pub mod engine;
pub mod variance;

pub use components::{CombinedFeatures, WalkComponents};
pub use variance::{kernel_variance, kernel_variance_iid};
pub use engine::{
    resample_walk, rows_from_walks, sample_components,
    sample_components_indexed, sample_components_indexed_part,
    sample_features, walk_rng, IndexedWalks, NodeWalks, Termination,
    WalkConfig, WalkSampler,
};
