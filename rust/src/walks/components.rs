//! Per-length walk component matrices and their fast recombination.
//!
//! Training recombines `Φ(f) = Σ_l f_l C_l` at every optimiser step, so
//! the union sparsity pattern and per-length scatter maps are
//! precomputed once ([`CombinedFeatures`]); each recombination is then
//! a single fused scatter pass with no allocation or sorting.

use crate::sparse::{CooBuilder, Csr, RowWidthStats};

/// The output of the walk engine: `c[l][i][j]` estimates `(W^l)[i][j]`.
#[derive(Clone, Debug)]
pub struct WalkComponents {
    pub c: Vec<Csr>,
}

impl WalkComponents {
    pub fn new(c: Vec<Csr>) -> Self {
        assert!(!c.is_empty());
        let n = c[0].n_rows;
        for m in &c {
            assert_eq!(m.n_rows, n);
            assert_eq!(m.n_cols, n);
        }
        WalkComponents { c }
    }

    pub fn n(&self) -> usize {
        self.c[0].n_rows
    }

    /// Number of modulation coefficients (l_max + 1).
    pub fn n_coeffs(&self) -> usize {
        self.c.len()
    }

    /// Total stored nonzeros across all lengths.
    pub fn nnz(&self) -> usize {
        self.c.iter().map(|m| m.nnz()).sum()
    }

    /// Row-width distribution of each per-length component matrix —
    /// the feature-build diagnostic behind the ELL layout decision
    /// (Theorem 1 bounds these widths w.h.p., which is exactly why the
    /// fixed-width layout pays off).
    pub fn row_width_stats(&self) -> Vec<RowWidthStats> {
        self.c.iter().map(|m| m.row_width_stats()).collect()
    }

    pub fn memory_bytes(&self) -> usize {
        self.c.iter().map(|m| m.memory_bytes()).sum()
    }

    /// One-shot combination Φ(f) = Σ_l f_l C_l (allocates; for repeated
    /// combination use [`CombinedFeatures`]).
    pub fn combine(&self, f: &[f64]) -> Csr {
        assert_eq!(f.len(), self.c.len(), "modulation length != l_max+1");
        let refs: Vec<&Csr> = self.c.iter().collect();
        Csr::linear_combination(&refs, f)
    }

    /// Precompute the union pattern + scatter maps for fast repeated
    /// recombination during training.
    pub fn prepare(&self) -> CombinedFeatures {
        let n = self.n();
        // Union pattern via a zero-weight linear combination trick:
        // build with all coefficient 1.0 on |values| to avoid cancel-drop.
        let mut b = CooBuilder::new(n, n);
        for m in &self.c {
            for r in 0..n {
                let (cols, _) = m.row(r);
                for c in cols {
                    b.push(r as u32, *c, 1.0);
                }
            }
        }
        let mut pattern = b.build();
        for v in &mut pattern.vals {
            *v = 0.0;
        }
        let maps = build_maps(self, &pattern);
        CombinedFeatures { components: self.clone(), pattern, maps }
    }
}

/// Scatter map per length: position of each component entry in the
/// union pattern. Shared by [`WalkComponents::prepare`] and the row
/// patcher ([`CombinedFeatures::patch_rows`]).
fn build_maps(components: &WalkComponents, pattern: &Csr) -> Vec<Vec<u32>> {
    let n = pattern.n_rows;
    components
        .c
        .iter()
        .map(|m| {
            let mut map = Vec::with_capacity(m.nnz());
            for r in 0..n {
                let (cols, _) = m.row(r);
                let (pc, _) = pattern.row(r);
                let base = pattern.offsets[r];
                for c in cols {
                    let k = pc.binary_search(c).expect("pattern covers entry");
                    map.push((base + k) as u32);
                }
            }
            map
        })
        .collect()
}

/// Union-pattern recombiner: `combine_into` refreshes the value array of
/// the shared pattern in O(total nnz) with zero allocation.
#[derive(Clone)]
pub struct CombinedFeatures {
    pub components: WalkComponents,
    /// Union sparsity pattern; `vals` holds the latest combination.
    pub pattern: Csr,
    /// For each length l, flat index into `pattern.vals` of each entry
    /// of `components.c[l]`.
    maps: Vec<Vec<u32>>,
}

impl CombinedFeatures {
    pub fn n(&self) -> usize {
        self.pattern.n_rows
    }

    /// Recompute Φ(f) into the shared pattern and return a reference.
    pub fn combine_into(&mut self, f: &[f64]) -> &Csr {
        assert_eq!(f.len(), self.components.c.len());
        for v in &mut self.pattern.vals {
            *v = 0.0;
        }
        for (l, map) in self.maps.iter().enumerate() {
            let fl = f[l];
            if fl == 0.0 {
                continue;
            }
            let vals = &self.components.c[l].vals;
            for (slot, v) in map.iter().zip(vals) {
                self.pattern.vals[*slot as usize] += fl * v;
            }
        }
        &self.pattern
    }

    /// Clone out the current combination.
    pub fn current(&self) -> Csr {
        self.pattern.clone()
    }

    /// Recompute the combined values of exactly `rows` under `f`,
    /// leaving every other slot of `pattern.vals` untouched.
    ///
    /// Steady-state invariant of the streaming delta path: between
    /// hyperparameter updates the modulation is fixed, so after
    /// [`CombinedFeatures::patch_rows`] only the patched rows' values
    /// are stale — everything else already holds the combination under
    /// the same `f`. The per-slot accumulation (length-major, with the
    /// `f_l == 0` skip) replays [`CombinedFeatures::combine_into`]
    /// exactly, so the partially recombined pattern is **bitwise** what
    /// a full recombination would produce.
    pub fn recombine_rows(&mut self, f: &[f64], rows: &[u32]) {
        assert_eq!(f.len(), self.components.c.len());
        for &r in rows {
            let (s, e) = (
                self.pattern.offsets[r as usize],
                self.pattern.offsets[r as usize + 1],
            );
            for v in &mut self.pattern.vals[s..e] {
                *v = 0.0;
            }
        }
        for (l, map) in self.maps.iter().enumerate() {
            let fl = f[l];
            if fl == 0.0 {
                continue;
            }
            let c = &self.components.c[l];
            for &r in rows {
                let (s, e) = (c.offsets[r as usize], c.offsets[r as usize + 1]);
                for k in s..e {
                    self.pattern.vals[map[k] as usize] += fl * c.vals[k];
                }
            }
        }
    }

    /// Row-width distribution of Φ's union pattern (invariant under
    /// recombination — the pattern is shared by every Φ(f)). This is
    /// what `GpModel`'s ELL auto-layout policy effectively decides on.
    pub fn row_width_stats(&self) -> RowWidthStats {
        self.pattern.row_width_stats()
    }

    /// Patch the given rows of every component matrix (growing the
    /// shape to `n` rows/cols if a node was appended), rebuild the
    /// union-pattern rows for exactly those rows, and refresh the
    /// scatter maps — the model-side half of a streaming graph delta.
    ///
    /// `patches[r][l] = (cols, vals)` must be sorted by column. The
    /// patched pattern is identical to what a fresh
    /// [`WalkComponents::prepare`] of the patched components would
    /// build (sorted union of the per-length row patterns), so later
    /// recombinations stay bitwise equal to the rebuilt-from-scratch
    /// path. The pattern's **value** array is left stale: call
    /// [`CombinedFeatures::combine_into`] before reading Φ.
    pub fn patch_rows(
        &mut self,
        n: usize,
        patches: &std::collections::BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>>,
    ) {
        let n_len = self.components.c.len();
        for l in 0..n_len {
            let per_l: std::collections::BTreeMap<u32, (Vec<u32>, Vec<f64>)> =
                patches.iter().map(|(&r, pl)| (r, pl[l].clone())).collect();
            self.components.c[l] =
                self.components.c[l].with_replaced_rows(n, n, &per_l);
        }
        let pattern_patches: std::collections::BTreeMap<u32, (Vec<u32>, Vec<f64>)> =
            patches
                .iter()
                .map(|(&r, pl)| {
                    let mut cols: Vec<u32> = pl
                        .iter()
                        .flat_map(|(c, _)| c.iter().copied())
                        .collect();
                    cols.sort_unstable();
                    cols.dedup();
                    let zeros = vec![0.0; cols.len()];
                    (r, (cols, zeros))
                })
                .collect();
        self.pattern = self.pattern.with_replaced_rows(n, n, &pattern_patches);
        self.maps = build_maps(&self.components, &self.pattern);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::rng::Rng;

    fn random_components(rng: &mut Rng, n: usize, lens: usize) -> WalkComponents {
        let mut c = Vec::new();
        for l in 0..lens {
            let mut b = CooBuilder::new(n, n);
            for i in 0..n {
                if l == 0 {
                    b.push(i as u32, i as u32, 1.0);
                } else {
                    for _ in 0..3 {
                        b.push(i as u32, rng.below(n) as u32, rng.normal());
                    }
                }
            }
            c.push(b.build());
        }
        WalkComponents::new(c)
    }

    #[test]
    fn prepared_combination_matches_oneshot() {
        let mut rng = Rng::new(0);
        let comps = random_components(&mut rng, 20, 4);
        let mut prepared = comps.prepare();
        for trial in 0..5 {
            let f: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            let fast = prepared.combine_into(&f).clone();
            let slow = comps.combine(&f);
            let (df, ds) = (fast.to_dense(), slow.to_dense());
            for i in 0..20 {
                for j in 0..20 {
                    assert!(
                        (df[i][j] - ds[i][j]).abs() < 1e-12,
                        "trial {trial} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_coefficients_give_zero_matrix() {
        let mut rng = Rng::new(1);
        let comps = random_components(&mut rng, 10, 3);
        let mut prepared = comps.prepare();
        let phi = prepared.combine_into(&[0.0, 0.0, 0.0]);
        assert!(phi.vals.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn row_width_stats_cover_union_pattern() {
        let mut rng = Rng::new(7);
        let comps = random_components(&mut rng, 30, 3);
        let per_len = comps.row_width_stats();
        assert_eq!(per_len.len(), 3);
        for (l, st) in per_len.iter().enumerate() {
            assert_eq!(st.n_rows, 30, "length {l}");
            assert_eq!(st.nnz, comps.c[l].nnz(), "length {l}");
            assert!(st.max >= 1 && st.mean > 0.0, "length {l}");
        }
        let prepared = comps.prepare();
        let union = prepared.row_width_stats();
        // The union pattern is at least as wide as any component and
        // no wider than their sum.
        let max_component = per_len.iter().map(|s| s.max).max().unwrap();
        let sum_nnz: usize = per_len.iter().map(|s| s.nnz).sum();
        assert!(union.max >= max_component);
        assert!(union.nnz <= sum_nnz);
        assert_eq!(union.n_rows, 30);
    }

    #[test]
    fn patch_rows_matches_fresh_prepare() {
        use std::collections::BTreeMap;
        let mut rng = Rng::new(5);
        let comps = random_components(&mut rng, 20, 3);
        let mut prepared = comps.prepare();
        // New content for rows 2 and 7, plus appended row 20 (growth
        // to 22 with an empty gap row 21).
        let mut patches: BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>> = BTreeMap::new();
        for &r in &[2u32, 7, 20] {
            let per_len: Vec<(Vec<u32>, Vec<f64>)> = (0..3)
                .map(|_| {
                    let mut cols: Vec<u32> =
                        (0..4).map(|_| rng.below(22) as u32).collect();
                    cols.sort_unstable();
                    cols.dedup();
                    let vals: Vec<f64> =
                        cols.iter().map(|_| rng.normal()).collect();
                    (cols, vals)
                })
                .collect();
            patches.insert(r, per_len);
        }
        prepared.patch_rows(22, &patches);
        // Reference: prepare() from scratch on the patched components.
        let mut fresh = prepared.components.prepare();
        assert_eq!(prepared.pattern.offsets, fresh.pattern.offsets);
        assert_eq!(prepared.pattern.cols, fresh.pattern.cols);
        let f = vec![0.7, -0.3, 1.1];
        let a = prepared.combine_into(&f).clone();
        let b = fresh.combine_into(&f);
        assert!(a == *b, "patched recombination differs from fresh prepare");
    }

    #[test]
    fn recombine_rows_matches_full_combination_bitwise() {
        use std::collections::BTreeMap;
        let mut rng = Rng::new(9);
        let comps = random_components(&mut rng, 15, 3);
        let f = vec![0.8, -0.4, 1.3];
        let mut a = comps.prepare();
        a.combine_into(&f);
        let mut b = a.clone();
        // Patch rows 1 and 9 in both, then recombine: partially in `a`,
        // fully in `b` — the value arrays must be bitwise equal.
        let mut patches: BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>> = BTreeMap::new();
        for &r in &[1u32, 9] {
            let per_len: Vec<(Vec<u32>, Vec<f64>)> = (0..3)
                .map(|_| {
                    let mut cols: Vec<u32> =
                        (0..4).map(|_| rng.below(15) as u32).collect();
                    cols.sort_unstable();
                    cols.dedup();
                    let vals: Vec<f64> =
                        cols.iter().map(|_| rng.normal()).collect();
                    (cols, vals)
                })
                .collect();
            patches.insert(r, per_len);
        }
        a.patch_rows(15, &patches);
        b.patch_rows(15, &patches);
        a.recombine_rows(&f, &[1, 9]);
        let full = b.combine_into(&f);
        assert!(a.pattern == *full, "partial recombination differs from full");
    }

    #[test]
    fn memory_accounting_positive() {
        let mut rng = Rng::new(2);
        let comps = random_components(&mut rng, 10, 3);
        assert!(comps.nnz() > 0);
        assert!(comps.memory_bytes() > comps.nnz() * 12);
        assert_eq!(comps.n_coeffs(), 3);
    }
}
