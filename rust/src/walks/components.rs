//! Per-length walk component matrices and their fast recombination.
//!
//! Training recombines `Φ(f) = Σ_l f_l C_l` at every optimiser step, so
//! the union sparsity pattern and per-length scatter maps are
//! precomputed once ([`CombinedFeatures`]); each recombination is then
//! a single fused scatter pass with no allocation or sorting.
//!
//! ## Row-segmented patching (streaming deltas)
//!
//! A graph delta rebuilds a handful of rows. [`CombinedFeatures`]
//! therefore keeps two stores, mirroring the stream's delta row-store:
//! the **compacted base** (component CSRs + union pattern + flat
//! scatter maps) and a **per-row overlay** of patched rows, each
//! carrying its own pattern segment and *row-relative* scatter maps.
//! [`CombinedFeatures::patch_rows`] only derives the affected rows'
//! segments — O(touched nnz), no CSR splice, no full map rebuild — and
//! [`CombinedFeatures::recombine_rows`] recombines exactly those rows.
//! [`CombinedFeatures::compact`] folds the overlay back (one O(nnz)
//! splice per matrix, map slots shifted arithmetically — bitwise the
//! maps a fresh [`WalkComponents::prepare`] would build). The full
//! rebuild `build_maps` only runs in `prepare`, guarded by the
//! [`CombinedFeatures::full_map_builds`] counter so the delta path can
//! prove it never pays it.

use crate::sparse::{CooBuilder, Csr, RowWidthStats};
use std::collections::BTreeMap;

/// The output of the walk engine: `c[l][i][j]` estimates `(W^l)[i][j]`.
#[derive(Clone, Debug)]
pub struct WalkComponents {
    pub c: Vec<Csr>,
}

impl WalkComponents {
    pub fn new(c: Vec<Csr>) -> Self {
        assert!(!c.is_empty());
        let n = c[0].n_rows;
        for m in &c {
            assert_eq!(m.n_rows, n);
            assert_eq!(m.n_cols, n);
        }
        WalkComponents { c }
    }

    pub fn n(&self) -> usize {
        self.c[0].n_rows
    }

    /// Number of modulation coefficients (l_max + 1).
    pub fn n_coeffs(&self) -> usize {
        self.c.len()
    }

    /// Total stored nonzeros across all lengths.
    pub fn nnz(&self) -> usize {
        self.c.iter().map(|m| m.nnz()).sum()
    }

    /// Row-width distribution of each per-length component matrix —
    /// the feature-build diagnostic behind the ELL layout decision
    /// (Theorem 1 bounds these widths w.h.p., which is exactly why the
    /// fixed-width layout pays off).
    pub fn row_width_stats(&self) -> Vec<RowWidthStats> {
        self.c.iter().map(|m| m.row_width_stats()).collect()
    }

    pub fn memory_bytes(&self) -> usize {
        self.c.iter().map(|m| m.memory_bytes()).sum()
    }

    /// One-shot combination Φ(f) = Σ_l f_l C_l (allocates; for repeated
    /// combination use [`CombinedFeatures`]).
    pub fn combine(&self, f: &[f64]) -> Csr {
        assert_eq!(f.len(), self.c.len(), "modulation length != l_max+1");
        let refs: Vec<&Csr> = self.c.iter().collect();
        Csr::linear_combination(&refs, f)
    }

    /// Precompute the union pattern + scatter maps for fast repeated
    /// recombination during training.
    pub fn prepare(&self) -> CombinedFeatures {
        let n = self.n();
        // Union pattern via a zero-weight linear combination trick:
        // build with all coefficient 1.0 on |values| to avoid cancel-drop.
        let mut b = CooBuilder::new(n, n);
        for m in &self.c {
            for r in 0..n {
                let (cols, _) = m.row(r);
                for c in cols {
                    b.push(r as u32, *c, 1.0);
                }
            }
        }
        let mut pattern = b.build();
        for v in &mut pattern.vals {
            *v = 0.0;
        }
        let maps = build_maps(self, &pattern);
        CombinedFeatures {
            components: self.clone(),
            pattern,
            maps,
            overlay: BTreeMap::new(),
            n,
            full_map_builds: 1,
        }
    }
}

/// Scatter map per length: flat position of each component entry in the
/// union pattern's value array. The **full** rebuild — only
/// [`WalkComponents::prepare`] runs it; the streaming delta path
/// derives per-row segments instead ([`CombinedFeatures::patch_rows`])
/// and proves it via [`CombinedFeatures::full_map_builds`].
fn build_maps(components: &WalkComponents, pattern: &Csr) -> Vec<Vec<u32>> {
    let n = pattern.n_rows;
    components
        .c
        .iter()
        .map(|m| {
            let mut map = Vec::with_capacity(m.nnz());
            for r in 0..n {
                let (cols, _) = m.row(r);
                let (pc, _) = pattern.row(r);
                let base = pattern.offsets[r];
                for c in cols {
                    let k = pc.binary_search(c).expect("pattern covers entry");
                    map.push((base + k) as u32);
                }
            }
            map
        })
        .collect()
}

/// One patched row staged in the [`CombinedFeatures`] overlay: its
/// per-length component rows, its union-pattern segment (cols + the
/// current combination values), and per-length **row-relative** scatter
/// maps (position of each component entry within the pattern row —
/// invariant under changes to every other row, which is what makes
/// per-row derivation sound).
#[derive(Clone, Debug)]
struct PatchedRow {
    per_len: Vec<(Vec<u32>, Vec<f64>)>,
    cols: Vec<u32>,
    vals: Vec<f64>,
    rel: Vec<Vec<u32>>,
}

/// Union-pattern recombiner: `combine_into` refreshes the value array of
/// the shared pattern in O(total nnz) with zero allocation, and the
/// streaming delta path patches + recombines single rows in
/// O(row nnz) through the overlay (module docs).
#[derive(Clone)]
pub struct CombinedFeatures {
    /// Compacted base component matrices. Rows staged in the overlay
    /// shadow these until the next [`CombinedFeatures::compact`]; use
    /// [`CombinedFeatures::component_row`] / `component_csr` for
    /// overlay-aware reads.
    pub components: WalkComponents,
    /// Compacted base union pattern; `vals` holds the latest
    /// combination of the base rows (overlay rows carry their own).
    pub pattern: Csr,
    /// For each length l, flat index into `pattern.vals` of each entry
    /// of `components.c[l]` (aligned to the compacted base).
    maps: Vec<Vec<u32>>,
    /// Delta row-store: rows patched since the last compaction.
    overlay: BTreeMap<u32, PatchedRow>,
    /// Logical node count (>= pattern.n_rows while appended rows are
    /// pending in the overlay).
    n: usize,
    /// Lifetime count of full `build_maps` passes (1 from
    /// `prepare`) — the delta path derives per-row segments only and
    /// must not move this.
    full_map_builds: usize,
}

impl CombinedFeatures {
    pub fn n(&self) -> usize {
        self.n
    }

    /// Rows currently staged in the delta overlay.
    pub fn overlay_rows(&self) -> usize {
        self.overlay.len()
    }

    /// How many times the full scatter-map rebuild ran (see the field
    /// doc) — the counter guard of the sub-linear delta path.
    pub fn full_map_builds(&self) -> usize {
        self.full_map_builds
    }

    /// Recompute Φ(f) into the shared pattern and return a reference.
    /// Folds any pending overlay first (full recombination wants one
    /// contiguous Φ) — a no-op in the steady training loop, where the
    /// overlay is empty.
    pub fn combine_into(&mut self, f: &[f64]) -> &Csr {
        assert_eq!(f.len(), self.components.c.len());
        self.compact();
        for v in &mut self.pattern.vals {
            *v = 0.0;
        }
        for (l, map) in self.maps.iter().enumerate() {
            let fl = f[l];
            if fl == 0.0 {
                continue;
            }
            let vals = &self.components.c[l].vals;
            for (slot, v) in map.iter().zip(vals) {
                self.pattern.vals[*slot as usize] += fl * v;
            }
        }
        &self.pattern
    }

    /// Materialise the current combination (base + overlay rows) as
    /// canonical CSR. A clone of the shared pattern when compacted.
    pub fn current(&self) -> Csr {
        if self.overlay.is_empty() && self.pattern.n_rows == self.n {
            return self.pattern.clone();
        }
        let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, (p.cols.clone(), p.vals.clone())))
            .collect();
        self.pattern.with_replaced_rows(self.n, self.n, &patches)
    }

    /// Union-pattern row `r` with its current combination values
    /// (overlay wins over base; grown rows are empty until patched).
    pub fn pattern_row(&self, r: usize) -> (&[u32], &[f64]) {
        if let Some(p) = self.overlay.get(&(r as u32)) {
            (&p.cols, &p.vals)
        } else if r < self.pattern.n_rows {
            self.pattern.row(r)
        } else {
            (&[], &[])
        }
    }

    /// Component row `(l, r)` with the overlay applied.
    pub fn component_row(&self, l: usize, r: usize) -> (&[u32], &[f64]) {
        if let Some(p) = self.overlay.get(&(r as u32)) {
            let (c, v) = &p.per_len[l];
            (c, v)
        } else if r < self.components.c[l].n_rows {
            self.components.c[l].row(r)
        } else {
            (&[], &[])
        }
    }

    /// Materialise component matrix `l` with the overlay applied (a
    /// clone when compacted) — what the modulation-gradient operands
    /// transpose.
    pub fn component_csr(&self, l: usize) -> Csr {
        if self.overlay.is_empty() && self.components.c[l].n_rows == self.n {
            return self.components.c[l].clone();
        }
        let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, p.per_len[l].clone()))
            .collect();
        self.components.c[l]
            .with_replaced_rows(self.n, self.n, &patches)
    }

    /// Recompute the combined values of exactly `rows` under `f`,
    /// leaving every other row untouched.
    ///
    /// Steady-state invariant of the streaming delta path: between
    /// hyperparameter updates the modulation is fixed, so after
    /// [`CombinedFeatures::patch_rows`] only the patched rows' values
    /// are stale — everything else already holds the combination under
    /// the same `f`. The per-slot accumulation (length-major, with the
    /// `f_l == 0` skip) replays [`CombinedFeatures::combine_into`]
    /// exactly, so the partially recombined state is **bitwise** what
    /// a full recombination would produce. Overlay rows recombine
    /// through their row-relative maps, base rows through their flat
    /// segment — same additions, same order.
    pub fn recombine_rows(&mut self, f: &[f64], rows: &[u32]) {
        assert_eq!(f.len(), self.components.c.len());
        for &r in rows {
            if let Some(p) = self.overlay.get_mut(&r) {
                for v in &mut p.vals {
                    *v = 0.0;
                }
                for (l, &fl) in f.iter().enumerate() {
                    if fl == 0.0 {
                        continue;
                    }
                    let (_, cvals) = &p.per_len[l];
                    for (rel, v) in p.rel[l].iter().zip(cvals) {
                        p.vals[*rel as usize] += fl * v;
                    }
                }
            } else {
                let (s, e) = (
                    self.pattern.offsets[r as usize],
                    self.pattern.offsets[r as usize + 1],
                );
                for v in &mut self.pattern.vals[s..e] {
                    *v = 0.0;
                }
                for (l, &fl) in f.iter().enumerate() {
                    if fl == 0.0 {
                        continue;
                    }
                    let c = &self.components.c[l];
                    let map = &self.maps[l];
                    let (cs, ce) =
                        (c.offsets[r as usize], c.offsets[r as usize + 1]);
                    for k in cs..ce {
                        self.pattern.vals[map[k] as usize] += fl * c.vals[k];
                    }
                }
            }
        }
    }

    /// Row-width distribution of Φ's union pattern (invariant under
    /// recombination — the pattern is shared by every Φ(f)). Reported
    /// off the compacted base; overlay rows are a vanishing fraction
    /// between compactions.
    pub fn row_width_stats(&self) -> RowWidthStats {
        self.pattern.row_width_stats()
    }

    /// Stage new content for the given rows (growing the logical shape
    /// to `n` if a node was appended): per row, derive its union
    /// pattern segment and row-relative scatter maps, and park
    /// everything in the overlay — **O(touched nnz)**, no component
    /// splice, no pattern splice, no full map rebuild (the base stores
    /// are untouched until [`CombinedFeatures::compact`]).
    ///
    /// `patches[r][l] = (cols, vals)` must be sorted by column. The
    /// per-row segments are exactly what a fresh
    /// [`WalkComponents::prepare`] of the patched components would
    /// build for those rows (sorted union of the per-length row
    /// patterns), so later recombinations stay bitwise equal to the
    /// rebuilt-from-scratch path. The staged **value** segment is left
    /// stale: call [`CombinedFeatures::recombine_rows`] (or a full
    /// [`CombinedFeatures::combine_into`]) before reading Φ.
    pub fn patch_rows(
        &mut self,
        n: usize,
        patches: &BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>>,
    ) {
        assert!(n >= self.n);
        self.n = n;
        let n_len = self.components.c.len();
        for (&r, per_len) in patches {
            assert!((r as usize) < n, "patched row {r} out of range");
            assert_eq!(per_len.len(), n_len);
            // Union pattern of the row (sorted, deduped — identical to
            // the CooBuilder union in `prepare`).
            let mut cols: Vec<u32> = per_len
                .iter()
                .flat_map(|(c, _)| c.iter().copied())
                .collect();
            cols.sort_unstable();
            cols.dedup();
            // Row-relative scatter maps per length.
            let rel: Vec<Vec<u32>> = per_len
                .iter()
                .map(|(pc, _)| {
                    pc.iter()
                        .map(|c| {
                            cols.binary_search(c).expect("union covers entry")
                                as u32
                        })
                        .collect()
                })
                .collect();
            let vals = vec![0.0; cols.len()];
            self.overlay.insert(
                r,
                PatchedRow { per_len: per_len.clone(), cols, vals, rel },
            );
        }
    }

    /// Fold the overlay into the base stores: one O(nnz) splice per
    /// component matrix and the pattern, with the flat scatter maps
    /// re-derived by **arithmetic slot shifting** (unpatched rows keep
    /// their relative layout, so their flat slots just move by the
    /// pattern-offset delta; patched rows materialise their relative
    /// maps) — bitwise the maps a full `build_maps` would produce,
    /// without its per-entry binary searches. No-op while compacted.
    pub fn compact(&mut self) {
        if self.overlay.is_empty() && self.pattern.n_rows == self.n {
            return;
        }
        let n = self.n;
        let n_len = self.components.c.len();
        let old_p_off = self.pattern.offsets.clone();
        let p_patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, (p.cols.clone(), p.vals.clone())))
            .collect();
        self.pattern = self.pattern.with_replaced_rows(n, n, &p_patches);
        for l in 0..n_len {
            let old_c_off = self.components.c[l].offsets.clone();
            let old_c_rows = self.components.c[l].n_rows;
            let c_patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
                .overlay
                .iter()
                .map(|(&r, p)| (r, p.per_len[l].clone()))
                .collect();
            self.components.c[l] =
                self.components.c[l].with_replaced_rows(n, n, &c_patches);
            let old_map = std::mem::take(&mut self.maps[l]);
            let mut new_map =
                Vec::with_capacity(self.components.c[l].nnz());
            for r in 0..n {
                if let Some(p) = self.overlay.get(&(r as u32)) {
                    let base = self.pattern.offsets[r];
                    new_map.extend(
                        p.rel[l].iter().map(|&rel| (base + rel as usize) as u32),
                    );
                } else if r < old_c_rows {
                    let (os, oe) = (old_c_off[r], old_c_off[r + 1]);
                    let shift =
                        self.pattern.offsets[r] as i64 - old_p_off[r] as i64;
                    for k in os..oe {
                        new_map.push((old_map[k] as i64 + shift) as u32);
                    }
                }
            }
            self.maps[l] = new_map;
        }
        self.overlay.clear();
    }

    /// Test/diagnostic hook: the flat maps a full rebuild would produce
    /// for the current (compacted) state — used to pin the compaction
    /// splice bitwise against `build_maps`.
    #[cfg(test)]
    fn rebuilt_maps(&self) -> Vec<Vec<u32>> {
        build_maps(&self.components, &self.pattern)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::rng::Rng;

    fn random_components(rng: &mut Rng, n: usize, lens: usize) -> WalkComponents {
        let mut c = Vec::new();
        for l in 0..lens {
            let mut b = CooBuilder::new(n, n);
            for i in 0..n {
                if l == 0 {
                    b.push(i as u32, i as u32, 1.0);
                } else {
                    for _ in 0..3 {
                        b.push(i as u32, rng.below(n) as u32, rng.normal());
                    }
                }
            }
            c.push(b.build());
        }
        WalkComponents::new(c)
    }

    fn random_patches(
        rng: &mut Rng,
        rows: &[u32],
        n: usize,
        lens: usize,
    ) -> BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>> {
        let mut patches: BTreeMap<u32, Vec<(Vec<u32>, Vec<f64>)>> =
            BTreeMap::new();
        for &r in rows {
            let per_len: Vec<(Vec<u32>, Vec<f64>)> = (0..lens)
                .map(|_| {
                    let mut cols: Vec<u32> =
                        (0..4).map(|_| rng.below(n) as u32).collect();
                    cols.sort_unstable();
                    cols.dedup();
                    let vals: Vec<f64> =
                        cols.iter().map(|_| rng.normal()).collect();
                    (cols, vals)
                })
                .collect();
            patches.insert(r, per_len);
        }
        patches
    }

    #[test]
    fn prepared_combination_matches_oneshot() {
        let mut rng = Rng::new(0);
        let comps = random_components(&mut rng, 20, 4);
        let mut prepared = comps.prepare();
        for trial in 0..5 {
            let f: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            let fast = prepared.combine_into(&f).clone();
            let slow = comps.combine(&f);
            let (df, ds) = (fast.to_dense(), slow.to_dense());
            for i in 0..20 {
                for j in 0..20 {
                    assert!(
                        (df[i][j] - ds[i][j]).abs() < 1e-12,
                        "trial {trial} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_coefficients_give_zero_matrix() {
        let mut rng = Rng::new(1);
        let comps = random_components(&mut rng, 10, 3);
        let mut prepared = comps.prepare();
        let phi = prepared.combine_into(&[0.0, 0.0, 0.0]);
        assert!(phi.vals.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn row_width_stats_cover_union_pattern() {
        let mut rng = Rng::new(7);
        let comps = random_components(&mut rng, 30, 3);
        let per_len = comps.row_width_stats();
        assert_eq!(per_len.len(), 3);
        for (l, st) in per_len.iter().enumerate() {
            assert_eq!(st.n_rows, 30, "length {l}");
            assert_eq!(st.nnz, comps.c[l].nnz(), "length {l}");
            assert!(st.max >= 1 && st.mean > 0.0, "length {l}");
        }
        let prepared = comps.prepare();
        let union = prepared.row_width_stats();
        // The union pattern is at least as wide as any component and
        // no wider than their sum.
        let max_component = per_len.iter().map(|s| s.max).max().unwrap();
        let sum_nnz: usize = per_len.iter().map(|s| s.nnz).sum();
        assert!(union.max >= max_component);
        assert!(union.nnz <= sum_nnz);
        assert_eq!(union.n_rows, 30);
    }

    /// The segmented patch path must be observationally identical to a
    /// fresh prepare of the patched components: same materialised Φ,
    /// same recombinations — and after compaction, structurally the
    /// same pattern and bitwise the same flat maps as a full
    /// `build_maps`, without ever running one.
    #[test]
    fn patch_rows_matches_fresh_prepare() {
        let mut rng = Rng::new(5);
        let comps = random_components(&mut rng, 20, 3);
        let mut prepared = comps.prepare();
        assert_eq!(prepared.full_map_builds(), 1);
        // New content for rows 2 and 7, plus appended row 20 (growth
        // to 22 with an empty gap row 21).
        let patches = random_patches(&mut rng, &[2, 7, 20], 22, 3);
        prepared.patch_rows(22, &patches);
        assert_eq!(prepared.overlay_rows(), 3);
        assert_eq!(
            prepared.full_map_builds(),
            1,
            "patch_rows ran a full map rebuild"
        );
        let f = vec![0.7, -0.3, 1.1];
        prepared.recombine_rows(&f, &[2, 7, 20]);
        // Reference: prepare() from scratch on the patched components.
        let mut base = comps.clone();
        for l in 0..3 {
            let per_l: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = patches
                .iter()
                .map(|(&r, pl)| (r, pl[l].clone()))
                .collect();
            base.c[l] = base.c[l].with_replaced_rows(22, 22, &per_l);
        }
        let mut fresh = base.prepare();
        // Base rows of `prepared` still hold the PRE-patch combination
        // (recombine_rows only touched the patched rows) — recombine
        // everything in the reference AND in a compacted copy.
        let b = fresh.combine_into(&f).clone();
        let mut compacted = prepared.clone();
        compacted.compact();
        assert_eq!(compacted.overlay_rows(), 0);
        assert_eq!(compacted.pattern.offsets, fresh.pattern.offsets);
        assert_eq!(compacted.pattern.cols, fresh.pattern.cols);
        // Compaction's arithmetic slot shift == the full binary-search
        // rebuild, bitwise.
        let rebuilt = compacted.rebuilt_maps();
        for l in 0..3 {
            assert_eq!(
                compacted.maps[l], rebuilt[l],
                "length {l}: compacted maps != build_maps"
            );
        }
        let full = compacted.combine_into(&f).clone();
        assert!(full == b, "patched recombination differs from fresh prepare");
    }

    #[test]
    fn compaction_slot_shift_matches_full_build_maps_bitwise() {
        let mut rng = Rng::new(13);
        let comps = random_components(&mut rng, 25, 3);
        let mut prepared = comps.prepare();
        for round in 0..3 {
            // Patch a few rows, sometimes including one appended row.
            let mut rows: Vec<u32> = (0..2 + rng.below(3))
                .map(|_| rng.below(prepared.n() + 1) as u32)
                .collect();
            rows.sort_unstable();
            rows.dedup();
            let n_new = prepared.n().max(*rows.iter().max().unwrap() as usize + 1);
            let patches = random_patches(&mut rng, &rows, n_new, 3);
            prepared.patch_rows(n_new, &patches);
            prepared.compact();
            let rebuilt = prepared.rebuilt_maps();
            for l in 0..3 {
                assert_eq!(
                    prepared.maps[l], rebuilt[l],
                    "round {round}, length {l}: spliced maps != build_maps"
                );
            }
        }
        assert_eq!(prepared.full_map_builds(), 1, "only prepare may build");
    }

    #[test]
    fn recombine_rows_matches_full_combination_bitwise() {
        let mut rng = Rng::new(9);
        let comps = random_components(&mut rng, 15, 3);
        let f = vec![0.8, -0.4, 1.3];
        let mut a = comps.prepare();
        a.combine_into(&f);
        let mut b = a.clone();
        // Patch rows 1 and 9 in both, then recombine: partially in `a`
        // (overlay path), fully in `b` — the materialised combinations
        // must be bitwise equal, before and after compacting `a`.
        let patches = random_patches(&mut rng, &[1, 9], 15, 3);
        a.patch_rows(15, &patches);
        b.patch_rows(15, &patches);
        a.recombine_rows(&f, &[1, 9]);
        let full = b.combine_into(&f).clone();
        assert!(
            a.current() == full,
            "partial recombination differs from full"
        );
        a.compact();
        assert!(a.current() == full, "compaction changed the combination");
        // Base-row recombination (no overlay entry) also replays the
        // full pass bitwise.
        a.recombine_rows(&f, &[0, 3]);
        assert!(a.current() == full, "base-row recombine drifted");
    }

    #[test]
    fn component_and_pattern_row_reads_are_overlay_aware() {
        let mut rng = Rng::new(11);
        let comps = random_components(&mut rng, 12, 3);
        let mut prepared = comps.prepare();
        let f = vec![1.0, 0.5, 0.25];
        prepared.combine_into(&f);
        let patches = random_patches(&mut rng, &[4, 12], 13, 3);
        prepared.patch_rows(13, &patches);
        prepared.recombine_rows(&f, &[4, 12]);
        for &r in &[4u32, 12] {
            for l in 0..3 {
                let (c, _) = prepared.component_row(l, r as usize);
                assert_eq!(c, &patches[&r][l].0[..], "component row {r} l={l}");
            }
        }
        // Materialised views agree with row reads everywhere.
        let cur = prepared.current();
        for r in 0..13 {
            let (pc, pv) = prepared.pattern_row(r);
            let (cc, cv) = cur.row(r);
            assert_eq!(pc, cc, "pattern row {r}");
            assert_eq!(pv, cv, "pattern vals {r}");
        }
        for l in 0..3 {
            let mat = prepared.component_csr(l);
            for r in 0..13 {
                let (c, v) = prepared.component_row(l, r);
                assert_eq!(mat.row(r), (c, v), "component_csr row {r} l={l}");
            }
        }
    }

    #[test]
    fn memory_accounting_positive() {
        let mut rng = Rng::new(2);
        let comps = random_components(&mut rng, 10, 3);
        assert!(comps.nnz() > 0);
        assert!(comps.memory_bytes() > comps.nnz() * 12);
        assert_eq!(comps.n_coeffs(), 3);
    }
}
