//! Monte-Carlo quality diagnostic: per-entry variance of the GRF
//! kernel estimator across independent walk seeds.
//!
//! The paper's estimator is unbiased — `E[Φ Φᵀ] = K` entrywise — but
//! its *variance* is what decides how many walks a deployment needs.
//! [`kernel_variance`] measures it empirically for whichever
//! [`Termination`] scheme the config selects: re-run the walk engine
//! under several independent seeds, evaluate `K̂_ij = ⟨Φ_i, Φ_j⟩` on a
//! fixed set of sampled entries, and average the across-seed sample
//! variance over those entries. The result is published to the
//! scheme's registry gauge (`grf_variance_iid` /
//! `grf_variance_antithetic` / `grf_variance_qmc`, and the matching
//! `metric_grf_variance_*` bench rows), giving the telemetry surface a
//! statistical-quality signal next to its throughput ones — and
//! giving each correlated-termination walker the iid baseline it must
//! beat, under identical walks, seeds, and sampled entries.

use super::engine::Termination;
use super::{WalkConfig, WalkSampler};
use crate::graph::Graph;
use crate::obs;
use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Dot product of two CSR rows (sorted-column two-pointer merge).
fn row_dot(a: &Csr, i: usize, b: &Csr, j: usize) -> f64 {
    let (ca, va) = a.row(i);
    let (cb, vb) = b.row(j);
    let (mut p, mut q, mut acc) = (0, 0, 0.0);
    while p < ca.len() && q < cb.len() {
        match ca[p].cmp(&cb[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                acc += va[p] * vb[q];
                p += 1;
                q += 1;
            }
        }
    }
    acc
}

/// Mean per-entry variance of the kernel estimate `K̂ = Φ Φᵀ` across
/// independent walk seeds, on `n_pairs` node pairs drawn from
/// `pair_seed` (diagonal entries included — they dominate the
/// estimator's error in practice). The walker runs under
/// `cfg.termination`, so calling this once per scheme with identical
/// `(cfg.n_walks, seeds, n_pairs, pair_seed)` is an
/// apples-to-apples scheme comparison.
///
/// Runs the full walk engine once per seed (`seeds.len() ≥ 2`
/// required), so this is an offline diagnostic, not a serving-path
/// computation. Publishes the result to the scheme's
/// `grf_variance_*` gauge before returning it.
pub fn kernel_variance(
    g: &Graph,
    cfg: &WalkConfig,
    coeffs: &[f64],
    seeds: &[u64],
    n_pairs: usize,
    pair_seed: u64,
) -> f64 {
    assert!(
        seeds.len() >= 2,
        "variance across seeds needs at least 2 seeds"
    );
    assert!(n_pairs > 0, "need at least one sampled kernel entry");
    let n = g.num_nodes();
    let mut rng = Rng::new(pair_seed).split(0x62F5);
    let pairs: Vec<(usize, usize)> = (0..n_pairs)
        .map(|k| {
            // Every 4th pair is a diagonal entry.
            let i = rng.below(n);
            let j = if k % 4 == 0 { i } else { rng.below(n) };
            (i, j)
        })
        .collect();
    // estimates[p][s] = K̂_{pairs[p]} under seeds[s].
    let mut estimates = vec![Vec::with_capacity(seeds.len()); pairs.len()];
    for &seed in seeds {
        let phi = WalkSampler::new(g, cfg, seed).features(coeffs);
        for (p, &(i, j)) in pairs.iter().enumerate() {
            estimates[p].push(row_dot(&phi, i, &phi, j));
        }
    }
    let m = seeds.len() as f64;
    let mean_var = estimates
        .iter()
        .map(|es| {
            let mean = es.iter().sum::<f64>() / m;
            es.iter().map(|e| (e - mean).powi(2)).sum::<f64>() / (m - 1.0)
        })
        .sum::<f64>()
        / pairs.len() as f64;
    match cfg.termination {
        Termination::Iid => obs::registry::GRF_VARIANCE_IID.set(mean_var),
        Termination::Antithetic => {
            obs::registry::GRF_VARIANCE_ANTITHETIC.set(mean_var)
        }
        Termination::Qmc => obs::registry::GRF_VARIANCE_QMC.set(mean_var),
    }
    mean_var
}

/// [`kernel_variance`] with the termination scheme pinned to
/// [`Termination::Iid`] regardless of `cfg` — the historical entry
/// point, kept so existing baselines keep meaning "the iid walker".
pub fn kernel_variance_iid(
    g: &Graph,
    cfg: &WalkConfig,
    coeffs: &[f64],
    seeds: &[u64],
    n_pairs: usize,
    pair_seed: u64,
) -> f64 {
    let cfg = WalkConfig { termination: Termination::Iid, ..cfg.clone() };
    kernel_variance(g, &cfg, coeffs, seeds, n_pairs, pair_seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::ring;

    fn cfg() -> WalkConfig {
        WalkConfig {
            n_walks: 24,
            p_halt: 0.2,
            max_len: 3,
            reweight: true,
            normalize: true,
            termination: Termination::Iid,
            threads: 1,
        }
    }

    #[test]
    fn variance_is_finite_positive_and_seed_deterministic() {
        let _g = crate::obs::registry::test_lock();
        let g = ring(64);
        let coeffs = [1.0, 0.5, 0.25, 0.125];
        let v1 = kernel_variance_iid(&g, &cfg(), &coeffs, &[0, 1, 2], 16, 7);
        assert!(v1.is_finite() && v1 >= 0.0, "variance = {v1}");
        // Independent seeds genuinely disagree on a Monte-Carlo
        // estimator, so the variance is strictly positive.
        assert!(v1 > 0.0);
        // Deterministic in (seeds, pair_seed).
        let v2 = kernel_variance_iid(&g, &cfg(), &coeffs, &[0, 1, 2], 16, 7);
        assert_eq!(v1, v2);
        // The gauge carries the published value.
        assert_eq!(crate::obs::registry::GRF_VARIANCE_IID.get(), v2);
    }

    #[test]
    fn more_walks_shrink_the_variance() {
        let _g = crate::obs::registry::test_lock();
        let g = ring(64);
        let coeffs = [1.0, 0.5, 0.25, 0.125];
        let few = WalkConfig { n_walks: 8, ..cfg() };
        let many = WalkConfig { n_walks: 128, ..cfg() };
        let v_few = kernel_variance_iid(&g, &few, &coeffs, &[0, 1, 2, 3], 24, 11);
        let v_many =
            kernel_variance_iid(&g, &many, &coeffs, &[0, 1, 2, 3], 24, 11);
        // 16x the walks: expect a clear drop (the estimator averages
        // i.i.d. walkers, so variance scales ~1/n_walks; allow slack).
        assert!(
            v_many < v_few,
            "variance should fall with walk count: few={v_few} many={v_many}"
        );
    }

    #[test]
    fn correlated_schemes_beat_iid_at_fixed_walk_count() {
        // The PR's headline claim, at a termination-sensitive
        // configuration (p_halt·max_len = 1, modulation weight out to
        // depth 5): both correlated schemes cut the across-seed
        // variance at identical n_walks, seeds, and sampled entries.
        // 12 seeds keep the variance estimator tight enough that the
        // ordering is stable across pair_seed choices (simulated win
        // rate ≳ 99.9%; qmc additionally clears a 10% margin).
        let _g = crate::obs::registry::test_lock();
        let g = ring(48);
        let coeffs = [1.0, 0.5, 0.25, 0.12, 0.06, 0.03];
        let base = WalkConfig {
            n_walks: 16,
            p_halt: 0.2,
            max_len: 5,
            reweight: true,
            normalize: true,
            termination: Termination::Iid,
            threads: 1,
        };
        let seeds: Vec<u64> = (0..12).collect();
        let v_iid = kernel_variance(&g, &base, &coeffs, &seeds, 48, 3);
        let mut v = std::collections::HashMap::new();
        for scheme in [Termination::Antithetic, Termination::Qmc] {
            let c = WalkConfig { termination: scheme, ..base.clone() };
            v.insert(scheme.as_str(), kernel_variance(&g, &c, &coeffs, &seeds, 48, 3));
        }
        let (va, vq) = (v["antithetic"], v["qmc"]);
        assert!(
            va < v_iid,
            "antithetic must beat iid at fixed n_walks: {va} vs {v_iid}"
        );
        assert!(vq < v_iid, "qmc must beat iid at fixed n_walks: {vq} vs {v_iid}");
        assert!(vq < 0.9 * v_iid, "qmc should clear a clean margin: {vq} vs {v_iid}");
        // Each scheme published to its own gauge.
        assert_eq!(crate::obs::registry::GRF_VARIANCE_IID.get(), v_iid);
        assert_eq!(crate::obs::registry::GRF_VARIANCE_ANTITHETIC.get(), va);
        assert_eq!(crate::obs::registry::GRF_VARIANCE_QMC.get(), vq);
    }

    #[test]
    fn iid_wrapper_pins_the_scheme() {
        let _g = crate::obs::registry::test_lock();
        let g = ring(32);
        let coeffs = [1.0, 0.5, 0.25, 0.125];
        let qmc_cfg = WalkConfig { termination: Termination::Qmc, ..cfg() };
        // The wrapper overrides the scheme: same value as an explicit
        // iid config, not the qmc one.
        let via_wrapper =
            kernel_variance_iid(&g, &qmc_cfg, &coeffs, &[0, 1, 2], 12, 5);
        let explicit = kernel_variance(&g, &cfg(), &coeffs, &[0, 1, 2], 12, 5);
        assert_eq!(via_wrapper, explicit);
    }

    #[test]
    fn row_dot_matches_dense() {
        let mut b = crate::sparse::CooBuilder::new(3, 4);
        for (r, c, v) in
            [(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (1, 2, 4.0), (2, 3, 5.0)]
        {
            b.push(r, c, v);
        }
        let m = b.build();
        assert_eq!(row_dot(&m, 0, &m, 1), 8.0); // overlap at col 2: 2*4
        assert_eq!(row_dot(&m, 0, &m, 0), 5.0); // 1 + 4
        assert_eq!(row_dot(&m, 0, &m, 2), 0.0); // disjoint
    }
}
