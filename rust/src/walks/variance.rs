//! Monte-Carlo quality diagnostic: per-entry variance of the GRF
//! kernel estimator across independent walk seeds.
//!
//! The paper's estimator is unbiased — `E[Φ Φᵀ] = K` entrywise — but
//! its *variance* is what decides how many walks a deployment needs.
//! [`kernel_variance_iid`] measures it empirically for the i.i.d.
//! walker: re-run the walk engine under several independent seeds,
//! evaluate `K̂_ij = ⟨Φ_i, Φ_j⟩` on a fixed set of sampled entries, and
//! average the across-seed sample variance over those entries. The
//! result is published as the `grf_variance_iid` registry gauge (and a
//! `metric_grf_variance_iid` bench row), giving the telemetry surface a
//! statistical-quality signal next to its throughput ones — and giving
//! a future quasi-Monte-Carlo walker the baseline it must beat.

use super::{sample_components, WalkConfig};
use crate::graph::Graph;
use crate::obs;
use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Dot product of two CSR rows (sorted-column two-pointer merge).
fn row_dot(a: &Csr, i: usize, b: &Csr, j: usize) -> f64 {
    let (ca, va) = a.row(i);
    let (cb, vb) = b.row(j);
    let (mut p, mut q, mut acc) = (0, 0, 0.0);
    while p < ca.len() && q < cb.len() {
        match ca[p].cmp(&cb[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                acc += va[p] * vb[q];
                p += 1;
                q += 1;
            }
        }
    }
    acc
}

/// Mean per-entry variance of the kernel estimate `K̂ = Φ Φᵀ` across
/// independent walk seeds, on `n_pairs` node pairs drawn from
/// `pair_seed` (diagonal entries included — they dominate the
/// estimator's error in practice).
///
/// Runs the full walk engine once per seed (`seeds.len() ≥ 2`
/// required), so this is an offline diagnostic, not a serving-path
/// computation. Publishes the result to the `grf_variance_iid` gauge
/// before returning it.
pub fn kernel_variance_iid(
    g: &Graph,
    cfg: &WalkConfig,
    coeffs: &[f64],
    seeds: &[u64],
    n_pairs: usize,
    pair_seed: u64,
) -> f64 {
    assert!(
        seeds.len() >= 2,
        "variance across seeds needs at least 2 seeds"
    );
    assert!(n_pairs > 0, "need at least one sampled kernel entry");
    let n = g.num_nodes();
    let mut rng = Rng::new(pair_seed).split(0x62F5);
    let pairs: Vec<(usize, usize)> = (0..n_pairs)
        .map(|k| {
            // Every 4th pair is a diagonal entry.
            let i = rng.below(n);
            let j = if k % 4 == 0 { i } else { rng.below(n) };
            (i, j)
        })
        .collect();
    // estimates[p][s] = K̂_{pairs[p]} under seeds[s].
    let mut estimates = vec![Vec::with_capacity(seeds.len()); pairs.len()];
    for &seed in seeds {
        let phi = sample_components(g, cfg, seed).combine(coeffs);
        for (p, &(i, j)) in pairs.iter().enumerate() {
            estimates[p].push(row_dot(&phi, i, &phi, j));
        }
    }
    let m = seeds.len() as f64;
    let mean_var = estimates
        .iter()
        .map(|es| {
            let mean = es.iter().sum::<f64>() / m;
            es.iter().map(|e| (e - mean).powi(2)).sum::<f64>() / (m - 1.0)
        })
        .sum::<f64>()
        / pairs.len() as f64;
    obs::registry::GRF_VARIANCE_IID.set(mean_var);
    mean_var
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::ring;

    fn cfg() -> WalkConfig {
        WalkConfig {
            n_walks: 24,
            p_halt: 0.2,
            max_len: 3,
            reweight: true,
            normalize: true,
            threads: 1,
        }
    }

    #[test]
    fn variance_is_finite_positive_and_seed_deterministic() {
        let _g = crate::obs::registry::test_lock();
        let g = ring(64);
        let coeffs = [1.0, 0.5, 0.25, 0.125];
        let v1 = kernel_variance_iid(&g, &cfg(), &coeffs, &[0, 1, 2], 16, 7);
        assert!(v1.is_finite() && v1 >= 0.0, "variance = {v1}");
        // Independent seeds genuinely disagree on a Monte-Carlo
        // estimator, so the variance is strictly positive.
        assert!(v1 > 0.0);
        // Deterministic in (seeds, pair_seed).
        let v2 = kernel_variance_iid(&g, &cfg(), &coeffs, &[0, 1, 2], 16, 7);
        assert_eq!(v1, v2);
        // The gauge carries the published value.
        assert_eq!(crate::obs::registry::GRF_VARIANCE_IID.get(), v2);
    }

    #[test]
    fn more_walks_shrink_the_variance() {
        let _g = crate::obs::registry::test_lock();
        let g = ring(64);
        let coeffs = [1.0, 0.5, 0.25, 0.125];
        let few = WalkConfig { n_walks: 8, ..cfg() };
        let many = WalkConfig { n_walks: 128, ..cfg() };
        let v_few = kernel_variance_iid(&g, &few, &coeffs, &[0, 1, 2, 3], 24, 11);
        let v_many =
            kernel_variance_iid(&g, &many, &coeffs, &[0, 1, 2, 3], 24, 11);
        // 16x the walks: expect a clear drop (the estimator averages
        // i.i.d. walkers, so variance scales ~1/n_walks; allow slack).
        assert!(
            v_many < v_few,
            "variance should fall with walk count: few={v_few} many={v_many}"
        );
    }

    #[test]
    fn row_dot_matches_dense() {
        let mut b = crate::sparse::CooBuilder::new(3, 4);
        for (r, c, v) in
            [(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (1, 2, 4.0), (2, 3, 5.0)]
        {
            b.push(r, c, v);
        }
        let m = b.build();
        assert_eq!(row_dot(&m, 0, &m, 1), 8.0); // overlap at col 2: 2*4
        assert_eq!(row_dot(&m, 0, &m, 0), 5.0); // 1 + 4
        assert_eq!(row_dot(&m, 0, &m, 2), 0.0); // disjoint
    }
}
