//! Random-walk simulation (paper Alg. 2), parallel over source nodes.

use super::components::WalkComponents;
use crate::graph::Graph;
use crate::sparse::Csr;
use crate::util::parallel::{num_threads, par_map_chunks};
use crate::util::rng::Rng;

/// Configuration of the GRF sampler.
#[derive(Clone, Debug)]
pub struct WalkConfig {
    /// Walks per node (paper `n`). Theorem 1: the number needed for an
    /// accurate estimate is independent of graph size N.
    pub n_walks: usize,
    /// Termination probability per step (paper `p`).
    pub p_halt: f64,
    /// Maximum walk length `l_max`; walks are truncated here and the
    /// modulation function is zero beyond it (App. C.1).
    pub max_len: usize,
    /// `false` switches to the *ad-hoc* ablation kernel (paper Eq. 13):
    /// loads are only products of edge weights, with no importance
    /// reweighting by `1/p(subwalk)`. Still a valid PSD kernel, but no
    /// longer unbiased for the target power series.
    pub reweight: bool,
    /// Walk the *symmetrically normalised* adjacency
    /// `Wn = D^{-1/2} W D^{-1/2}` instead of raw W (default true).
    /// Wn's spectrum lies in [-1, 1], so Theorem 1's constant
    /// `c = Σ|f_r| (max W d/(1-p))^r` stays small: the per-step load
    /// factor becomes `√(d_u/d_v)/(1-p)` instead of `d_u·w/(1-p)`,
    /// which diverges with degree on unweighted graphs. Kernels are
    /// then power series of Wn — e.g. diffusion on the normalised
    /// Laplacian, `exp(-βL̃) = e^{-β} exp(βWn)`.
    pub normalize: bool,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for WalkConfig {
    fn default() -> Self {
        WalkConfig {
            n_walks: 100,
            p_halt: 0.1,
            max_len: 10,
            reweight: true,
            normalize: true,
            threads: 0,
        }
    }
}

impl WalkConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            num_threads()
        } else {
            self.threads
        }
    }
}

/// Per-chunk CSR fragment: rows [start, end) of each C_l.
struct ChunkOut {
    start: usize,
    /// For each l: (row_lengths, cols, vals).
    per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)>,
}

/// All deposit records of one source node's walks, flattened:
/// walk `t` deposited `deposits[offsets[t]..offsets[t+1]]`, one entry
/// per visited step in step order (so index `l` within the slice is
/// the deposit into `C_l`). This is the replayable raw material of the
/// streaming subsystem: a single walk can be swapped out and the
/// node's component rows rebuilt bit-identically.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeWalks {
    pub offsets: Vec<u32>,
    pub deposits: Vec<(u32, f64)>,
}

impl NodeWalks {
    pub fn n_walks(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    #[inline]
    pub fn walk(&self, t: usize) -> &[(u32, f64)] {
        &self.deposits[self.offsets[t] as usize..self.offsets[t + 1] as usize]
    }
}

/// Output of [`sample_components_indexed`]: the component matrices plus
/// the per-walk deposit store and the **visit index**
/// `visit[j] = [(source, walk), ...]` listing every walk whose
/// trajectory stepped through node `j`. An edge delta touching (u, v)
/// invalidates exactly `visit[u] ∪ visit[v]` (walk transitions are
/// node-local: a walk that never visited either endpoint replays
/// bit-identically under its per-walk RNG stream).
pub struct IndexedWalks {
    pub components: WalkComponents,
    pub store: Vec<NodeWalks>,
    pub visit: Vec<Vec<(u32, u32)>>,
}

/// The deterministic per-walk RNG stream: walk `t` from node `i` under
/// `seed`. Unlike [`sample_components`] (one sequential stream per
/// node), every walk is independently seeded so any single walk can be
/// resampled in isolation — the invariant the streaming subsystem's
/// incremental maintenance is built on.
#[inline]
pub fn walk_rng(seed: u64, node: usize, walk: usize) -> Rng {
    Rng::new(seed).split(node as u64).split(walk as u64)
}

/// Rebuild the per-length component rows of one source node from its
/// walk records: deposits are replayed in walk order per length, then
/// deduped exactly like the samplers do (sort by target, merge runs,
/// scale by 1/n_walks). Both the full indexed sampler and the
/// incremental patcher call this, which is what makes an incremental
/// update bit-identical to a from-scratch rebuild.
pub fn rows_from_walks(
    nw: &NodeWalks,
    n_len: usize,
    inv_n: f64,
) -> Vec<(Vec<u32>, Vec<f64>)> {
    let mut per_len: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n_len];
    for t in 0..nw.n_walks() {
        for (l, &d) in nw.walk(t).iter().enumerate() {
            per_len[l].push(d);
        }
    }
    per_len
        .into_iter()
        .map(|mut dep| {
            dep.sort_unstable_by_key(|&(j, _)| j);
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            let mut k = 0;
            while k < dep.len() {
                let j = dep[k].0;
                let mut v = 0.0;
                while k < dep.len() && dep[k].0 == j {
                    v += dep[k].1;
                    k += 1;
                }
                cols.push(j);
                vals.push(v * inv_n);
            }
            (cols, vals)
        })
        .collect()
}

/// Simulate the GRF walks and build the per-length component matrices.
///
/// Deterministic given `seed` regardless of thread count: node `i`
/// always uses RNG stream `seed ⊕ i`.
pub fn sample_components(g: &Graph, cfg: &WalkConfig, seed: u64) -> WalkComponents {
    let n = g.num_nodes();
    let n_len = cfg.max_len + 1;
    let threads = cfg.effective_threads();
    let base = Rng::new(seed);
    // Weighted degrees for adjacency normalisation (1.0 disables).
    let norm_deg: Vec<f64> = if cfg.normalize {
        (0..n).map(|i| g.weighted_degree(i).max(1e-12)).collect()
    } else {
        Vec::new()
    };

    let chunks: Vec<ChunkOut> = par_map_chunks(n, threads, |s, e, _| {
        let mut per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)> =
            (0..n_len).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        // Scratch: deposits of one source node, per length.
        let mut deposits: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n_len];
        let mut rec: Vec<(u32, f64)> = Vec::with_capacity(n_len);
        for i in s..e {
            let mut rng = base.split(i as u64);
            for d in deposits.iter_mut() {
                d.clear();
            }
            for _ in 0..cfg.n_walks {
                rec.clear();
                walk_once_record(g, cfg, &norm_deg, i, &mut rng, &mut rec);
                for (l, &d) in rec.iter().enumerate() {
                    deposits[l].push(d);
                }
            }
            // Dedup per (row, length): sort by target, merge runs.
            let inv_n = 1.0 / cfg.n_walks as f64;
            for (l, dep) in deposits.iter_mut().enumerate() {
                dep.sort_unstable_by_key(|&(j, _)| j);
                let (rows, cols, vals) = &mut per_len[l];
                let mut count = 0u32;
                let mut k = 0;
                while k < dep.len() {
                    let j = dep[k].0;
                    let mut v = 0.0;
                    while k < dep.len() && dep[k].0 == j {
                        v += dep[k].1;
                        k += 1;
                    }
                    cols.push(j);
                    vals.push(v * inv_n);
                    count += 1;
                }
                rows.push(count);
            }
        }
        ChunkOut { start: s, per_len }
    });

    // Stitch chunk fragments into global CSRs. The per-length stitches
    // are independent memcpy-bound passes, so they run in parallel over
    // the l_max+1 lengths (this sits on the training path:
    // `refresh_features` re-derives Φ from these components every Adam
    // step). Chunks are in row order, so each stitch is a prefix-sum
    // over row lengths plus two concatenations.
    let stitch = |l: usize| -> Csr {
        let total_nnz: usize = chunks.iter().map(|ch| ch.per_len[l].1.len()).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut cols = Vec::with_capacity(total_nnz);
        let mut vals = Vec::with_capacity(total_nnz);
        for ch in &chunks {
            debug_assert_eq!(ch.start, offsets.len() - 1);
            let (rows, ccols, cvals) = &ch.per_len[l];
            for &rl in rows {
                offsets.push(offsets.last().unwrap() + rl as usize);
            }
            cols.extend_from_slice(ccols);
            vals.extend_from_slice(cvals);
        }
        Csr { n_rows: n, n_cols: n, offsets, cols, vals }
    };
    let c: Vec<Csr> = par_map_chunks(n_len, threads.min(n_len), |s, e, _| {
        (s..e).map(stitch).collect::<Vec<Csr>>()
    })
    .into_iter()
    .flatten()
    .collect();
    WalkComponents::new(c)
}

/// Per-chunk output of the indexed sampler.
struct IndexedChunkOut {
    start: usize,
    per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)>,
    store: Vec<NodeWalks>,
    /// (visited node, source node, walk idx), deduped per walk.
    visits: Vec<(u32, u32, u32)>,
}

/// Indexed variant of [`sample_components`] for dynamic graphs: every
/// walk `(i, t)` runs on its own RNG stream ([`walk_rng`]), and the
/// sampler additionally emits the per-walk deposit store and the visit
/// index. Deterministic given `seed` regardless of thread count.
///
/// The component estimates differ from [`sample_components`] only in
/// the RNG scheme (both are unbiased with the same variance); the
/// per-walk streams cost one extra seeding per walk, which buys walk
/// isolation: resampling any subset of walks and rebuilding the
/// affected rows via [`rows_from_walks`] is bit-identical to a full
/// resample in which only those walks changed.
pub fn sample_components_indexed(g: &Graph, cfg: &WalkConfig, seed: u64) -> IndexedWalks {
    sample_components_indexed_part(g, cfg, seed, None)
}

/// Partition-filtered [`sample_components_indexed`]: with
/// `owner = Some((shard, n_shards))` only sources `i` with
/// `i % n_shards == shard` are walked; every other source gets an
/// empty deposit store, empty feature rows, and no visit entries.
/// Because each walk `(i, t)` runs on its own RNG stream, the rows and
/// visit entries this emits for the owned sources are **bitwise** the
/// corresponding slices of the unfiltered sampler — the foundation of
/// the sharded engine's composition contract (see `crate::shard`).
pub fn sample_components_indexed_part(
    g: &Graph,
    cfg: &WalkConfig,
    seed: u64,
    owner: Option<(u32, u32)>,
) -> IndexedWalks {
    let n = g.num_nodes();
    let n_len = cfg.max_len + 1;
    let threads = cfg.effective_threads();
    let norm_deg: Vec<f64> = if cfg.normalize {
        (0..n).map(|i| g.weighted_degree(i).max(1e-12)).collect()
    } else {
        Vec::new()
    };
    let inv_n = 1.0 / cfg.n_walks as f64;
    let owns = |i: usize| match owner {
        Some((shard, count)) => i as u32 % count == shard,
        None => true,
    };

    let chunks: Vec<IndexedChunkOut> = par_map_chunks(n, threads, |s, e, _| {
        let mut per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)> =
            (0..n_len).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        let mut store = Vec::with_capacity(e - s);
        let mut visits = Vec::new();
        let mut seen: Vec<u32> = Vec::with_capacity(n_len);
        for i in s..e {
            let mut nw = NodeWalks::default();
            nw.offsets.push(0);
            if !owns(i) {
                // Foreign source: this shard holds no walks and an
                // all-empty row — the owner's shard carries them.
                for (rows, _, _) in per_len.iter_mut() {
                    rows.push(0);
                }
                store.push(nw);
                continue;
            }
            for t in 0..cfg.n_walks {
                let mut rng = walk_rng(seed, i, t);
                walk_once_record(g, cfg, &norm_deg, i, &mut rng, &mut nw.deposits);
                let start = *nw.offsets.last().unwrap() as usize;
                nw.offsets.push(nw.deposits.len() as u32);
                // Visit entries: distinct nodes on this trajectory.
                seen.clear();
                seen.extend(nw.deposits[start..].iter().map(|&(j, _)| j));
                seen.sort_unstable();
                seen.dedup();
                for &j in &seen {
                    visits.push((j, i as u32, t as u32));
                }
            }
            for (l, (cols, vals)) in
                rows_from_walks(&nw, n_len, inv_n).into_iter().enumerate()
            {
                let (rows, ccols, cvals) = &mut per_len[l];
                rows.push(cols.len() as u32);
                ccols.extend_from_slice(&cols);
                cvals.extend_from_slice(&vals);
            }
            store.push(nw);
        }
        IndexedChunkOut { start: s, per_len, store, visits }
    });

    // Stitch the per-length CSRs (same prefix-sum concat as the legacy
    // sampler) and scatter the visit triples chunk-by-chunk (chunks are
    // ordered, so the index layout is thread-count independent).
    let stitch = |l: usize| -> Csr {
        let total_nnz: usize = chunks.iter().map(|ch| ch.per_len[l].1.len()).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut cols = Vec::with_capacity(total_nnz);
        let mut vals = Vec::with_capacity(total_nnz);
        for ch in &chunks {
            debug_assert_eq!(ch.start, offsets.len() - 1);
            let (rows, ccols, cvals) = &ch.per_len[l];
            for &rl in rows {
                offsets.push(offsets.last().unwrap() + rl as usize);
            }
            cols.extend_from_slice(ccols);
            vals.extend_from_slice(cvals);
        }
        Csr { n_rows: n, n_cols: n, offsets, cols, vals }
    };
    let c: Vec<Csr> = par_map_chunks(n_len, threads.min(n_len), |s, e, _| {
        (s..e).map(stitch).collect::<Vec<Csr>>()
    })
    .into_iter()
    .flatten()
    .collect();

    let mut store = Vec::with_capacity(n);
    let mut visit: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
    for ch in chunks {
        store.extend(ch.store);
        for (j, src, t) in ch.visits {
            visit[j as usize].push((src, t));
        }
    }
    IndexedWalks { components: WalkComponents::new(c), store, visit }
}

/// One walk from `source`: append one `(node, load)` record per visited
/// step to `rec` (index within the appended run = subwalk length `l`).
/// The deposit/termination/step order matches Alg. 2 exactly, so both
/// samplers (and the streaming resampler) share this single walker.
#[inline]
fn walk_once_record(
    g: &Graph,
    cfg: &WalkConfig,
    norm_deg: &[f64],
    source: usize,
    rng: &mut Rng,
    rec: &mut Vec<(u32, f64)>,
) {
    let mut current = source;
    let mut load = 1.0f64;
    for l in 0..=cfg.max_len {
        rec.push((current as u32, load));
        if l == cfg.max_len {
            break;
        }
        let (nb, wts) = g.row(current);
        let deg = nb.len();
        if deg == 0 {
            break; // isolated node: walk cannot continue
        }
        // Termination draw (after the deposit, as in Alg. 2).
        if rng.bernoulli(cfg.p_halt) {
            break;
        }
        let k = rng.below(deg);
        let next = nb[k] as usize;
        let mut w = wts[k];
        if cfg.normalize {
            // Effective matrix entry: Wn_uv = w / sqrt(d_u d_v).
            w /= (norm_deg[current] * norm_deg[next]).sqrt();
        }
        load *= if cfg.reweight {
            // Importance weight: 1 / P(step) = deg / (1 - p_halt),
            // times the traversed (normalised) edge weight.
            deg as f64 * w / (1.0 - cfg.p_halt)
        } else {
            // Ad-hoc ablation: raw edge-weight product (Eq. 13).
            w
        };
        current = next;
    }
}

/// Re-run a single walk `(source, walk)` on the (possibly mutated)
/// graph under its deterministic stream, appending its deposit records
/// to `rec`. `norm_deg` must hold the **current** weighted degrees when
/// `cfg.normalize` (empty otherwise) — exactly what the full sampler
/// would see. This is the streaming subsystem's incremental kernel.
pub fn resample_walk(
    g: &Graph,
    cfg: &WalkConfig,
    norm_deg: &[f64],
    source: usize,
    walk: usize,
    seed: u64,
    rec: &mut Vec<(u32, f64)>,
) {
    let mut rng = walk_rng(seed, source, walk);
    walk_once_record(g, cfg, norm_deg, source, &mut rng, rec);
}

/// Convenience: sample components and immediately combine them with a
/// modulation vector, returning the feature matrix Φ(f).
pub fn sample_features(g: &Graph, cfg: &WalkConfig, f: &[f64], seed: u64) -> Csr {
    let comps = sample_components(g, cfg, seed);
    comps.combine(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::Mat;
    use crate::prop_assert;
    use crate::util::proptest::proptest;

    /// Dense W^l for the unbiasedness oracle.
    fn adjacency_powers(g: &Graph, max_len: usize) -> Vec<Mat> {
        let w = Mat::from_rows(&g.dense_adjacency());
        let n = g.num_nodes();
        let mut out = vec![Mat::eye(n)];
        for l in 1..=max_len {
            out.push(out[l - 1].matmul(&w));
        }
        out
    }

    #[test]
    fn components_unbiased_for_adjacency_powers() {
        // E[C_l] = W^l: Monte Carlo mean over many walks on a small
        // weighted graph must match the exact matrix power.
        let mut edges = vec![];
        let mut rng = Rng::new(3);
        for i in 0u32..8 {
            for j in (i + 1)..8 {
                if rng.bernoulli(0.5) {
                    edges.push((i, j, 0.3 + 0.4 * rng.uniform()));
                }
            }
        }
        let g = Graph::from_edges(8, &edges);
        let cfg = WalkConfig {
            n_walks: 60_000,
            p_halt: 0.25,
            max_len: 3,
            reweight: true,
            normalize: false,
            threads: 2,
        };
        let comps = sample_components(&g, &cfg, 12345);
        let powers = adjacency_powers(&g, cfg.max_len);
        for l in 0..=cfg.max_len {
            let dense = comps.c[l].to_dense();
            for i in 0..8 {
                for j in 0..8 {
                    let got = dense[i][j];
                    let expect = powers[l][(i, j)];
                    assert!(
                        (got - expect).abs() < 0.15 * (1.0 + expect.abs()),
                        "l={l} ({i},{j}): {got} vs {expect}"
                    );
                }
            }
        }
    }

    #[test]
    fn c0_is_identity_exactly() {
        let g = generators::ring(20);
        let cfg = WalkConfig { n_walks: 7, max_len: 2, ..Default::default() };
        let comps = sample_components(&g, &cfg, 0);
        let d = comps.c[0].to_dense();
        for i in 0..20 {
            for j in 0..20 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((d[i][j] - expect).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let g = generators::grid2d(6, 6);
        let cfg1 = WalkConfig { n_walks: 20, threads: 1, ..Default::default() };
        let cfg4 = WalkConfig { n_walks: 20, threads: 4, ..Default::default() };
        let a = sample_components(&g, &cfg1, 99);
        let b = sample_components(&g, &cfg4, 99);
        for l in 0..a.c.len() {
            assert_eq!(a.c[l], b.c[l], "length {l} differs across threads");
        }
    }

    #[test]
    fn sparsity_independent_of_graph_size() {
        // Theorem 1: nonzeros per feature bounded independent of N.
        let cfg = WalkConfig { n_walks: 16, max_len: 4, ..Default::default() };
        let mut nnz_per_row = Vec::new();
        for &n in &[64usize, 256, 1024] {
            let g = generators::ring(n);
            let comps = sample_components(&g, &cfg, 5);
            let phi = comps.combine(&[1.0, 0.5, 0.25, 0.12, 0.06]);
            nnz_per_row.push(phi.nnz() as f64 / n as f64);
        }
        let spread = nnz_per_row
            .iter()
            .cloned()
            .fold(f64::MIN, f64::max)
            - nnz_per_row.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            spread < 1.5,
            "nnz/row should be ~constant across N: {nnz_per_row:?}"
        );
    }

    #[test]
    fn adhoc_differs_from_reweighted() {
        let g = generators::grid2d(5, 5);
        let base = WalkConfig { n_walks: 200, max_len: 4, ..Default::default() };
        let adhoc = WalkConfig { reweight: false, ..base.clone() };
        let a = sample_components(&g, &base, 1);
        let b = sample_components(&g, &adhoc, 1);
        // Loads differ beyond length 0 (deg/(1-p) factor ~ 4/0.9 >> 1).
        let da = a.c[2].to_dense();
        let db = b.c[2].to_dense();
        let suma: f64 = da.iter().flatten().sum();
        let sumb: f64 = db.iter().flatten().sum();
        assert!(suma > 3.0 * sumb, "suma={suma} sumb={sumb}");
    }

    #[test]
    fn indexed_sampler_deterministic_and_visit_exact() {
        let g = generators::grid2d(6, 6);
        let cfg1 = WalkConfig { n_walks: 12, max_len: 3, threads: 1, ..Default::default() };
        let cfg4 = WalkConfig { threads: 4, ..cfg1.clone() };
        let a = sample_components_indexed(&g, &cfg1, 7);
        let b = sample_components_indexed(&g, &cfg4, 7);
        for l in 0..a.components.c.len() {
            assert_eq!(a.components.c[l], b.components.c[l], "length {l}");
        }
        assert_eq!(a.store, b.store);
        assert_eq!(a.visit, b.visit);
        // Visit index is exactly the inverted deposit map, deduped.
        let n = g.num_nodes();
        let mut expect: Vec<std::collections::BTreeSet<(u32, u32)>> =
            vec![Default::default(); n];
        for (i, nw) in a.store.iter().enumerate() {
            for t in 0..nw.n_walks() {
                for &(j, _) in nw.walk(t) {
                    expect[j as usize].insert((i as u32, t as u32));
                }
            }
        }
        for j in 0..n {
            let got: std::collections::BTreeSet<(u32, u32)> =
                a.visit[j].iter().copied().collect();
            assert_eq!(got.len(), a.visit[j].len(), "dup visit entries at {j}");
            assert_eq!(got, expect[j], "visit index mismatch at node {j}");
        }
        // Component rows are exactly rows_from_walks of the store.
        let inv_n = 1.0 / cfg1.n_walks as f64;
        for (i, nw) in a.store.iter().enumerate() {
            let rows = rows_from_walks(nw, cfg1.max_len + 1, inv_n);
            for (l, (cols, vals)) in rows.into_iter().enumerate() {
                let (rc, rv) = a.components.c[l].row(i);
                assert_eq!(rc, &cols[..], "node {i} length {l} cols");
                assert_eq!(rv, &vals[..], "node {i} length {l} vals");
            }
        }
    }

    #[test]
    fn indexed_sampler_unbiased_for_adjacency_powers() {
        // Same oracle as the legacy sampler, per-walk streams: E[C_l] = W^l.
        let mut edges = vec![];
        let mut rng = Rng::new(5);
        for i in 0u32..6 {
            for j in (i + 1)..6 {
                if rng.bernoulli(0.6) {
                    edges.push((i, j, 0.3 + 0.4 * rng.uniform()));
                }
            }
        }
        let g = Graph::from_edges(6, &edges);
        let cfg = WalkConfig {
            n_walks: 40_000,
            p_halt: 0.25,
            max_len: 2,
            reweight: true,
            normalize: false,
            threads: 2,
        };
        let iw = sample_components_indexed(&g, &cfg, 999);
        let powers = adjacency_powers(&g, cfg.max_len);
        for l in 0..=cfg.max_len {
            let dense = iw.components.c[l].to_dense();
            for i in 0..6 {
                for j in 0..6 {
                    let got = dense[i][j];
                    let expect = powers[l][(i, j)];
                    assert!(
                        (got - expect).abs() < 0.15 * (1.0 + expect.abs()),
                        "l={l} ({i},{j}): {got} vs {expect}"
                    );
                }
            }
        }
    }

    #[test]
    fn walk_respects_max_len_and_isolated_nodes() {
        proptest(8, |rng| {
            let n = 3 + rng.below(20);
            // Graph with an isolated node n-1.
            let mut edges = Vec::new();
            for i in 0..(n as u32 - 2) {
                edges.push((i, i + 1, 1.0));
            }
            let g = Graph::from_edges(n, &edges);
            let max_len = rng.below(4);
            let cfg = WalkConfig {
                n_walks: 10,
                max_len,
                p_halt: 0.01,
                ..Default::default()
            };
            let comps = sample_components(&g, &cfg, rng.next_u64());
            prop_assert!(comps.c.len() == max_len + 1, "len count");
            // Isolated node deposits only at l=0 on itself.
            let last = n - 1;
            for (l, cl) in comps.c.iter().enumerate() {
                let (cols, vals) = cl.row(last);
                if l == 0 {
                    prop_assert!(
                        cols == [last as u32] && (vals[0] - 1.0).abs() < 1e-12,
                        "isolated node l=0 row"
                    );
                } else {
                    prop_assert!(cols.is_empty(), "isolated node deposited at l={l}");
                }
            }
            Ok(())
        });
    }
}
