//! Random-walk simulation (paper Alg. 2), parallel over source nodes.

use super::components::WalkComponents;
use crate::graph::Graph;
use crate::sparse::Csr;
use crate::util::parallel::{num_threads, par_map_chunks};
use crate::util::rng::Rng;

/// Configuration of the GRF sampler.
#[derive(Clone, Debug)]
pub struct WalkConfig {
    /// Walks per node (paper `n`). Theorem 1: the number needed for an
    /// accurate estimate is independent of graph size N.
    pub n_walks: usize,
    /// Termination probability per step (paper `p`).
    pub p_halt: f64,
    /// Maximum walk length `l_max`; walks are truncated here and the
    /// modulation function is zero beyond it (App. C.1).
    pub max_len: usize,
    /// `false` switches to the *ad-hoc* ablation kernel (paper Eq. 13):
    /// loads are only products of edge weights, with no importance
    /// reweighting by `1/p(subwalk)`. Still a valid PSD kernel, but no
    /// longer unbiased for the target power series.
    pub reweight: bool,
    /// Walk the *symmetrically normalised* adjacency
    /// `Wn = D^{-1/2} W D^{-1/2}` instead of raw W (default true).
    /// Wn's spectrum lies in [-1, 1], so Theorem 1's constant
    /// `c = Σ|f_r| (max W d/(1-p))^r` stays small: the per-step load
    /// factor becomes `√(d_u/d_v)/(1-p)` instead of `d_u·w/(1-p)`,
    /// which diverges with degree on unweighted graphs. Kernels are
    /// then power series of Wn — e.g. diffusion on the normalised
    /// Laplacian, `exp(-βL̃) = e^{-β} exp(βWn)`.
    pub normalize: bool,
    /// How walk terminations are sampled (see [`Termination`]).
    /// Default [`Termination::Iid`] — bit-identical to the historical
    /// per-step Bernoulli walker.
    pub termination: Termination,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for WalkConfig {
    fn default() -> Self {
        WalkConfig {
            n_walks: 100,
            p_halt: 0.1,
            max_len: 10,
            reweight: true,
            normalize: true,
            termination: Termination::Iid,
            threads: 0,
        }
    }
}

impl WalkConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            num_threads()
        } else {
            self.threads
        }
    }
}

/// Stream tag for the antithetic pair budgets: walks `2t` and `2t+1`
/// of a node share the uniform drawn from
/// `Rng::new(seed).split(node).split(ANTITHETIC_STREAM).split(t)`.
/// Far outside the `split(walk)` range [`walk_rng`] uses, so the
/// budget streams never collide with a walk's step stream.
const ANTITHETIC_STREAM: u64 = 0x7E57_A171_0000_0001;

/// Stream tag for the per-node QMC rotation shift (one uniform per
/// node, applied to every walk's van der Corput point).
const QMC_SHIFT_STREAM: u64 = 0x7E57_51AC_0000_0002;

/// How walk terminations are sampled — the variance knob of Reid et
/// al., *Quasi-Monte Carlo Graph Random Features* (arXiv 2305.12470).
///
/// Every scheme draws each walk's halting time from the **same
/// geometric marginal** `P(length ≥ k) = (1-p_halt)^k`, so the
/// estimator stays unbiased (`E[C_l] = W^l`, tested); schemes differ
/// only in how the draws of *different walks from the same node* are
/// correlated, which is what shrinks the variance of the per-node
/// average. All three are pure functions of `(seed, node, walk)` —
/// walk isolation, thread-count determinism, and the sharded engine's
/// partition-independence hold under every scheme.
///
/// See the `walks` module docs, "Termination schemes", for the full
/// contract and guidance on which scheme to pick.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Termination {
    /// Independent per-step Bernoulli halting drawn from the walk's
    /// own RNG stream — bit-identical to the historical walker (the
    /// pre-scheme output is pinned by a regression test).
    #[default]
    Iid,
    /// Antithetic pairs: walks `2t` and `2t+1` of a node draw their
    /// termination budgets from one shared uniform `u` and its mirror
    /// `1-u` (comonotone coupling). When one walk of a pair halts
    /// early the other runs long, cancelling halting-time noise in
    /// the node's average.
    Antithetic,
    /// Randomised quasi-Monte-Carlo: walk `t` of a node maps the
    /// base-2 van der Corput point `vdc(t)` through a per-node random
    /// rotation (Cranley-Patterson), so the walk budgets of each node
    /// stratify the geometric quantiles near-perfectly.
    Qmc,
}

impl Termination {
    /// Every scheme, in stable order (test matrices iterate this).
    pub const ALL: [Termination; 3] =
        [Termination::Iid, Termination::Antithetic, Termination::Qmc];

    /// Canonical lowercase name (the `--termination` wire spelling).
    pub fn as_str(self) -> &'static str {
        match self {
            Termination::Iid => "iid",
            Termination::Antithetic => "antithetic",
            Termination::Qmc => "qmc",
        }
    }

    /// Parse the canonical spelling; `None` for anything else.
    pub fn parse(s: &str) -> Option<Termination> {
        match s {
            "iid" => Some(Termination::Iid),
            "antithetic" => Some(Termination::Antithetic),
            "qmc" => Some(Termination::Qmc),
            _ => None,
        }
    }

    /// Schemes a test matrix should cover: `GRFGP_TEST_TERMINATION`
    /// (comma-separated scheme names, e.g. `iid,qmc`) or every scheme
    /// when unset — the stream/shard property suites run their bitwise
    /// contracts once per entry, mirroring `GRFGP_TEST_SHARDS`.
    pub fn test_matrix() -> Vec<Termination> {
        match std::env::var("GRFGP_TEST_TERMINATION") {
            Ok(spec) => spec
                .split(',')
                .map(|t| t.trim())
                .filter(|t| !t.is_empty())
                .map(|t| {
                    Termination::parse(t).unwrap_or_else(|| {
                        panic!("GRFGP_TEST_TERMINATION: bad entry {t:?}")
                    })
                })
                .collect(),
            Err(_) => Termination::ALL.to_vec(),
        }
    }

    /// Build the termination cursor of walk `(node, walk)` under
    /// `seed`. For `Iid` this touches no RNG (the walk's own stream
    /// supplies the per-step draws, exactly as before the scheme
    /// existed); the correlated schemes derive the walk's length
    /// budget here, from dedicated streams that never overlap the
    /// step streams.
    fn draws(self, p_halt: f64, seed: u64, node: usize, walk: usize) -> TermDraws {
        match self {
            Termination::Iid => TermDraws::Iid,
            Termination::Antithetic => {
                let mut pair = Rng::new(seed)
                    .split(node as u64)
                    .split(ANTITHETIC_STREAM)
                    .split((walk / 2) as u64);
                let mut u = pair.uniform();
                if walk % 2 == 1 {
                    u = 1.0 - u;
                }
                TermDraws::Budget(geometric_budget(u, p_halt))
            }
            Termination::Qmc => {
                let mut shift_rng =
                    Rng::new(seed).split(node as u64).split(QMC_SHIFT_STREAM);
                let mut u = vdc53(walk as u64) + shift_rng.uniform();
                if u >= 1.0 {
                    u -= 1.0;
                }
                TermDraws::Budget(geometric_budget(u, p_halt))
            }
        }
    }
}

/// Per-walk termination cursor, consumed by the walker's halting test.
#[derive(Clone, Copy, Debug)]
enum TermDraws {
    /// Draw `bernoulli(p_halt)` from the walk's step stream each step.
    Iid,
    /// Halt once the subwalk length reaches this pre-drawn budget.
    Budget(usize),
}

impl TermDraws {
    /// Halting test after the deposit at subwalk length `l` (Alg. 2
    /// order: deposit, halt?, step).
    #[inline]
    fn halts(self, l: usize, p_halt: f64, rng: &mut Rng) -> bool {
        match self {
            TermDraws::Iid => rng.bernoulli(p_halt),
            TermDraws::Budget(b) => l >= b,
        }
    }
}

/// Geometric length budget by inverse CDF: the largest `L` with
/// `u ≥ 1 - (1-p)^L`, so `P(budget ≥ k) = (1-p)^k` for uniform `u` —
/// the same marginal the per-step Bernoulli walker realises. Monotone
/// in `u`, which is what makes the antithetic `u ↦ 1-u` coupling
/// comonotone in walk length.
fn geometric_budget(u: f64, p: f64) -> usize {
    if p <= 0.0 {
        return usize::MAX; // no geometric halting; max_len truncates
    }
    if u <= 0.0 {
        return 0;
    }
    let b = (1.0 - u).ln() / (1.0 - p).ln();
    if b.is_finite() && b < usize::MAX as f64 {
        b as usize
    } else {
        usize::MAX // u → 1 (or p ≥ 1 degeneracies): defer to max_len
    }
}

/// Base-2 van der Corput radical inverse of `t` with 53-bit
/// resolution: bit-reverse, then scale to [0, 1) exactly like
/// [`Rng::uniform`]. The first `2^k` points stratify [0, 1) into
/// `2^k` equal strata — one walk budget per geometric quantile.
fn vdc53(t: u64) -> f64 {
    (t.reverse_bits() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Per-chunk CSR fragment: rows [start, end) of each C_l.
struct ChunkOut {
    start: usize,
    /// For each l: (row_lengths, cols, vals).
    per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)>,
}

/// All deposit records of one source node's walks, flattened:
/// walk `t` deposited `deposits[offsets[t]..offsets[t+1]]`, one entry
/// per visited step in step order (so index `l` within the slice is
/// the deposit into `C_l`). This is the replayable raw material of the
/// streaming subsystem: a single walk can be swapped out and the
/// node's component rows rebuilt bit-identically.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeWalks {
    pub offsets: Vec<u32>,
    pub deposits: Vec<(u32, f64)>,
}

impl NodeWalks {
    pub fn n_walks(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    #[inline]
    pub fn walk(&self, t: usize) -> &[(u32, f64)] {
        &self.deposits[self.offsets[t] as usize..self.offsets[t + 1] as usize]
    }
}

/// Output of [`sample_components_indexed`]: the component matrices plus
/// the per-walk deposit store and the **visit index**
/// `visit[j] = [(source, walk), ...]` listing every walk whose
/// trajectory stepped through node `j`. An edge delta touching (u, v)
/// invalidates exactly `visit[u] ∪ visit[v]` (walk transitions are
/// node-local: a walk that never visited either endpoint replays
/// bit-identically under its per-walk RNG stream).
pub struct IndexedWalks {
    pub components: WalkComponents,
    pub store: Vec<NodeWalks>,
    pub visit: Vec<Vec<(u32, u32)>>,
}

/// The deterministic per-walk RNG stream: walk `t` from node `i` under
/// `seed`. Unlike [`sample_components`] (one sequential stream per
/// node), every walk is independently seeded so any single walk can be
/// resampled in isolation — the invariant the streaming subsystem's
/// incremental maintenance is built on.
#[inline]
pub fn walk_rng(seed: u64, node: usize, walk: usize) -> Rng {
    Rng::new(seed).split(node as u64).split(walk as u64)
}

/// Rebuild the per-length component rows of one source node from its
/// walk records: deposits are replayed in walk order per length, then
/// deduped exactly like the samplers do (sort by target, merge runs,
/// scale by 1/n_walks). Both the full indexed sampler and the
/// incremental patcher call this, which is what makes an incremental
/// update bit-identical to a from-scratch rebuild.
pub fn rows_from_walks(
    nw: &NodeWalks,
    n_len: usize,
    inv_n: f64,
) -> Vec<(Vec<u32>, Vec<f64>)> {
    let mut per_len: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n_len];
    for t in 0..nw.n_walks() {
        for (l, &d) in nw.walk(t).iter().enumerate() {
            per_len[l].push(d);
        }
    }
    per_len
        .into_iter()
        .map(|mut dep| {
            dep.sort_unstable_by_key(|&(j, _)| j);
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            let mut k = 0;
            while k < dep.len() {
                let j = dep[k].0;
                let mut v = 0.0;
                while k < dep.len() && dep[k].0 == j {
                    v += dep[k].1;
                    k += 1;
                }
                cols.push(j);
                vals.push(v * inv_n);
            }
            (cols, vals)
        })
        .collect()
}

/// Simulate the GRF walks and build the per-length component matrices.
///
/// Deterministic given `seed` regardless of thread count: node `i`
/// always uses RNG stream `seed ⊕ i`.
pub fn sample_components(g: &Graph, cfg: &WalkConfig, seed: u64) -> WalkComponents {
    let n = g.num_nodes();
    let n_len = cfg.max_len + 1;
    let threads = cfg.effective_threads();
    let base = Rng::new(seed);
    // Weighted degrees for adjacency normalisation (1.0 disables).
    let norm_deg: Vec<f64> = if cfg.normalize {
        (0..n).map(|i| g.weighted_degree(i).max(1e-12)).collect()
    } else {
        Vec::new()
    };

    let chunks: Vec<ChunkOut> = par_map_chunks(n, threads, |s, e, _| {
        let mut per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)> =
            (0..n_len).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        // Scratch: deposits of one source node, per length.
        let mut deposits: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n_len];
        let mut rec: Vec<(u32, f64)> = Vec::with_capacity(n_len);
        for i in s..e {
            let mut rng = base.split(i as u64);
            for d in deposits.iter_mut() {
                d.clear();
            }
            for t in 0..cfg.n_walks {
                rec.clear();
                let term = cfg.termination.draws(cfg.p_halt, seed, i, t);
                walk_once_record(g, cfg, &norm_deg, i, &mut rng, term, &mut rec);
                for (l, &d) in rec.iter().enumerate() {
                    deposits[l].push(d);
                }
            }
            // Dedup per (row, length): sort by target, merge runs.
            let inv_n = 1.0 / cfg.n_walks as f64;
            for (l, dep) in deposits.iter_mut().enumerate() {
                dep.sort_unstable_by_key(|&(j, _)| j);
                let (rows, cols, vals) = &mut per_len[l];
                let mut count = 0u32;
                let mut k = 0;
                while k < dep.len() {
                    let j = dep[k].0;
                    let mut v = 0.0;
                    while k < dep.len() && dep[k].0 == j {
                        v += dep[k].1;
                        k += 1;
                    }
                    cols.push(j);
                    vals.push(v * inv_n);
                    count += 1;
                }
                rows.push(count);
            }
        }
        ChunkOut { start: s, per_len }
    });

    // Stitch chunk fragments into global CSRs. The per-length stitches
    // are independent memcpy-bound passes, so they run in parallel over
    // the l_max+1 lengths (this sits on the training path:
    // `refresh_features` re-derives Φ from these components every Adam
    // step). Chunks are in row order, so each stitch is a prefix-sum
    // over row lengths plus two concatenations.
    let stitch = |l: usize| -> Csr {
        let total_nnz: usize = chunks.iter().map(|ch| ch.per_len[l].1.len()).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut cols = Vec::with_capacity(total_nnz);
        let mut vals = Vec::with_capacity(total_nnz);
        for ch in &chunks {
            debug_assert_eq!(ch.start, offsets.len() - 1);
            let (rows, ccols, cvals) = &ch.per_len[l];
            for &rl in rows {
                offsets.push(offsets.last().unwrap() + rl as usize);
            }
            cols.extend_from_slice(ccols);
            vals.extend_from_slice(cvals);
        }
        Csr { n_rows: n, n_cols: n, offsets, cols, vals }
    };
    let c: Vec<Csr> = par_map_chunks(n_len, threads.min(n_len), |s, e, _| {
        (s..e).map(stitch).collect::<Vec<Csr>>()
    })
    .into_iter()
    .flatten()
    .collect();
    WalkComponents::new(c)
}

/// Per-chunk output of the indexed sampler.
struct IndexedChunkOut {
    start: usize,
    per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)>,
    store: Vec<NodeWalks>,
    /// (visited node, source node, walk idx), deduped per walk.
    visits: Vec<(u32, u32, u32)>,
}

/// Indexed variant of [`sample_components`] for dynamic graphs: every
/// walk `(i, t)` runs on its own RNG stream ([`walk_rng`]), and the
/// sampler additionally emits the per-walk deposit store and the visit
/// index. Deterministic given `seed` regardless of thread count.
///
/// The component estimates differ from [`sample_components`] only in
/// the RNG scheme (both are unbiased with the same variance); the
/// per-walk streams cost one extra seeding per walk, which buys walk
/// isolation: resampling any subset of walks and rebuilding the
/// affected rows via [`rows_from_walks`] is bit-identical to a full
/// resample in which only those walks changed.
pub fn sample_components_indexed(g: &Graph, cfg: &WalkConfig, seed: u64) -> IndexedWalks {
    sample_components_indexed_part(g, cfg, seed, None)
}

/// Partition-filtered [`sample_components_indexed`]: with
/// `owner = Some((shard, n_shards))` only sources `i` with
/// `i % n_shards == shard` are walked; every other source gets an
/// empty deposit store, empty feature rows, and no visit entries.
/// Because each walk `(i, t)` runs on its own RNG stream, the rows and
/// visit entries this emits for the owned sources are **bitwise** the
/// corresponding slices of the unfiltered sampler — the foundation of
/// the sharded engine's composition contract (see `crate::shard`).
pub fn sample_components_indexed_part(
    g: &Graph,
    cfg: &WalkConfig,
    seed: u64,
    owner: Option<(u32, u32)>,
) -> IndexedWalks {
    let n = g.num_nodes();
    let n_len = cfg.max_len + 1;
    let threads = cfg.effective_threads();
    let norm_deg: Vec<f64> = if cfg.normalize {
        (0..n).map(|i| g.weighted_degree(i).max(1e-12)).collect()
    } else {
        Vec::new()
    };
    let inv_n = 1.0 / cfg.n_walks as f64;
    let owns = |i: usize| match owner {
        Some((shard, count)) => i as u32 % count == shard,
        None => true,
    };

    let chunks: Vec<IndexedChunkOut> = par_map_chunks(n, threads, |s, e, _| {
        let mut per_len: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)> =
            (0..n_len).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        let mut store = Vec::with_capacity(e - s);
        let mut visits = Vec::new();
        let mut seen: Vec<u32> = Vec::with_capacity(n_len);
        for i in s..e {
            let mut nw = NodeWalks::default();
            nw.offsets.push(0);
            if !owns(i) {
                // Foreign source: this shard holds no walks and an
                // all-empty row — the owner's shard carries them.
                for (rows, _, _) in per_len.iter_mut() {
                    rows.push(0);
                }
                store.push(nw);
                continue;
            }
            for t in 0..cfg.n_walks {
                let mut rng = walk_rng(seed, i, t);
                let term = cfg.termination.draws(cfg.p_halt, seed, i, t);
                walk_once_record(g, cfg, &norm_deg, i, &mut rng, term, &mut nw.deposits);
                let start = *nw.offsets.last().unwrap() as usize;
                nw.offsets.push(nw.deposits.len() as u32);
                // Visit entries: distinct nodes on this trajectory.
                seen.clear();
                seen.extend(nw.deposits[start..].iter().map(|&(j, _)| j));
                seen.sort_unstable();
                seen.dedup();
                for &j in &seen {
                    visits.push((j, i as u32, t as u32));
                }
            }
            for (l, (cols, vals)) in
                rows_from_walks(&nw, n_len, inv_n).into_iter().enumerate()
            {
                let (rows, ccols, cvals) = &mut per_len[l];
                rows.push(cols.len() as u32);
                ccols.extend_from_slice(&cols);
                cvals.extend_from_slice(&vals);
            }
            store.push(nw);
        }
        IndexedChunkOut { start: s, per_len, store, visits }
    });

    // Stitch the per-length CSRs (same prefix-sum concat as the legacy
    // sampler) and scatter the visit triples chunk-by-chunk (chunks are
    // ordered, so the index layout is thread-count independent).
    let stitch = |l: usize| -> Csr {
        let total_nnz: usize = chunks.iter().map(|ch| ch.per_len[l].1.len()).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut cols = Vec::with_capacity(total_nnz);
        let mut vals = Vec::with_capacity(total_nnz);
        for ch in &chunks {
            debug_assert_eq!(ch.start, offsets.len() - 1);
            let (rows, ccols, cvals) = &ch.per_len[l];
            for &rl in rows {
                offsets.push(offsets.last().unwrap() + rl as usize);
            }
            cols.extend_from_slice(ccols);
            vals.extend_from_slice(cvals);
        }
        Csr { n_rows: n, n_cols: n, offsets, cols, vals }
    };
    let c: Vec<Csr> = par_map_chunks(n_len, threads.min(n_len), |s, e, _| {
        (s..e).map(stitch).collect::<Vec<Csr>>()
    })
    .into_iter()
    .flatten()
    .collect();

    let mut store = Vec::with_capacity(n);
    let mut visit: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
    for ch in chunks {
        store.extend(ch.store);
        for (j, src, t) in ch.visits {
            visit[j as usize].push((src, t));
        }
    }
    IndexedWalks { components: WalkComponents::new(c), store, visit }
}

/// One walk from `source`: append one `(node, load)` record per visited
/// step to `rec` (index within the appended run = subwalk length `l`).
/// The deposit/termination/step order matches Alg. 2 exactly, so both
/// samplers (and the streaming resampler) share this single walker.
/// `term` is the walk's termination cursor ([`Termination::draws`]);
/// under [`TermDraws::Iid`] the halting draws come from `rng` itself,
/// bit-identical to the pre-scheme walker.
#[inline]
fn walk_once_record(
    g: &Graph,
    cfg: &WalkConfig,
    norm_deg: &[f64],
    source: usize,
    rng: &mut Rng,
    term: TermDraws,
    rec: &mut Vec<(u32, f64)>,
) {
    let mut current = source;
    let mut load = 1.0f64;
    for l in 0..=cfg.max_len {
        rec.push((current as u32, load));
        if l == cfg.max_len {
            break;
        }
        let (nb, wts) = g.row(current);
        let deg = nb.len();
        if deg == 0 {
            break; // isolated node: walk cannot continue
        }
        // Termination draw (after the deposit, as in Alg. 2).
        if term.halts(l, cfg.p_halt, rng) {
            break;
        }
        let k = rng.below(deg);
        let next = nb[k] as usize;
        let mut w = wts[k];
        if cfg.normalize {
            // Effective matrix entry: Wn_uv = w / sqrt(d_u d_v).
            w /= (norm_deg[current] * norm_deg[next]).sqrt();
        }
        load *= if cfg.reweight {
            // Importance weight: 1 / P(step) = deg / (1 - p_halt),
            // times the traversed (normalised) edge weight.
            deg as f64 * w / (1.0 - cfg.p_halt)
        } else {
            // Ad-hoc ablation: raw edge-weight product (Eq. 13).
            w
        };
        current = next;
    }
}

/// Re-run a single walk `(source, walk)` on the (possibly mutated)
/// graph under its deterministic stream, appending its deposit records
/// to `rec`. `norm_deg` must hold the **current** weighted degrees when
/// `cfg.normalize` (empty otherwise) — exactly what the full sampler
/// would see. This is the streaming subsystem's incremental kernel.
pub fn resample_walk(
    g: &Graph,
    cfg: &WalkConfig,
    norm_deg: &[f64],
    source: usize,
    walk: usize,
    seed: u64,
    rec: &mut Vec<(u32, f64)>,
) {
    let mut rng = walk_rng(seed, source, walk);
    let term = cfg.termination.draws(cfg.p_halt, seed, source, walk);
    walk_once_record(g, cfg, norm_deg, source, &mut rng, term, rec);
}

/// Convenience: sample components and immediately combine them with a
/// modulation vector, returning the feature matrix Φ(f).
pub fn sample_features(g: &Graph, cfg: &WalkConfig, f: &[f64], seed: u64) -> Csr {
    let comps = sample_components(g, cfg, seed);
    comps.combine(f)
}

/// Unified front door to the walk engine: one `(graph, config, seed)`
/// binding with a typed request per output shape, in place of the
/// older three-function family (`sample_components` /
/// `sample_components_indexed` / `sample_components_indexed_part`,
/// which remain as thin wrappers). Everything configurable — walk
/// count, halting, normalisation, and the [`Termination`] scheme —
/// rides on the [`WalkConfig`], so a new sampling strategy is a config
/// change at every call site at once, not a fourth entry point.
///
/// ```
/// use grfgp::graph::generators;
/// use grfgp::walks::{Termination, WalkConfig, WalkSampler};
///
/// let g = generators::ring(32);
/// let cfg = WalkConfig {
///     n_walks: 8,
///     termination: Termination::Antithetic,
///     ..Default::default()
/// };
/// let sampler = WalkSampler::new(&g, &cfg, 7);
/// let comps = sampler.components();          // features only
/// let indexed = sampler.indexed();           // + deposit store/index
/// let mine = sampler.partition(0, 2);        // + ownership filter
/// assert_eq!(comps.c.len(), cfg.max_len + 1);
/// assert_eq!(indexed.store.len(), 32);
/// assert_eq!(mine.store[1].n_walks(), 0);    // node 1 owned by shard 1
/// ```
#[derive(Clone, Copy, Debug)]
pub struct WalkSampler<'a> {
    graph: &'a Graph,
    cfg: &'a WalkConfig,
    seed: u64,
}

impl<'a> WalkSampler<'a> {
    /// Bind the sampler inputs. Cheap (no walking happens until an
    /// output is requested).
    pub fn new(graph: &'a Graph, cfg: &'a WalkConfig, seed: u64) -> Self {
        WalkSampler { graph, cfg, seed }
    }

    /// Component matrices only (one sequential RNG stream per node —
    /// the cheapest request; cannot be incrementally patched).
    pub fn components(&self) -> WalkComponents {
        sample_components(self.graph, self.cfg, self.seed)
    }

    /// Components combined with modulation coefficients: Φ(f).
    pub fn features(&self, f: &[f64]) -> Csr {
        self.components().combine(f)
    }

    /// Components plus the per-walk deposit store and visit index
    /// (per-walk RNG streams — the streaming subsystem's request).
    pub fn indexed(&self) -> IndexedWalks {
        sample_components_indexed(self.graph, self.cfg, self.seed)
    }

    /// [`WalkSampler::indexed`] restricted to the sources owned by
    /// `shard` of `of` (`i % of == shard`); foreign rows come back
    /// empty. Owned rows are **bitwise** the corresponding rows of the
    /// unfiltered request, under every termination scheme.
    pub fn partition(&self, shard: u32, of: u32) -> IndexedWalks {
        sample_components_indexed_part(self.graph, self.cfg, self.seed, Some((shard, of)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::Mat;
    use crate::prop_assert;
    use crate::util::proptest::proptest;

    /// Dense W^l for the unbiasedness oracle.
    fn adjacency_powers(g: &Graph, max_len: usize) -> Vec<Mat> {
        let w = Mat::from_rows(&g.dense_adjacency());
        let n = g.num_nodes();
        let mut out = vec![Mat::eye(n)];
        for l in 1..=max_len {
            out.push(out[l - 1].matmul(&w));
        }
        out
    }

    #[test]
    fn components_unbiased_for_adjacency_powers() {
        // E[C_l] = W^l: Monte Carlo mean over many walks on a small
        // weighted graph must match the exact matrix power.
        let mut edges = vec![];
        let mut rng = Rng::new(3);
        for i in 0u32..8 {
            for j in (i + 1)..8 {
                if rng.bernoulli(0.5) {
                    edges.push((i, j, 0.3 + 0.4 * rng.uniform()));
                }
            }
        }
        let g = Graph::from_edges(8, &edges);
        let cfg = WalkConfig {
            n_walks: 60_000,
            p_halt: 0.25,
            max_len: 3,
            reweight: true,
            normalize: false,
            termination: Termination::Iid,
            threads: 2,
        };
        let comps = sample_components(&g, &cfg, 12345);
        let powers = adjacency_powers(&g, cfg.max_len);
        for l in 0..=cfg.max_len {
            let dense = comps.c[l].to_dense();
            for i in 0..8 {
                for j in 0..8 {
                    let got = dense[i][j];
                    let expect = powers[l][(i, j)];
                    assert!(
                        (got - expect).abs() < 0.15 * (1.0 + expect.abs()),
                        "l={l} ({i},{j}): {got} vs {expect}"
                    );
                }
            }
        }
    }

    #[test]
    fn c0_is_identity_exactly() {
        let g = generators::ring(20);
        let cfg = WalkConfig { n_walks: 7, max_len: 2, ..Default::default() };
        let comps = sample_components(&g, &cfg, 0);
        let d = comps.c[0].to_dense();
        for i in 0..20 {
            for j in 0..20 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((d[i][j] - expect).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let g = generators::grid2d(6, 6);
        let cfg1 = WalkConfig { n_walks: 20, threads: 1, ..Default::default() };
        let cfg4 = WalkConfig { n_walks: 20, threads: 4, ..Default::default() };
        let a = sample_components(&g, &cfg1, 99);
        let b = sample_components(&g, &cfg4, 99);
        for l in 0..a.c.len() {
            assert_eq!(a.c[l], b.c[l], "length {l} differs across threads");
        }
    }

    #[test]
    fn sparsity_independent_of_graph_size() {
        // Theorem 1: nonzeros per feature bounded independent of N.
        let cfg = WalkConfig { n_walks: 16, max_len: 4, ..Default::default() };
        let mut nnz_per_row = Vec::new();
        for &n in &[64usize, 256, 1024] {
            let g = generators::ring(n);
            let comps = sample_components(&g, &cfg, 5);
            let phi = comps.combine(&[1.0, 0.5, 0.25, 0.12, 0.06]);
            nnz_per_row.push(phi.nnz() as f64 / n as f64);
        }
        let spread = nnz_per_row
            .iter()
            .cloned()
            .fold(f64::MIN, f64::max)
            - nnz_per_row.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            spread < 1.5,
            "nnz/row should be ~constant across N: {nnz_per_row:?}"
        );
    }

    #[test]
    fn adhoc_differs_from_reweighted() {
        let g = generators::grid2d(5, 5);
        let base = WalkConfig { n_walks: 200, max_len: 4, ..Default::default() };
        let adhoc = WalkConfig { reweight: false, ..base.clone() };
        let a = sample_components(&g, &base, 1);
        let b = sample_components(&g, &adhoc, 1);
        // Loads differ beyond length 0 (deg/(1-p) factor ~ 4/0.9 >> 1).
        let da = a.c[2].to_dense();
        let db = b.c[2].to_dense();
        let suma: f64 = da.iter().flatten().sum();
        let sumb: f64 = db.iter().flatten().sum();
        assert!(suma > 3.0 * sumb, "suma={suma} sumb={sumb}");
    }

    #[test]
    fn indexed_sampler_deterministic_and_visit_exact() {
        let g = generators::grid2d(6, 6);
        let cfg1 = WalkConfig { n_walks: 12, max_len: 3, threads: 1, ..Default::default() };
        let cfg4 = WalkConfig { threads: 4, ..cfg1.clone() };
        let a = sample_components_indexed(&g, &cfg1, 7);
        let b = sample_components_indexed(&g, &cfg4, 7);
        for l in 0..a.components.c.len() {
            assert_eq!(a.components.c[l], b.components.c[l], "length {l}");
        }
        assert_eq!(a.store, b.store);
        assert_eq!(a.visit, b.visit);
        // Visit index is exactly the inverted deposit map, deduped.
        let n = g.num_nodes();
        let mut expect: Vec<std::collections::BTreeSet<(u32, u32)>> =
            vec![Default::default(); n];
        for (i, nw) in a.store.iter().enumerate() {
            for t in 0..nw.n_walks() {
                for &(j, _) in nw.walk(t) {
                    expect[j as usize].insert((i as u32, t as u32));
                }
            }
        }
        for j in 0..n {
            let got: std::collections::BTreeSet<(u32, u32)> =
                a.visit[j].iter().copied().collect();
            assert_eq!(got.len(), a.visit[j].len(), "dup visit entries at {j}");
            assert_eq!(got, expect[j], "visit index mismatch at node {j}");
        }
        // Component rows are exactly rows_from_walks of the store.
        let inv_n = 1.0 / cfg1.n_walks as f64;
        for (i, nw) in a.store.iter().enumerate() {
            let rows = rows_from_walks(nw, cfg1.max_len + 1, inv_n);
            for (l, (cols, vals)) in rows.into_iter().enumerate() {
                let (rc, rv) = a.components.c[l].row(i);
                assert_eq!(rc, &cols[..], "node {i} length {l} cols");
                assert_eq!(rv, &vals[..], "node {i} length {l} vals");
            }
        }
    }

    #[test]
    fn indexed_sampler_unbiased_for_adjacency_powers() {
        // Same oracle as the legacy sampler, per-walk streams: E[C_l] = W^l.
        let mut edges = vec![];
        let mut rng = Rng::new(5);
        for i in 0u32..6 {
            for j in (i + 1)..6 {
                if rng.bernoulli(0.6) {
                    edges.push((i, j, 0.3 + 0.4 * rng.uniform()));
                }
            }
        }
        let g = Graph::from_edges(6, &edges);
        let cfg = WalkConfig {
            n_walks: 40_000,
            p_halt: 0.25,
            max_len: 2,
            reweight: true,
            normalize: false,
            termination: Termination::Iid,
            threads: 2,
        };
        let iw = sample_components_indexed(&g, &cfg, 999);
        let powers = adjacency_powers(&g, cfg.max_len);
        for l in 0..=cfg.max_len {
            let dense = iw.components.c[l].to_dense();
            for i in 0..6 {
                for j in 0..6 {
                    let got = dense[i][j];
                    let expect = powers[l][(i, j)];
                    assert!(
                        (got - expect).abs() < 0.15 * (1.0 + expect.abs()),
                        "l={l} ({i},{j}): {got} vs {expect}"
                    );
                }
            }
        }
    }

    /// Frozen copy of the walker as it was before the [`Termination`]
    /// layer existed: per-step Bernoulli halting drawn from the walk's
    /// own stream. The regression tests below pin `Termination::Iid`
    /// to this exact draw sequence.
    fn pre_scheme_walk(
        g: &Graph,
        cfg: &WalkConfig,
        norm_deg: &[f64],
        source: usize,
        rng: &mut Rng,
        rec: &mut Vec<(u32, f64)>,
    ) {
        let mut current = source;
        let mut load = 1.0f64;
        for l in 0..=cfg.max_len {
            rec.push((current as u32, load));
            if l == cfg.max_len {
                break;
            }
            let (nb, wts) = g.row(current);
            let deg = nb.len();
            if deg == 0 {
                break;
            }
            if rng.bernoulli(cfg.p_halt) {
                break;
            }
            let k = rng.below(deg);
            let next = nb[k] as usize;
            let mut w = wts[k];
            if cfg.normalize {
                w /= (norm_deg[current] * norm_deg[next]).sqrt();
            }
            load *= if cfg.reweight {
                deg as f64 * w / (1.0 - cfg.p_halt)
            } else {
                w
            };
            current = next;
        }
    }

    /// Small weighted graph exercising degree spread + normalisation.
    fn scheme_test_graph() -> Graph {
        let mut edges = vec![];
        let mut rng = Rng::new(17);
        for i in 0u32..10 {
            for j in (i + 1)..10 {
                if rng.bernoulli(0.4) {
                    edges.push((i, j, 0.2 + 0.6 * rng.uniform()));
                }
            }
        }
        Graph::from_edges(10, &edges)
    }

    #[test]
    fn iid_bit_identical_to_pre_scheme_sampler() {
        let g = scheme_test_graph();
        let cfg = WalkConfig { n_walks: 9, p_halt: 0.3, max_len: 4, ..Default::default() };
        assert_eq!(cfg.termination, Termination::Iid);
        let seed = 2024u64;
        let n = g.num_nodes();
        let norm_deg: Vec<f64> =
            (0..n).map(|i| g.weighted_degree(i).max(1e-12)).collect();

        // Legacy sampler (one sequential stream per node): replay the
        // pre-scheme draws and rebuild rows through the shared dedup.
        let comps = sample_components(&g, &cfg, seed);
        let base = Rng::new(seed);
        let inv_n = 1.0 / cfg.n_walks as f64;
        for i in 0..n {
            let mut rng = base.split(i as u64);
            let mut nw = NodeWalks::default();
            nw.offsets.push(0);
            for _ in 0..cfg.n_walks {
                pre_scheme_walk(&g, &cfg, &norm_deg, i, &mut rng, &mut nw.deposits);
                nw.offsets.push(nw.deposits.len() as u32);
            }
            for (l, (cols, vals)) in
                rows_from_walks(&nw, cfg.max_len + 1, inv_n).into_iter().enumerate()
            {
                let (rc, rv) = comps.c[l].row(i);
                assert_eq!(rc, &cols[..], "legacy node {i} length {l} cols");
                assert_eq!(rv, &vals[..], "legacy node {i} length {l} vals");
            }
        }

        // Indexed sampler (per-walk streams): every stored trajectory
        // is bitwise the pre-scheme walk under its stream.
        let iw = sample_components_indexed(&g, &cfg, seed);
        let mut rec = Vec::new();
        for i in 0..n {
            for t in 0..cfg.n_walks {
                rec.clear();
                let mut rng = walk_rng(seed, i, t);
                pre_scheme_walk(&g, &cfg, &norm_deg, i, &mut rng, &mut rec);
                assert_eq!(iw.store[i].walk(t), &rec[..], "walk ({i},{t})");
            }
        }
    }

    #[test]
    fn geometric_budget_inverts_the_survival_cdf() {
        let p = 0.3;
        // budget(u) >= k  ⟺  u >= 1 - (1-p)^k (strict floor semantics,
        // checked just inside both sides of every quantile boundary).
        for k in 1usize..=8 {
            let q = 1.0 - (1.0f64 - p).powi(k as i32);
            assert!(geometric_budget(q + 1e-12, p) >= k, "just above q_{k}");
            assert!(geometric_budget(q - 1e-12, p) < k, "just below q_{k}");
        }
        // Monotone in u.
        let mut prev = 0;
        for j in 0..100 {
            let b = geometric_budget(j as f64 / 100.0, p);
            assert!(b >= prev);
            prev = b;
        }
        // Edge cases: no halting mass, u at the endpoints, p >= 1.
        assert_eq!(geometric_budget(0.5, 0.0), usize::MAX);
        assert_eq!(geometric_budget(0.5, -1.0), usize::MAX);
        assert_eq!(geometric_budget(0.0, p), 0);
        assert_eq!(geometric_budget(-1.0, p), 0);
        assert_eq!(geometric_budget(1.0, p), usize::MAX); // max_len truncates
        assert_eq!(geometric_budget(0.5, 1.0), 0);
    }

    #[test]
    fn correlated_budgets_keep_the_geometric_marginal() {
        // Both correlated schemes must realise the same survival curve
        // P(budget >= k) = (1-p)^k as the iid walker — that is what
        // keeps E[C_l] = W^l scheme-independent.
        let p = 0.3;
        let (nodes, walks) = (2000usize, 20usize);
        for scheme in [Termination::Antithetic, Termination::Qmc] {
            let mut survive = [0usize; 4];
            for i in 0..nodes {
                for t in 0..walks {
                    let b = match scheme.draws(p, 99, i, t) {
                        TermDraws::Budget(b) => b,
                        TermDraws::Iid => unreachable!("correlated scheme"),
                    };
                    for (k, s) in survive.iter_mut().enumerate() {
                        if b >= k + 1 {
                            *s += 1;
                        }
                    }
                }
            }
            let total = (nodes * walks) as f64;
            for (k, &s) in survive.iter().enumerate() {
                let got = s as f64 / total;
                let expect = (1.0f64 - p).powi(k as i32 + 1);
                assert!(
                    (got - expect).abs() < 0.015,
                    "{scheme:?} P(budget>={}) = {got} vs {expect}",
                    k + 1
                );
            }
        }
    }

    #[test]
    fn antithetic_pairs_mirror_one_uniform() {
        // The pairing rule: walks 2t and 2t+1 of a node derive their
        // budgets from one uniform u and its mirror 1-u, drawn from
        // the pair stream (seed, node, ANTITHETIC_STREAM, t).
        let p = 0.25;
        for (seed, node, t) in [(1u64, 3usize, 0usize), (9, 0, 5), (42, 7, 11)] {
            let mut pair = Rng::new(seed)
                .split(node as u64)
                .split(ANTITHETIC_STREAM)
                .split(t as u64);
            let u = pair.uniform();
            let even = Termination::Antithetic.draws(p, seed, node, 2 * t);
            let odd = Termination::Antithetic.draws(p, seed, node, 2 * t + 1);
            match (even, odd) {
                (TermDraws::Budget(b0), TermDraws::Budget(b1)) => {
                    assert_eq!(b0, geometric_budget(u, p));
                    assert_eq!(b1, geometric_budget(1.0 - u, p));
                }
                _ => unreachable!("antithetic draws budgets"),
            }
        }
    }

    #[test]
    fn qmc_budgets_stratify_per_node() {
        // With n_walks = 2^k, the shifted van der Corput points land
        // one in each of the 2^k equal strata of [0,1) — so each node
        // gets exactly one budget per geometric quantile block.
        let (p, walks) = (0.3, 16usize);
        for node in 0..8usize {
            let mut shift_rng =
                Rng::new(5).split(node as u64).split(QMC_SHIFT_STREAM);
            let shift = shift_rng.uniform();
            let mut strata = vec![0usize; walks];
            for t in 0..walks {
                let mut u = vdc53(t as u64) + shift;
                if u >= 1.0 {
                    u -= 1.0;
                }
                strata[(u * walks as f64) as usize] += 1;
                // And the walker's budget is exactly this point's.
                match Termination::Qmc.draws(p, 5, node, t) {
                    TermDraws::Budget(b) => {
                        assert_eq!(b, geometric_budget(u, p))
                    }
                    TermDraws::Iid => unreachable!(),
                }
            }
            assert!(
                strata.iter().all(|&c| c == 1),
                "node {node}: strata {strata:?}"
            );
        }
    }

    #[test]
    fn schemes_deterministic_and_walk_isolated() {
        // Thread-count determinism and resample-in-isolation hold for
        // every termination scheme, not just Iid — both are pure
        // consequences of budgets being functions of (seed, node, walk).
        let g = scheme_test_graph();
        let seed = 31u64;
        for scheme in Termination::ALL {
            let cfg1 = WalkConfig {
                n_walks: 11,
                max_len: 4,
                p_halt: 0.3,
                termination: scheme,
                threads: 1,
                ..Default::default()
            };
            let cfg4 = WalkConfig { threads: 4, ..cfg1.clone() };
            let a = sample_components_indexed(&g, &cfg1, seed);
            let b = sample_components_indexed(&g, &cfg4, seed);
            for l in 0..a.components.c.len() {
                assert_eq!(a.components.c[l], b.components.c[l], "{scheme:?} l={l}");
            }
            assert_eq!(a.store, b.store, "{scheme:?} store");
            assert_eq!(a.visit, b.visit, "{scheme:?} visit");
            let norm_deg: Vec<f64> = (0..g.num_nodes())
                .map(|i| g.weighted_degree(i).max(1e-12))
                .collect();
            let mut rec = Vec::new();
            for i in 0..g.num_nodes() {
                for t in 0..cfg1.n_walks {
                    rec.clear();
                    resample_walk(&g, &cfg1, &norm_deg, i, t, seed, &mut rec);
                    assert_eq!(a.store[i].walk(t), &rec[..], "{scheme:?} ({i},{t})");
                }
            }
            // Partition-independence: owned slices of a partitioned
            // request are bitwise the unfiltered sampler's, foreign
            // sources come back empty — under every scheme.
            for shard in 0..3u32 {
                let p = sample_components_indexed_part(&g, &cfg1, seed, Some((shard, 3)));
                for i in 0..g.num_nodes() {
                    if i as u32 % 3 == shard {
                        assert_eq!(p.store[i], a.store[i], "{scheme:?} shard {shard} node {i}");
                    } else {
                        assert_eq!(p.store[i].n_walks(), 0, "{scheme:?} foreign node {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn correlated_schemes_unbiased_for_adjacency_powers() {
        // E[C_l] = W^l must survive the correlated terminations: the
        // budget marginal is the iid geometric, and budgets are
        // independent of the step draws.
        let mut edges = vec![];
        let mut rng = Rng::new(5);
        for i in 0u32..6 {
            for j in (i + 1)..6 {
                if rng.bernoulli(0.6) {
                    edges.push((i, j, 0.3 + 0.4 * rng.uniform()));
                }
            }
        }
        let g = Graph::from_edges(6, &edges);
        let powers = adjacency_powers(&g, 2);
        for scheme in [Termination::Antithetic, Termination::Qmc] {
            let cfg = WalkConfig {
                n_walks: 40_000,
                p_halt: 0.25,
                max_len: 2,
                reweight: true,
                normalize: false,
                termination: scheme,
                threads: 2,
            };
            let comps = sample_components(&g, &cfg, 999);
            for l in 0..=cfg.max_len {
                let dense = comps.c[l].to_dense();
                for i in 0..6 {
                    for j in 0..6 {
                        let got = dense[i][j];
                        let expect = powers[l][(i, j)];
                        assert!(
                            (got - expect).abs() < 0.15 * (1.0 + expect.abs()),
                            "{scheme:?} l={l} ({i},{j}): {got} vs {expect}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn termination_parse_round_trips() {
        for scheme in Termination::ALL {
            assert_eq!(Termination::parse(scheme.as_str()), Some(scheme));
        }
        assert_eq!(Termination::parse("halton"), None);
        assert_eq!(Termination::default(), Termination::Iid);
    }

    #[test]
    fn walk_sampler_matches_free_functions() {
        let g = scheme_test_graph();
        let cfg = WalkConfig {
            n_walks: 8,
            max_len: 3,
            termination: Termination::Qmc,
            ..Default::default()
        };
        let sampler = WalkSampler::new(&g, &cfg, 12);
        let a = sampler.components();
        let b = sample_components(&g, &cfg, 12);
        for l in 0..a.c.len() {
            assert_eq!(a.c[l], b.c[l]);
        }
        let f = [1.0, 0.5, 0.25, 0.12];
        assert_eq!(sampler.features(&f), a.combine(&f));
        let ia = sampler.indexed();
        let ib = sample_components_indexed(&g, &cfg, 12);
        assert_eq!(ia.store, ib.store);
        assert_eq!(ia.visit, ib.visit);
        let pa = sampler.partition(1, 3);
        let pb = sample_components_indexed_part(&g, &cfg, 12, Some((1, 3)));
        assert_eq!(pa.store, pb.store);
        for (i, nw) in pa.store.iter().enumerate() {
            let expect = if i % 3 == 1 { cfg.n_walks } else { 0 };
            assert_eq!(nw.n_walks(), expect, "partition ownership at {i}");
        }
    }

    #[test]
    fn walk_respects_max_len_and_isolated_nodes() {
        proptest(8, |rng| {
            let n = 3 + rng.below(20);
            // Graph with an isolated node n-1.
            let mut edges = Vec::new();
            for i in 0..(n as u32 - 2) {
                edges.push((i, i + 1, 1.0));
            }
            let g = Graph::from_edges(n, &edges);
            let max_len = rng.below(4);
            let cfg = WalkConfig {
                n_walks: 10,
                max_len,
                p_halt: 0.01,
                ..Default::default()
            };
            let comps = sample_components(&g, &cfg, rng.next_u64());
            prop_assert!(comps.c.len() == max_len + 1, "len count");
            // Isolated node deposits only at l=0 on itself.
            let last = n - 1;
            for (l, cl) in comps.c.iter().enumerate() {
                let (cols, vals) = cl.row(last);
                if l == 0 {
                    prop_assert!(
                        cols == [last as u32] && (vals[0] - 1.0).abs() < 1e-12,
                        "isolated node l=0 row"
                    );
                } else {
                    prop_assert!(cols.is_empty(), "isolated node deposited at l={l}");
                }
            }
            Ok(())
        });
    }
}
