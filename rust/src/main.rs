//! grfgp — CLI for the GRF-GP reproduction.
//!
//! Subcommands:
//!   exp <id>        run an experiment driver (scaling | ablation |
//!                   traffic | wind | bo-synthetic | bo-social |
//!                   bo-wind | classify | all)
//!   serve           start the GP inference server on a graph
//!   info            print environment / artifact status
//!
//! Every experiment accepts `--seeds`, workload-specific size knobs,
//! and writes JSON into `results/` (see DESIGN.md §4 for the mapping
//! to paper tables/figures).

use anyhow::{bail, Result};
use grfgp::exp;
use grfgp::gp::{Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::server::wire::WireConfig;
use grfgp::server::ServerConfig;
use grfgp::stream::StreamingFeatures;
use grfgp::util::cli::Args;
use grfgp::util::json::UnicodeMode;
use grfgp::util::rng::Rng;
use grfgp::walks::{Termination, WalkConfig};
use std::time::Duration;

const USAGE: &str = "\
grfgp — Graph Random Features for Scalable Gaussian Processes

USAGE:
  grfgp exp <scaling|ablation|traffic|wind|bo-synthetic|bo-social|bo-wind|classify|all> [opts]
  grfgp serve [--graph ring --n 4096 --addr 127.0.0.1:7701]
              [--max-frame-bytes B --max-parse-depth D --unicode strict|replace]
              [--max-conns C --read-timeout-ms T --idle-timeout-s T --write-timeout-s T]
              [--max-batch K] [--slow-request-ms T]
              [--shards S] [--metrics-addr 127.0.0.1:9464]
              [--alert-p99-ms op=ms[,op=ms...]]
              [--termination iid|antithetic|qmc]
  grfgp info  [--artifacts artifacts]

Common experiment options:
  --seeds N          repetitions (default 3)
  --walks N          random walks per node
  --threads N        worker threads (default: all cores)
  full list per experiment: see rust/src/exp/*.rs
";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("exp") => run_exp(&args),
        Some("serve") => run_serve(&args),
        Some("info") => run_info(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn run_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match which {
        "scaling" => {
            exp::scaling::run(args);
        }
        "ablation" => {
            exp::ablation::run(args);
        }
        "traffic" => {
            exp::regression::run_traffic(args);
        }
        "wind" => {
            exp::regression::run_wind(args);
        }
        "bo-synthetic" => {
            exp::bo::run_synthetic(args);
        }
        "bo-social" => {
            exp::bo::run_social(args);
        }
        "bo-wind" => {
            exp::bo::run_wind(args);
        }
        "classify" => {
            exp::classify::run(args);
        }
        "all" => {
            exp::scaling::run(args);
            exp::ablation::run(args);
            exp::regression::run_traffic(args);
            exp::regression::run_wind(args);
            exp::bo::run_synthetic(args);
            exp::bo::run_social(args);
            exp::bo::run_wind(args);
            exp::classify::run(args);
        }
        other => bail!("unknown experiment {other:?}\n{USAGE}"),
    }
    Ok(())
}

fn run_serve(args: &Args) -> Result<()> {
    let n = args.usize("n", 4096);
    let addr = args.get_or("addr", "127.0.0.1:7701").to_string();
    let seed = args.u64("seed", 0);
    let graph = match args.get_or("graph", "ring") {
        "ring" => generators::ring(n),
        "grid" => {
            let side = (n as f64).sqrt() as usize;
            generators::grid2d(side, side)
        }
        "ba" => generators::barabasi_albert(n, 3, &mut Rng::new(seed)),
        other => bail!("unknown graph kind {other:?}"),
    };
    // Walk-termination scheme: `antithetic`/`qmc` cut estimator
    // variance at the same `--walks` budget (see walks module docs,
    // "Termination schemes"); `iid` is the classical sampler.
    let term_spec = args.get_or("termination", "iid");
    let termination = match Termination::parse(term_spec) {
        Some(t) => t,
        None => bail!("unknown --termination {term_spec:?} (iid|antithetic|qmc)"),
    };
    let cfg = WalkConfig {
        n_walks: args.usize("walks", 100),
        p_halt: args.f64("p-halt", 0.1),
        max_len: args.usize("max-len", 5),
        reweight: true,
        normalize: true,
        termination,
        threads: args.usize("threads", 0),
    };
    eprintln!(
        "sampling GRF components (indexed, per-walk streams): n={} walks={} l_max={} termination={}",
        graph.num_nodes(),
        cfg.n_walks,
        cfg.max_len,
        cfg.termination.as_str()
    );
    let hypers = Hypers::new(
        Modulation::diffusion(1.0, 1.0, cfg.max_len),
        args.f64("noise", 0.1),
    );
    // The streaming state backs the server's dynamic-graph ops
    // (add_edge / remove_edge / add_node patch features incrementally).
    let stream =
        StreamingFeatures::new(graph, cfg, hypers.modulation.coeffs(), seed);

    // Serving-edge limits (see server module docs, "Limits & failure
    // modes"). `fault_injection` is deliberately not exposed here: the
    // panic-injection op is for the test harness only.
    let defaults = ServerConfig::default();
    let unicode = match args.get_or("unicode", "strict") {
        "strict" => UnicodeMode::Strict,
        "replace" => UnicodeMode::Replace,
        other => bail!("unknown --unicode mode {other:?} (strict|replace)"),
    };
    let config = ServerConfig {
        wire: WireConfig {
            max_frame_bytes: args
                .usize("max-frame-bytes", defaults.wire.max_frame_bytes),
            max_parse_depth: args
                .usize("max-parse-depth", defaults.wire.max_parse_depth),
            unicode,
        },
        max_connections: args.usize("max-conns", defaults.max_connections),
        read_timeout: Duration::from_millis(args.u64(
            "read-timeout-ms",
            defaults.read_timeout.as_millis() as u64,
        )),
        idle_timeout: Duration::from_secs(
            args.u64("idle-timeout-s", defaults.idle_timeout.as_secs()),
        ),
        write_timeout: Duration::from_secs(
            args.u64("write-timeout-s", defaults.write_timeout.as_secs()),
        ),
        fault_injection: false,
        // Micro-batching width: how many compatible requests one
        // engine call may serve (predict unions / write batches).
        max_batch: args.usize("max-batch", defaults.max_batch),
        // Slow-request outlier log: one structured JSON line to stderr
        // per request slower than this (0 = off).
        slow_request_ms: args.u64("slow-request-ms", defaults.slow_request_ms),
        // Partitioned feature maintenance: S workers each own the rows
        // `i mod S == s` (1 = the mono engine; see server docs,
        // "Sharding topology"). Bitwise-identical results either way.
        shards: args.usize("shards", defaults.shards),
        // Prometheus exposition: plain-HTTP `GET /metrics` listener
        // (unset = wire `{"op":"metrics"}` only).
        metrics_addr: args.get("metrics-addr").map(|s| s.to_string()),
        // p99 latency limits per request op, checked at scrape time.
        alerts: match args.get("alert-p99-ms") {
            None => Vec::new(),
            Some(spec) => match grfgp::obs::alerts::parse_rules(spec) {
                Ok(rules) => rules,
                Err(e) => bail!("--alert-p99-ms: {e}"),
            },
        },
    };
    grfgp::server::ServeOptions::new()
        .addr(addr)
        .seed(seed)
        .config(config)
        .termination(termination)
        .serve(stream, hypers)
}

fn run_info(args: &Args) -> Result<()> {
    println!(
        "grfgp {} (three-layer Rust + JAX + Pallas GRF-GP)",
        env!("CARGO_PKG_VERSION")
    );
    println!("threads available: {}", grfgp::util::parallel::num_threads());
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    match grfgp::runtime::Runtime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts in {}:", dir.display());
            for a in &rt.manifest.artifacts {
                println!(
                    "  {:<44} kind={:<18} n={:<8} k={:<4} kt={:<4} iters={}",
                    a.name, a.kind, a.n, a.k, a.kt, a.iters
                );
            }
        }
        Err(e) => println!("no artifacts loaded ({e}); run `make artifacts`"),
    }
    Ok(())
}
