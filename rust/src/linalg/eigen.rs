//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Used for small-N oracles (expm validation, spectral checks) and for
//! the exact Matérn kernel baseline `(2ν/κ² + L̃)^{-ν}` which needs a
//! matrix power of a symmetric matrix.

use super::Mat;

/// Eigen-decomposition of symmetric `a`: returns (eigenvalues asc,
/// eigenvector matrix V with columns = eigenvectors, i.e. A = V Λ Vᵀ).
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.inf_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract and sort ascending.
    let mut pairs: Vec<(f64, usize)> =
        (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let lam: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vec_sorted = Mat::zeros(n, n);
    for (new_j, &(_, old_j)) in pairs.iter().enumerate() {
        for i in 0..n {
            vec_sorted[(i, new_j)] = v[(i, old_j)];
        }
    }
    (lam, vec_sorted)
}

/// Full symmetric eigendecomposition for larger matrices (N up to a few
/// thousand): Householder tridiagonalisation (tred2) followed by the
/// implicit-shift QL algorithm (tql2) — the classic EISPACK pair.
/// Returns (eigenvalues ascending, eigenvector columns).
pub fn sym_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    if n <= 24 {
        return jacobi_eigen(a, 100);
    }
    let mut z = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];

    // --- tred2: Householder reduction to tridiagonal -------------------
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let val = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= val;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let val = g * z[(k, i)];
                    z[(k, j)] -= val;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }

    // --- tql2: implicit-shift QL on the tridiagonal ---------------------
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small subdiagonal element.
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 50, "tql2 failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort ascending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| d[a].total_cmp(&d[b]));
    let lam: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut v = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..n {
            v[(i, newj)] = z[(i, oldj)];
        }
    }
    (lam, v)
}

/// Apply a scalar function to a symmetric matrix via its eigensystem:
/// f(A) = V f(Λ) Vᵀ.
pub fn matrix_function(a: &Mat, f: impl Fn(f64) -> f64) -> Mat {
    let n = a.rows;
    let (lam, v) = jacobi_eigen(a, 100);
    let mut out = Mat::zeros(n, n);
    for k in 0..n {
        let fl = f(lam[k]);
        if fl == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = v[(i, k)];
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += fl * vik * v[(j, k)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::proptest;

    #[test]
    fn diagonal_matrix_eigen() {
        let a = Mat::from_rows(&[vec![3.0, 0.0], vec![0.0, 1.0]]);
        let (lam, _) = jacobi_eigen(&a, 50);
        assert!((lam[0] - 1.0).abs() < 1e-12);
        assert!((lam[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_property() {
        proptest(16, |rng| {
            let n = 2 + rng.below(10);
            let mut b = Mat::zeros(n, n);
            for v in &mut b.data {
                *v = rng.normal();
            }
            let a = b.add(&b.transpose()).scale(0.5);
            let (lam, v) = jacobi_eigen(&a, 100);
            // A v_k = lam_k v_k
            for k in 0..n {
                let vk: Vec<f64> = (0..n).map(|i| v[(i, k)]).collect();
                let av = a.matvec(&vk);
                for i in 0..n {
                    prop_assert!(
                        (av[i] - lam[k] * vk[i]).abs() < 1e-7,
                        "eigpair {k} comp {i}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matrix_function_square() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let sq = matrix_function(&a, |x| x * x);
        let direct = a.matmul(&a);
        for i in 0..4 {
            assert!((sq.data[i] - direct.data[i]).abs() < 1e-9);
        }
    }
}
