//! Cholesky factorisation and triangular solves — the `O(N^3)` exact-GP
//! baseline (paper §1: "exact kernels generally incur O(N^3)").

use super::Mat;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factor `a = L L^T`. Fails if `a` is not (numerically) SPD.
    pub fn new(a: &Mat) -> Result<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        bail!("matrix not SPD at pivot {i} (sum={sum})");
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Solve A x = b via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // L^T x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    /// Solve for many right-hand sides.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows;
        assert_eq!(b.rows, n);
        let mut out = Mat::zeros(n, b.cols);
        for j in 0..b.cols {
            let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
            let x = self.solve(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// log det A = 2 Σ log L_ii — the LML's log-determinant term.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Sample z ~ N(0, A) as L u with u ~ N(0, I).
    pub fn sample(&self, u: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        (0..n)
            .map(|i| (0..=i).map(|k| self.l[(i, k)] * u[k]).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::proptest;

    fn random_spd(rng: &mut crate::util::rng::Rng, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n);
        for v in &mut b.data {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(0.5 + n as f64 * 0.01);
        a
    }

    #[test]
    fn factor_and_solve() {
        proptest(24, |rng| {
            let n = 1 + rng.below(25);
            let a = random_spd(rng, n);
            let ch = Cholesky::new(&a).map_err(|e| e.to_string())?;
            // L L^T == A
            let rec = ch.l.matmul(&ch.l.transpose());
            for i in 0..n {
                for j in 0..n {
                    prop_assert!(
                        (rec[(i, j)] - a[(i, j)]).abs() < 1e-8,
                        "LL^T mismatch at ({i},{j})"
                    );
                }
            }
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = ch.solve(&b);
            let ax = a.matvec(&x);
            for i in 0..n {
                prop_assert!((ax[i] - b[i]).abs() < 1e-7, "solve residual {i}");
            }
            Ok(())
        });
    }

    #[test]
    fn logdet_matches_eigen() {
        let mut rng = crate::util::rng::Rng::new(0);
        let a = random_spd(&mut rng, 8);
        let ch = Cholesky::new(&a).unwrap();
        let (lam, _) = crate::linalg::eigen::jacobi_eigen(&a, 200);
        let expect: f64 = lam.iter().map(|l| l.ln()).sum();
        assert!((ch.logdet() - expect).abs() < 1e-6);
    }

    #[test]
    fn rejects_non_spd() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn sample_covariance() {
        // Cov(Lu) = LL^T = A; check on 2x2 with many samples.
        let a = Mat::from_rows(&[vec![2.0, 0.6], vec![0.6, 1.0]]);
        let ch = Cholesky::new(&a).unwrap();
        let mut rng = crate::util::rng::Rng::new(42);
        let mut cov = [[0.0; 2]; 2];
        let n = 40_000;
        for _ in 0..n {
            let u = [rng.normal(), rng.normal()];
            let z = ch.sample(&u);
            for i in 0..2 {
                for j in 0..2 {
                    cov[i][j] += z[i] * z[j];
                }
            }
        }
        for i in 0..2 {
            for j in 0..2 {
                let emp = cov[i][j] / n as f64;
                assert!((emp - a[(i, j)]).abs() < 0.06, "cov[{i}][{j}]={emp}");
            }
        }
    }
}
