//! Conjugate-gradient solvers over abstract SPD operators.
//!
//! This is the paper's core inference engine (Lemma 1): CG on
//! `(K̂ + σ²I)` converges in `O(√κ) = O(√N)` iterations, each an
//! `O(N)` sparse matvec, giving the headline `O(N^{3/2})`.
//!
//! Two refinements over textbook CG, both aimed at the multi-RHS hot
//! path (Hutchinson probes during training, pathwise samples during
//! prediction):
//!
//! * **Block execution** — [`block_cg_solve`] runs `B` independent CG
//!   recurrences in lockstep over row-major `n × B` blocks, sharing one
//!   blocked operator application per iteration. SpMV is
//!   memory-bandwidth-bound, so fusing the right-hand sides amortises
//!   the matrix traffic ~`B`×; α/β and the convergence test stay
//!   per-column, so every column produces bitwise the same iterates as
//!   a standalone [`cg_solve`] run.
//! * **Diagonal (Jacobi) preconditioning** — [`pcg_solve`] and
//!   [`block_cg_solve`] accept an optional diagonal `M = diag(d)`;
//!   iterating on `M⁻¹A` cuts the `O(√κ)` iteration count on badly
//!   conditioned operators (small σ², sharply modulated diffusion
//!   kernels). See `GramOperator::jacobi_diag` for the `O(nnz(Φ))`
//!   masked-row-norm construction.

use super::{axpy, column_dots, dot};
use crate::obs;

/// CG run statistics.
#[derive(Clone, Copy, Debug)]
pub struct CgStats {
    pub iterations: usize,
    pub residual_norm: f64,
    pub converged: bool,
}

/// Solve A x = b for SPD operator `apply(x, y)` computing y = A x.
/// Stops at `tol * ||b||` relative residual or `max_iters`.
pub fn cg_solve<F>(
    apply: F,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, CgStats)
where
    F: FnMut(&[f64], &mut [f64]),
{
    pcg_solve(apply, b, x0, None, tol, max_iters)
}

/// Preconditioned CG: solve A x = b, optionally preconditioning with
/// `M = diag(precond_diag)` (entries must be positive for an SPD `M`).
/// With `precond_diag = None` this is exactly the classic recurrence —
/// no extra buffer, no extra pass.
pub fn pcg_solve<F>(
    mut apply: F,
    b: &[f64],
    x0: Option<&[f64]>,
    precond_diag: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, CgStats)
where
    F: FnMut(&[f64], &mut [f64]),
{
    let n = b.len();
    if let Some(d) = precond_diag {
        debug_assert_eq!(d.len(), n);
    }
    let mut x = match x0 {
        Some(v) => v.to_vec(),
        None => vec![0.0; n],
    };
    // r = b − A x₀; with no warm start A·0 = 0 exactly, so skip the
    // operator application (bitwise identical, one full pass cheaper —
    // the same shortcut block_cg_solve takes).
    let mut r: Vec<f64> = match x0 {
        Some(_) => {
            let mut ax = vec![0.0; n];
            apply(&x, &mut ax);
            b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect()
        }
        None => b.to_vec(),
    };
    // z = M⁻¹ r; with no preconditioner z aliases r conceptually and we
    // skip the buffer entirely.
    // (1/d)·r rather than r/d so the arithmetic — and therefore the
    // iterates — matches block_cg_solve's per-row reciprocal exactly.
    let mut z: Vec<f64> = match precond_diag {
        Some(d) => r.iter().zip(d).map(|(ri, di)| ri * (1.0 / di)).collect(),
        None => Vec::new(),
    };
    let mut p = if precond_diag.is_some() { z.clone() } else { r.clone() };
    // rz = r·z drives α/β; rr = r·r drives the (preconditioner-
    // independent) stopping test. They coincide when M = I.
    let mut rz = match precond_diag {
        Some(_) => dot(&r, &z),
        None => dot(&r, &r),
    };
    let mut rr = if precond_diag.is_some() { dot(&r, &r) } else { rz };
    let b_norm = dot(b, b).sqrt().max(1e-300);
    let mut ap = vec![0.0; n];
    let mut iterations = 0;
    for _ in 0..max_iters {
        if rr.sqrt() <= tol * b_norm {
            break;
        }
        apply(&p, &mut ap);
        let denom = dot(&p, &ap);
        if denom <= 0.0 {
            // Numerical loss of positive-definiteness; bail with the
            // current iterate.
            break;
        }
        let alpha = rz / denom;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let (rz_new, rr_new) = match precond_diag {
            Some(d) => {
                for i in 0..n {
                    z[i] = r[i] * (1.0 / d[i]);
                }
                (dot(&r, &z), dot(&r, &r))
            }
            None => {
                let rs = dot(&r, &r);
                (rs, rs)
            }
        };
        let beta = rz_new / rz;
        let zcur: &[f64] = if precond_diag.is_some() { &z } else { &r };
        for i in 0..n {
            p[i] = zcur[i] + beta * p[i];
        }
        rz = rz_new;
        rr = rr_new;
        iterations += 1;
        // Residual trajectory: one decades sample per iteration (how
        // many digits the solve has earned so far). Atomic fetch_add —
        // negligible next to the operator application it follows.
        if obs::enabled() {
            obs::registry::record_residual_decades(rr.sqrt() / b_norm);
        }
    }
    let residual_norm = rr.sqrt() / b_norm;
    let converged = residual_norm <= tol;
    obs::registry::CG_SOLVES.inc();
    obs::registry::CG_ITERS.record(iterations as u64);
    obs::registry::CG_LAST_RESIDUAL.set(residual_norm);
    if !converged {
        obs::registry::CG_NOCONVERGED.inc();
    }
    (
        x,
        CgStats {
            iterations,
            residual_norm,
            converged,
        },
    )
}

/// Block CG: solve A X = B for `ncols` right-hand sides packed in a
/// row-major `n × ncols` block, sharing one blocked operator
/// application `apply_block(X, Y)` (computing `Y = A X` column-wise)
/// per iteration.
///
/// `x0` optionally warm-starts the whole block (row-major `n × ncols`,
/// like `b`): the initial residual becomes `R = B − A·X0` at the cost
/// of one extra operator application. Thompson-sampling BO re-solves
/// nearly identical systems after each single-point data update, so
/// carrying the previous solves as `x0` cuts the iteration count (see
/// the warm-start test in `bo`). With `x0 = None` the zero-start
/// shortcut (`R = B`, no operator application) is taken, bitwise
/// identical to the pre-warm-start behavior.
///
/// Each column keeps its own α, β, residual, and convergence flag, so
/// the per-column iterates are **bitwise identical** to running
/// [`cg_solve`] / [`pcg_solve`] on that column alone with the matching
/// `x0` column (columns that converge early are frozen and no longer
/// updated; the operator is still applied to the full block, whose
/// traffic the live columns amortise). Returns the solution block and
/// per-column stats.
pub fn block_cg_solve<F>(
    mut apply_block: F,
    b: &[f64],
    ncols: usize,
    x0: Option<&[f64]>,
    precond_diag: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, Vec<CgStats>)
where
    F: FnMut(&[f64], &mut [f64]),
{
    assert!(ncols > 0, "ncols must be positive");
    debug_assert_eq!(b.len() % ncols, 0);
    let n = b.len() / ncols;
    if let Some(d) = precond_diag {
        debug_assert_eq!(d.len(), n);
    }
    let use_precond = precond_diag.is_some();

    let mut x = match x0 {
        Some(v) => {
            assert_eq!(v.len(), n * ncols, "x0 block shape must match b");
            v.to_vec()
        }
        None => vec![0.0; n * ncols],
    };
    // R = B − A·X0; without a warm start A·0 = 0 exactly, so skip the
    // operator application (bitwise identical, one full pass cheaper —
    // the same shortcut pcg_solve takes).
    let mut r: Vec<f64> = match x0 {
        Some(_) => {
            let mut ax = vec![0.0; n * ncols];
            apply_block(&x, &mut ax);
            b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect()
        }
        None => b.to_vec(),
    };
    let mut z: Vec<f64> = if use_precond {
        let d = precond_diag.unwrap();
        let mut z = vec![0.0; n * ncols];
        for i in 0..n {
            let base = i * ncols;
            let inv = 1.0 / d[i];
            for j in 0..ncols {
                z[base + j] = r[base + j] * inv;
            }
        }
        z
    } else {
        Vec::new()
    };
    let mut p = if use_precond { z.clone() } else { r.clone() };
    let mut ap = vec![0.0; n * ncols];

    let mut rz = if use_precond {
        column_dots(&r, &z, ncols)
    } else {
        column_dots(&r, &r, ncols)
    };
    let mut rr = if use_precond { column_dots(&r, &r, ncols) } else { rz.clone() };
    let b_norm: Vec<f64> = column_dots(b, b, ncols)
        .iter()
        .map(|v| v.sqrt().max(1e-300))
        .collect();
    let mut active: Vec<bool> =
        (0..ncols).map(|j| rr[j].sqrt() > tol * b_norm[j]).collect();
    let mut iterations = vec![0usize; ncols];
    let mut alpha = vec![0.0; ncols];
    let mut beta = vec![0.0; ncols];

    for _ in 0..max_iters {
        if !active.iter().any(|&a| a) {
            break;
        }
        apply_block(&p, &mut ap);
        let denom = column_dots(&p, &ap, ncols);
        for j in 0..ncols {
            alpha[j] = 0.0;
            if !active[j] {
                continue;
            }
            if denom[j] <= 0.0 {
                // Per-column loss of positive-definiteness: freeze this
                // column with its current iterate, like the single-RHS
                // bail-out.
                active[j] = false;
                continue;
            }
            alpha[j] = rz[j] / denom[j];
            iterations[j] += 1;
        }
        // Fused per-row update of the active columns:
        // x += α∘p, r −= α∘ap (streaming pass over the blocks).
        for i in 0..n {
            let base = i * ncols;
            for j in 0..ncols {
                let a = alpha[j];
                if a != 0.0 {
                    x[base + j] += a * p[base + j];
                    r[base + j] -= a * ap[base + j];
                }
            }
        }
        if let Some(d) = precond_diag {
            for i in 0..n {
                let base = i * ncols;
                let inv = 1.0 / d[i];
                for j in 0..ncols {
                    if alpha[j] != 0.0 {
                        z[base + j] = r[base + j] * inv;
                    }
                }
            }
        }
        let zcur: &[f64] = if use_precond { &z } else { &r };
        let rz_new = column_dots(&r, zcur, ncols);
        let rr_new = if use_precond { column_dots(&r, &r, ncols) } else { rz_new.clone() };
        for j in 0..ncols {
            beta[j] = 0.0;
            if alpha[j] != 0.0 {
                beta[j] = rz_new[j] / rz[j];
                rz[j] = rz_new[j];
                rr[j] = rr_new[j];
                if rr[j].sqrt() <= tol * b_norm[j] {
                    active[j] = false;
                }
            }
        }
        for i in 0..n {
            let base = i * ncols;
            for j in 0..ncols {
                if alpha[j] != 0.0 {
                    p[base + j] = zcur[base + j] + beta[j] * p[base + j];
                }
            }
        }
    }

    let stats: Vec<CgStats> = (0..ncols)
        .map(|j| {
            let residual_norm = rr[j].sqrt() / b_norm[j];
            CgStats {
                iterations: iterations[j],
                residual_norm,
                converged: residual_norm <= tol,
            }
        })
        .collect();
    obs::registry::CG_BLOCK_SOLVES.inc();
    if obs::enabled() {
        for st in &stats {
            obs::registry::CG_BLOCK_ITERS.record(st.iterations as u64);
            obs::registry::record_residual_decades(st.residual_norm);
            if !st.converged {
                obs::registry::CG_NOCONVERGED.inc();
            }
        }
        if let Some(worst) = stats
            .iter()
            .map(|st| st.residual_norm)
            .max_by(f64::total_cmp)
        {
            obs::registry::CG_LAST_RESIDUAL.set(worst);
        }
    }
    (x, stats)
}

/// Batched CG over separate right-hand-side vectors: packs `bs` into an
/// `n × B` block, runs [`block_cg_solve`] (one shared blocked operator
/// application per iteration — this is where the multi-RHS speedup
/// comes from), and unpacks the solutions.
///
/// `apply_block(x, y, ncols)` receives row-major `n × ncols` blocks
/// with `ncols == bs.len()`. The explicit-arity closure is deliberate:
/// the pre-block-CG version of this function took a per-vector
/// `apply(x, y)`, and keeping that two-argument shape would let stale
/// callers compile against the new block contract and silently compute
/// garbage.
pub fn cg_solve_batch<F>(
    mut apply_block: F,
    bs: &[Vec<f64>],
    precond_diag: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<Vec<f64>>, Vec<CgStats>)
where
    F: FnMut(&[f64], &mut [f64], usize),
{
    if bs.is_empty() {
        return (Vec::new(), Vec::new());
    }
    let ncols = bs.len();
    let n = bs[0].len();
    let mut block = vec![0.0; n * ncols];
    for (j, b) in bs.iter().enumerate() {
        debug_assert_eq!(b.len(), n);
        for i in 0..n {
            block[i * ncols + j] = b[i];
        }
    }
    let (xb, stats) = block_cg_solve(
        |x, y| apply_block(x, y, ncols),
        &block,
        ncols,
        None,
        precond_diag,
        tol,
        max_iters,
    );
    let xs = (0..ncols)
        .map(|j| (0..n).map(|i| xb[i * ncols + j]).collect())
        .collect();
    (xs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::Cholesky;
    use crate::linalg::Mat;
    use crate::prop_assert;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    /// Blocked apply for a dense matrix: per-column matvec with the
    /// same accumulation order as `Mat::matvec` (parity oracle).
    fn dense_apply_block(a: &Mat, x: &[f64], y: &mut [f64], ncols: usize) {
        let n = a.rows;
        let mut col = vec![0.0; n];
        for j in 0..ncols {
            for i in 0..n {
                col[i] = x[i * ncols + j];
            }
            let av = a.matvec(&col);
            for i in 0..n {
                y[i * ncols + j] = av[i];
            }
        }
    }

    #[test]
    fn solves_identity() {
        let b = vec![1.0, 2.0, 3.0];
        let (x, st) = cg_solve(
            |v, y| y.copy_from_slice(v),
            &b,
            None,
            1e-12,
            10,
        );
        assert_eq!(x, b);
        assert!(st.converged);
    }

    #[test]
    fn matches_cholesky_on_random_spd() {
        proptest(24, |rng| {
            let n = 2 + rng.below(30);
            let mut bmat = Mat::zeros(n, n);
            for v in &mut bmat.data {
                *v = rng.normal();
            }
            let mut a = bmat.matmul(&bmat.transpose());
            a.add_diag(1.0);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (x, st) = cg_solve(
                |v, y| {
                    let av = a.matvec(v);
                    y.copy_from_slice(&av);
                },
                &b,
                None,
                1e-10,
                10 * n,
            );
            prop_assert!(st.converged, "CG failed to converge: {st:?}");
            let xd = Cholesky::new(&a).map_err(|e| e.to_string())?.solve(&b);
            for i in 0..n {
                prop_assert!(
                    (x[i] - xd[i]).abs() < 1e-6,
                    "component {i}: {} vs {}",
                    x[i],
                    xd[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn iteration_count_scales_with_sqrt_condition() {
        // Diagonal operator with condition number kappa: CG needs
        // ~sqrt(kappa) iterations; verify the trend.
        let mut iters = Vec::new();
        for &kappa in &[4.0, 64.0, 1024.0] {
            let n = 2000;
            let diag: Vec<f64> = (0..n)
                .map(|i| 1.0 + (kappa - 1.0) * i as f64 / (n - 1) as f64)
                .collect();
            let b = vec![1.0; n];
            let (_, st) = cg_solve(
                |v, y| {
                    for i in 0..n {
                        y[i] = diag[i] * v[i];
                    }
                },
                &b,
                None,
                1e-8,
                n,
            );
            iters.push(st.iterations as f64);
        }
        assert!(iters[1] > 1.5 * iters[0], "{iters:?}");
        assert!(iters[2] > 1.5 * iters[1], "{iters:?}");
        // ~sqrt growth, not linear: 256x condition -> far less than
        // 256x iterations (sqrt predicts 16x; allow slack).
        assert!(iters[2] < 64.0 * iters[0], "{iters:?}");
    }

    #[test]
    fn jacobi_preconditioner_kills_diagonal_conditioning() {
        // For a diagonal operator the Jacobi preconditioner is exact:
        // PCG must converge in one iteration where plain CG needs many,
        // and both must agree on the solution.
        let n = 1500;
        let diag: Vec<f64> = (0..n)
            .map(|i| 1.0 + 999.0 * i as f64 / (n - 1) as f64)
            .collect();
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let apply = |v: &[f64], y: &mut [f64]| {
            for i in 0..n {
                y[i] = diag[i] * v[i];
            }
        };
        let (x_plain, st_plain) = cg_solve(apply, &b, None, 1e-10, n);
        let (x_pre, st_pre) = pcg_solve(apply, &b, None, Some(&diag), 1e-10, n);
        assert!(st_plain.converged && st_pre.converged);
        assert!(
            st_pre.iterations < st_plain.iterations / 4,
            "precond {} vs plain {}",
            st_pre.iterations,
            st_plain.iterations
        );
        for i in 0..n {
            assert!(
                (x_pre[i] - x_plain[i]).abs() < 1e-8,
                "solutions diverge at {i}: {} vs {}",
                x_pre[i],
                x_plain[i]
            );
        }
    }

    #[test]
    fn block_cg_matches_single_rhs_bitwise() {
        // Property: every column of a block solve reproduces the
        // standalone single-RHS solve — same iterates, same stats —
        // because alpha/beta/convergence are tracked per column.
        proptest(16, |rng| {
            let n = 2 + rng.below(24);
            let ncols = 1 + rng.below(6);
            let mut bmat = Mat::zeros(n, n);
            for v in &mut bmat.data {
                *v = rng.normal();
            }
            let mut a = bmat.matmul(&bmat.transpose());
            a.add_diag(0.5);
            let cols: Vec<Vec<f64>> = (0..ncols)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            let mut block = vec![0.0; n * ncols];
            for (j, c) in cols.iter().enumerate() {
                for i in 0..n {
                    block[i * ncols + j] = c[i];
                }
            }
            let (xb, stats) = block_cg_solve(
                |x, y| dense_apply_block(&a, x, y, ncols),
                &block,
                ncols,
                None,
                None,
                1e-10,
                20 * n,
            );
            for (j, c) in cols.iter().enumerate() {
                let (xs, st) = cg_solve(
                    |v, y| {
                        let av = a.matvec(v);
                        y.copy_from_slice(&av);
                    },
                    c,
                    None,
                    1e-10,
                    20 * n,
                );
                prop_assert!(
                    stats[j].iterations == st.iterations,
                    "col {j}: {} vs {} iterations",
                    stats[j].iterations,
                    st.iterations
                );
                for i in 0..n {
                    let bv = xb[i * ncols + j];
                    prop_assert!(
                        (bv - xs[i]).abs() < 1e-12 * (1.0 + xs[i].abs()),
                        "col {j} row {i}: block {bv} vs single {}",
                        xs[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_cg_preconditioned_agrees_and_saves_iterations() {
        // Ill-conditioned diagonal block system: Jacobi-preconditioned
        // block CG reaches the same solutions in (far) fewer iterations.
        let n = 800;
        let ncols = 5;
        let diag: Vec<f64> = (0..n)
            .map(|i| 1.0 + 4999.0 * i as f64 / (n - 1) as f64)
            .collect();
        let mut rng = Rng::new(7);
        let block: Vec<f64> = (0..n * ncols).map(|_| rng.normal()).collect();
        let apply = |x: &[f64], y: &mut [f64]| {
            for i in 0..n {
                for j in 0..ncols {
                    y[i * ncols + j] = diag[i] * x[i * ncols + j];
                }
            }
        };
        let (x_plain, st_plain) =
            block_cg_solve(apply, &block, ncols, None, None, 1e-10, n);
        let (x_pre, st_pre) =
            block_cg_solve(apply, &block, ncols, None, Some(&diag), 1e-10, n);
        for j in 0..ncols {
            assert!(st_plain[j].converged && st_pre[j].converged, "col {j}");
            assert!(
                st_pre[j].iterations < st_plain[j].iterations,
                "col {j}: precond {} !< plain {}",
                st_pre[j].iterations,
                st_plain[j].iterations
            );
        }
        for i in 0..n * ncols {
            assert!(
                (x_plain[i] - x_pre[i]).abs() < 1e-8,
                "entry {i}: {} vs {}",
                x_plain[i],
                x_pre[i]
            );
        }
    }

    #[test]
    fn block_cg_warm_start_matches_single_rhs_bitwise() {
        // The x0 block extends the lockstep guarantee: column j of a
        // warm-started block solve reproduces pcg_solve on that column
        // with the matching x0 column — same iterates, same stats.
        proptest(16, |rng| {
            let n = 2 + rng.below(24);
            let ncols = 1 + rng.below(5);
            let mut bmat = Mat::zeros(n, n);
            for v in &mut bmat.data {
                *v = rng.normal();
            }
            let mut a = bmat.matmul(&bmat.transpose());
            a.add_diag(0.5);
            let cols: Vec<Vec<f64>> = (0..ncols)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            let x0_cols: Vec<Vec<f64>> = (0..ncols)
                .map(|_| (0..n).map(|_| 0.3 * rng.normal()).collect())
                .collect();
            let mut block = vec![0.0; n * ncols];
            let mut x0_block = vec![0.0; n * ncols];
            for j in 0..ncols {
                for i in 0..n {
                    block[i * ncols + j] = cols[j][i];
                    x0_block[i * ncols + j] = x0_cols[j][i];
                }
            }
            let (xb, stats) = block_cg_solve(
                |x, y| dense_apply_block(&a, x, y, ncols),
                &block,
                ncols,
                Some(&x0_block),
                None,
                1e-10,
                20 * n,
            );
            for j in 0..ncols {
                let (xs, st) = pcg_solve(
                    |v, y: &mut [f64]| {
                        let av = a.matvec(v);
                        y.copy_from_slice(&av);
                    },
                    &cols[j],
                    Some(&x0_cols[j]),
                    None,
                    1e-10,
                    20 * n,
                );
                prop_assert!(
                    stats[j].iterations == st.iterations,
                    "col {j}: {} vs {} iterations",
                    stats[j].iterations,
                    st.iterations
                );
                for i in 0..n {
                    let bv = xb[i * ncols + j];
                    prop_assert!(
                        (bv - xs[i]).abs() < 1e-12 * (1.0 + xs[i].abs()),
                        "col {j} row {i}: block {bv} vs single {}",
                        xs[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_cg_warm_start_at_solution_takes_zero_iterations() {
        // x0 = exact solution => R = B − A·X0 = 0, every column starts
        // converged, and the returned block is x0 unchanged.
        let n = 40;
        let ncols = 3;
        let diag: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let mut rng = Rng::new(5);
        let x_true: Vec<f64> = (0..n * ncols).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; n * ncols];
        for i in 0..n {
            for j in 0..ncols {
                b[i * ncols + j] = diag[i] * x_true[i * ncols + j];
            }
        }
        let apply = |x: &[f64], y: &mut [f64]| {
            for i in 0..n {
                for j in 0..ncols {
                    y[i * ncols + j] = diag[i] * x[i * ncols + j];
                }
            }
        };
        let (x, stats) =
            block_cg_solve(apply, &b, ncols, Some(&x_true), None, 1e-10, 100);
        for st in &stats {
            assert_eq!(st.iterations, 0, "{st:?}");
            assert!(st.converged);
        }
        assert_eq!(x, x_true);
    }

    #[test]
    fn batch_matches_single() {
        let a = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let bs = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let (xs, stats) = cg_solve_batch(
            |x, y, ncols| dense_apply_block(&a, x, y, ncols),
            &bs,
            None,
            1e-12,
            50,
        );
        assert!(stats.iter().all(|s| s.converged));
        for (b, x) in bs.iter().zip(&xs) {
            let ax = a.matvec(x);
            for i in 0..2 {
                assert!((ax[i] - b[i]).abs() < 1e-9);
            }
        }
        // Empty batch is a no-op.
        let (xs0, st0) = cg_solve_batch(|_, _, _| {}, &[], None, 1e-12, 50);
        assert!(xs0.is_empty() && st0.is_empty());
    }
}
