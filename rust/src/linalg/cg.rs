//! Conjugate-gradient solver over abstract SPD operators.
//!
//! This is the paper's core inference engine (Lemma 1): CG on
//! `(K̂ + σ²I)` converges in `O(√κ) = O(√N)` iterations, each an
//! `O(N)` sparse matvec, giving the headline `O(N^{3/2})`.

use super::{axpy, dot};

/// CG run statistics.
#[derive(Clone, Copy, Debug)]
pub struct CgStats {
    pub iterations: usize,
    pub residual_norm: f64,
    pub converged: bool,
}

/// Solve A x = b for SPD operator `apply(x, y)` computing y = A x.
/// Stops at `tol * ||b||` relative residual or `max_iters`.
pub fn cg_solve<F>(
    mut apply: F,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, CgStats)
where
    F: FnMut(&[f64], &mut [f64]),
{
    let n = b.len();
    let mut x = match x0 {
        Some(v) => v.to_vec(),
        None => vec![0.0; n],
    };
    let mut ax = vec![0.0; n];
    apply(&x, &mut ax);
    let mut r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
    let mut p = r.clone();
    let mut rs = dot(&r, &r);
    let b_norm = dot(b, b).sqrt().max(1e-300);
    let mut ap = vec![0.0; n];
    let mut iterations = 0;
    for _ in 0..max_iters {
        if rs.sqrt() <= tol * b_norm {
            break;
        }
        apply(&p, &mut ap);
        let denom = dot(&p, &ap);
        if denom <= 0.0 {
            // Numerical loss of positive-definiteness; bail with the
            // current iterate.
            break;
        }
        let alpha = rs / denom;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        iterations += 1;
    }
    let residual_norm = rs.sqrt() / b_norm;
    (
        x,
        CgStats {
            iterations,
            residual_norm,
            converged: residual_norm <= tol,
        },
    )
}

/// Batched CG: solve A X = B for several right-hand sides, sharing the
/// operator. RHS are solved independently (no block-CG coupling) but
/// the caller may parallelise over them.
pub fn cg_solve_batch<F>(
    mut apply: F,
    bs: &[Vec<f64>],
    tol: f64,
    max_iters: usize,
) -> (Vec<Vec<f64>>, Vec<CgStats>)
where
    F: FnMut(&[f64], &mut [f64]),
{
    let mut xs = Vec::with_capacity(bs.len());
    let mut stats = Vec::with_capacity(bs.len());
    for b in bs {
        let (x, s) = cg_solve(&mut apply, b, None, tol, max_iters);
        xs.push(x);
        stats.push(s);
    }
    (xs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::Cholesky;
    use crate::linalg::Mat;
    use crate::prop_assert;
    use crate::util::proptest::proptest;

    #[test]
    fn solves_identity() {
        let b = vec![1.0, 2.0, 3.0];
        let (x, st) = cg_solve(
            |v, y| y.copy_from_slice(v),
            &b,
            None,
            1e-12,
            10,
        );
        assert_eq!(x, b);
        assert!(st.converged);
    }

    #[test]
    fn matches_cholesky_on_random_spd() {
        proptest(24, |rng| {
            let n = 2 + rng.below(30);
            let mut bmat = Mat::zeros(n, n);
            for v in &mut bmat.data {
                *v = rng.normal();
            }
            let mut a = bmat.matmul(&bmat.transpose());
            a.add_diag(1.0);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (x, st) = cg_solve(
                |v, y| {
                    let av = a.matvec(v);
                    y.copy_from_slice(&av);
                },
                &b,
                None,
                1e-10,
                10 * n,
            );
            prop_assert!(st.converged, "CG failed to converge: {st:?}");
            let xd = Cholesky::new(&a).map_err(|e| e.to_string())?.solve(&b);
            for i in 0..n {
                prop_assert!(
                    (x[i] - xd[i]).abs() < 1e-6,
                    "component {i}: {} vs {}",
                    x[i],
                    xd[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn iteration_count_scales_with_sqrt_condition() {
        // Diagonal operator with condition number kappa: CG needs
        // ~sqrt(kappa) iterations; verify the trend.
        let mut iters = Vec::new();
        for &kappa in &[4.0, 64.0, 1024.0] {
            let n = 2000;
            let diag: Vec<f64> = (0..n)
                .map(|i| 1.0 + (kappa - 1.0) * i as f64 / (n - 1) as f64)
                .collect();
            let b = vec![1.0; n];
            let (_, st) = cg_solve(
                |v, y| {
                    for i in 0..n {
                        y[i] = diag[i] * v[i];
                    }
                },
                &b,
                None,
                1e-8,
                n,
            );
            iters.push(st.iterations as f64);
        }
        assert!(iters[1] > 1.5 * iters[0], "{iters:?}");
        assert!(iters[2] > 1.5 * iters[1], "{iters:?}");
        // ~sqrt growth, not linear: 256x condition -> far less than
        // 256x iterations (sqrt predicts 16x; allow slack).
        assert!(iters[2] < 64.0 * iters[0], "{iters:?}");
    }

    #[test]
    fn batch_matches_single() {
        let a = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let bs = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let apply = |v: &[f64], y: &mut [f64]| {
            let av = a.matvec(v);
            y.copy_from_slice(&av);
        };
        let (xs, stats) = cg_solve_batch(apply, &bs, 1e-12, 50);
        assert!(stats.iter().all(|s| s.converged));
        for (b, x) in bs.iter().zip(&xs) {
            let ax = a.matvec(x);
            for i in 0..2 {
                assert!((ax[i] - b[i]).abs() < 1e-9);
            }
        }
    }
}
