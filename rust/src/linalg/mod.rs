//! Dense linear-algebra substrate (no LAPACK in the offline registry).
//!
//! Used by the exact GP baselines (dense diffusion/Matérn kernels, the
//! `O(N^3)` comparator in the scaling experiments) and by small-N test
//! oracles. Row-major flat storage.

pub mod cg;
pub mod chol;
pub mod eigen;
pub mod expm;

/// Dense row-major matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// C = A B — blocked ikj loop (cache-friendly; the dense baseline's
    /// hot operation).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            let ci = &mut c.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * n..(p + 1) * n];
                for (cj, bj) in ci.iter_mut().zip(b_row) {
                    *cj += a * bj;
                }
            }
        }
        c
    }

    /// Parallel matmul over row chunks (threads=0 → auto).
    pub fn matmul_par(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.rows);
        let threads = if threads == 0 {
            crate::util::parallel::num_threads()
        } else {
            threads
        };
        let (m, k, n) = (self.rows, self.cols, other.cols);
        if threads <= 1 || m < 64 {
            return self.matmul(other);
        }
        let rows = crate::util::parallel::par_map_chunks(m, threads, |s, e, _| {
            let mut block = vec![0.0; (e - s) * n];
            for i in s..e {
                let ci = &mut block[(i - s) * n..(i - s + 1) * n];
                for p in 0..k {
                    let a = self.data[i * k + p];
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[p * n..(p + 1) * n];
                    for (cj, bj) in ci.iter_mut().zip(b_row) {
                        *cj += a * bj;
                    }
                }
            }
            block
        });
        Mat { rows: m, cols: n, data: rows.concat() }
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for v in &mut out.data {
            *v *= s;
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn add_diag(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += s;
        }
    }

    pub fn inf_norm(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    pub fn memory_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Per-column dot products of two row-major `n × ncols` blocks:
/// `out[j] = Σ_i a[i*ncols + j] * b[i*ncols + j]`.
///
/// One streaming pass over both blocks computes all `ncols` dots —
/// the block-CG inner products cost one read of the iterate blocks
/// regardless of RHS count. Accumulation order per column matches
/// [`dot`] over the corresponding vectors, so results are bitwise
/// identical to the single-RHS path.
pub fn column_dots(a: &[f64], b: &[f64], ncols: usize) -> Vec<f64> {
    assert!(ncols > 0, "ncols must be positive");
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % ncols, 0);
    let mut out = vec![0.0; ncols];
    for (ar, br) in a.chunks_exact(ncols).zip(b.chunks_exact(ncols)) {
        for ((o, x), y) in out.iter_mut().zip(ar).zip(br) {
            *o += x * y;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_manual() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_and_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matvec_and_norms() {
        let a = Mat::from_rows(&[vec![1.0, -2.0], vec![0.0, 3.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![-1.0, 3.0]);
        assert_eq!(a.inf_norm(), 3.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
