//! Dense matrix exponential — the exact diffusion kernel
//! `K = σ_f² exp(-βL)` baseline (paper Eq. after (1)).
//!
//! Scaling-and-squaring with a Taylor core, mirroring the L2 artifact
//! (`python/compile/model.py::dense_diffusion`) so the two baselines
//! agree to float tolerance.

use super::Mat;

/// exp(A) via scaling-and-squaring + Taylor. `order` ~ 16 gives ~1e-14
/// once the scaled norm is < 0.5.
pub fn expm(a: &Mat, order: usize) -> Mat {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let nrm = a.inf_norm();
    let squarings = if nrm > 0.5 {
        (nrm / 0.5).log2().ceil() as u32
    } else {
        0
    };
    let scale = 0.5f64.powi(squarings as i32);
    let a_s = a.scale(scale);
    let mut out = Mat::eye(n);
    let mut term = Mat::eye(n);
    for r in 1..=order {
        term = term.matmul(&a_s).scale(1.0 / r as f64);
        out = out.add(&term);
    }
    for _ in 0..squarings {
        out = out.matmul(&out);
    }
    out
}

/// Exact dense diffusion kernel K = sigma_f2 * exp(-beta * L) for a
/// graph Laplacian given as rows.
pub fn diffusion_kernel(laplacian: &Mat, beta: f64, sigma_f2: f64) -> Mat {
    expm(&laplacian.scale(-beta), 18).scale(sigma_f2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::{jacobi_eigen, matrix_function};
    use crate::prop_assert;
    use crate::util::proptest::proptest;

    #[test]
    fn expm_zero_is_identity() {
        let e = expm(&Mat::zeros(4, 4), 16);
        assert_eq!(e, Mat::eye(4));
    }

    #[test]
    fn expm_diagonal() {
        let a = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, -2.0]]);
        let e = expm(&a, 20);
        assert!((e[(0, 0)] - 1f64.exp()).abs() < 1e-12);
        assert!((e[(1, 1)] - (-2f64).exp()).abs() < 1e-12);
        assert!(e[(0, 1)].abs() < 1e-14);
    }

    #[test]
    fn expm_matches_eigen_for_symmetric() {
        proptest(12, |rng| {
            let n = 2 + rng.below(8);
            let mut b = Mat::zeros(n, n);
            for v in &mut b.data {
                *v = rng.normal();
            }
            let a = b.add(&b.transpose()).scale(0.5);
            let via_taylor = expm(&a, 20);
            let via_eigen = matrix_function(&a, f64::exp);
            for i in 0..n * n {
                prop_assert!(
                    (via_taylor.data[i] - via_eigen.data[i]).abs() < 1e-8,
                    "expm mismatch at flat {i}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn diffusion_kernel_spd_and_trace() {
        // Ring graph laplacian, beta small: K ~ I - beta L.
        let g = crate::graph::generators::ring(8);
        let l = Mat::from_rows(&g.dense_laplacian());
        let k = diffusion_kernel(&l, 0.01, 1.0);
        let (lam, _) = jacobi_eigen(&k, 100);
        assert!(lam[0] > 0.0);
        for i in 0..8 {
            assert!((k[(i, i)] - (1.0 - 0.01 * 2.0)).abs() < 1e-3);
        }
    }
}
